"""Crash-resume CI smoke (ISSUE 7): real process-boundary resume equality.

Three subprocess launches of ``repro.launch.train`` on one fixed workload
(semisync chainfed + DP + 20% dropout injection):

* **A** — uninterrupted reference run; saves final params + metrics JSON.
* **B** — same run with ``--checkpoint-every 2 --halt-after 2``: writes the
  durable run-state checkpoint, then "crashes" right after it.
* **C** — fresh process, ``--resume`` from B's checkpoint, finishes the
  remaining rounds; saves final params + metrics JSON.

Gates:

* C's saved parameter file is **byte-identical** to A's — same trees, same
  dtypes, same bits (msgpack serialization is deterministic);
* C's metrics JSON is **text-identical** to A's — every RoundMetrics field
  including the DP ε spend;
* C's ``== jit-cache:`` report shows every compiled cohort function holding
  exactly one cache entry — restoring a checkpoint must not recompile.

    PYTHONPATH=src python -m benchmarks.crash_resume_smoke
"""
from __future__ import annotations

import argparse
import os
import pathlib
import re
import subprocess
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parent.parent

BASE = ["--arch", "bert_tiny", "--smoke", "--unconstrained-memory",
        "--rounds", "4", "--clients", "6", "--clients-per-round", "3",
        "--batch-size", "4", "--local-steps", "1", "--eval-every", "2",
        "--method", "chainfed", "--mode", "semisync",
        "--dropout-prob", "0.2", "--dp-clip", "0.5", "--dp-noise", "0.6"]


def launch(extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + BASE + extra,
        cwd=REPO, env=env, capture_output=True, text=True)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    if proc.returncode != 0:
        raise SystemExit(f"launcher failed ({proc.returncode}): {extra}")
    return proc.stdout


def main(argv=None):
    argparse.ArgumentParser(description=__doc__).parse_args(argv)
    with tempfile.TemporaryDirectory(prefix="crash_resume_") as td:
        d = pathlib.Path(td)
        ck = d / "run.msgpack"
        print("# phase A: uninterrupted reference")
        launch(["--save", str(d / "a.ckpt"),
                "--metrics-out", str(d / "a.json")])
        print("# phase B: checkpoint, then crash after round 2")
        launch(["--checkpoint-every", "2", "--checkpoint-path", str(ck),
                "--halt-after", "2"])
        assert ck.exists(), "phase B wrote no checkpoint"
        print("# phase C: fresh process resumes from the checkpoint")
        out_c = launch(["--resume", str(ck),
                        "--save", str(d / "c.ckpt"),
                        "--metrics-out", str(d / "c.json")])

        a, c = (d / "a.ckpt").read_bytes(), (d / "c.ckpt").read_bytes()
        assert a == c, (
            f"resumed params differ from the uninterrupted run "
            f"({len(a)} vs {len(c)} bytes)")
        ma = (d / "a.json").read_text()
        mc = (d / "c.json").read_text()
        assert ma == mc, ("resumed metrics differ from the uninterrupted "
                          f"run:\n--- A\n{ma}\n--- C\n{mc}")
        m = re.search(r"== jit-cache: fns=(\d+) sizes=\[([^\]]*)\]", out_c)
        assert m, "resume run printed no jit-cache report"
        sizes = [int(s) for s in m.group(2).split(",") if s.strip()]
        assert int(m.group(1)) >= 1 and all(s == 1 for s in sizes), (
            f"resume recompiled: cache sizes {sizes}")
        print(f"# smoke OK: resume bit-identical ({len(a)} param bytes, "
              f"metrics match, {len(sizes)} cohort fns each compiled once)")


if __name__ == "__main__":
    main()
