"""Paper Table 4: ablation — removing DLCT (window co-tuning), GPO (global
loss) or FOAT (boundary selection) each degrades CHAINFED.
"""
from __future__ import annotations

import time

import jax

from .common import base_params, make_sim
from repro.configs import get_config
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import make_strategy
from repro.models.config import ChainConfig

# ablations are themselves registered strategies (chainfed_wo_*)
VARIANTS = {
    "chainfed": "chainfed",
    "wo_dlct": "chainfed_wo_dlct",
    "wo_gpo": "chainfed_wo_gpo",
    "wo_foat": "chainfed_wo_foat",
}


def run(rounds=16, fast=False):
    cfg = get_config("bert_tiny")
    chain = ChainConfig(window=3, lam=0.2, foat_threshold=0.8, local_steps=2,
                        lr=3e-3)
    rows, table = [], {}
    for ds in (["agnews"] if fast else ["yelp_p", "agnews"]):
        for iid in (True, False):
            sim, tokens, labels, spec = make_sim(ds, iid, cfg)
            params = base_params(cfg, tokens)
            for name, registered in VARIANTS.items():
                strat = make_strategy(registered, cfg, chain,
                                      jax.random.PRNGKey(0))
                strat.params = params
                t0 = time.time()
                hist = run_sync_rounds(sim, strat, rounds, eval_every=3)
                acc = max(h.acc for h in hist)
                key = f"{ds}/{'iid' if iid else 'noniid'}"
                table[(name, key)] = acc
                rows.append(f"table4/{key}/{name},"
                            f"{(time.time()-t0)/rounds*1e6:.0f},acc={acc:.4f}")
    return rows, table
