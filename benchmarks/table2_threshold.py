"""Paper Table 2: impact of the FOAT threshold T (Q=3).  T=1.0 = full chain.

Claims validated: accuracy peaks below T=1.0 (freezing general lower layers
helps), with convergence speedup and communication reduction vs full chain.
"""
from __future__ import annotations

import time

import jax

from .common import Result, base_params, csv_row, make_sim
from repro.configs import get_config
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import make_strategy
from repro.models.config import ChainConfig


def _rounds_to(hist, target):
    for h in hist:
        if h.acc >= target:
            return h.round + 1
    return hist[-1].round + 1 if hist else 1


def run(rounds=18, fast=False):
    cfg = get_config("bert_tiny")
    rows, table = [], {}
    for ds in (["agnews"] if fast else ["yelp_p", "agnews"]):
        base_hist = None
        for T in (1.0, 0.9, 0.8):
            accs = {}
            for iid in (True, False):
                sim, tokens, labels, spec = make_sim(ds, iid, cfg)
                params = base_params(cfg, tokens)
                chain = ChainConfig(window=3, lam=0.2, foat_threshold=T,
                                    local_steps=2, lr=3e-3)
                strat = make_strategy("chainfed", cfg, chain,
                                      jax.random.PRNGKey(0),
                                      use_foat=(T < 1.0))
                strat.params = params
                t0 = time.time()
                hist = run_sync_rounds(sim, strat, rounds, eval_every=2)
                wall = time.time() - t0
                accs[iid] = (max(h.acc for h in hist), hist, wall,
                             strat.comm_bytes_per_round(),
                             strat.l_start)
            best, hist, wall, comm, l_start = accs[True]
            if T == 1.0:
                base_hist = hist
            target = 0.9 * max(h.acc for h in base_hist)
            speedup = _rounds_to(base_hist, target) / max(1, _rounds_to(hist, target))
            table[(ds, T)] = {"iid": accs[True][0], "noniid": accs[False][0],
                              "speedup": speedup, "comm": comm,
                              "l_start": l_start}
            rows.append(
                f"table2/{ds}/T={T},{wall/rounds*1e6:.0f},"
                f"acc_iid={accs[True][0]:.4f};acc_noniid={accs[False][0]:.4f};"
                f"speedup={speedup:.2f};comm={comm};l_start={l_start}")
    return rows, table
