"""Shared benchmark harness: builds the federated testbed (pretrained base +
memory-budgeted clients + Dirichlet partitions) and runs any method to
convergence, returning (accuracy, wall, comm) — the measurements behind every
paper-table benchmark.

Scale note (EXPERIMENTS.md): models/datasets are CPU-reduced; the *claims*
validated are ordering/trend claims, not absolute accuracies.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import make_strategy
from repro.models.config import ChainConfig, FedConfig
from repro.train.pretrain import pretrained_base

DEFAULT_ROUNDS = 14
PRETRAIN_STEPS = 300


@dataclasses.dataclass
class Result:
    name: str
    acc: float
    rounds: int
    wall_s: float
    comm_bytes: int
    aux: dict


def make_sim(dataset: str, iid: bool, cfg, seed=0, n_clients=12,
             clients_per_round=4, batch_size=8, memory_constrained=True):
    spec = DATASETS[dataset]
    spec = dataclasses.replace(spec, vocab=cfg.vocab_size)
    tokens, labels = make_classification(spec)
    fed = FedConfig(n_clients=n_clients, clients_per_round=clients_per_round,
                    iid=iid, dirichlet_alpha=1.0, seed=seed)
    # host arrays: jit converts on call; cohort_batches stays host-side
    batch_fn = lambda idx: classification_batch(spec, tokens, labels, idx)
    sim = FedSim(cfg, fed, tokens, labels, batch_fn, batch_size=batch_size,
                 memory_constrained=memory_constrained)
    return sim, tokens, labels, spec


def base_params(cfg, tokens, steps=PRETRAIN_STEPS):
    return pretrained_base(cfg, tokens, steps=steps)


def run_method(method: str, cfg, chain: ChainConfig, sim, params,
               rounds=DEFAULT_ROUNDS, seed=0, strategy_opts=None) -> Result:
    key = jax.random.PRNGKey(seed)
    if method == "no_ft":
        strat = make_strategy("full_adapters", cfg, chain, key)
        strat.params = params
        loss, acc = strat.evaluate(sim.eval_batch())
        return Result("no_ft", acc, 0, 0.0, 0, {})
    strat = make_strategy(method, cfg, chain, key, **(strategy_opts or {}))
    strat.params = params
    t0 = time.time()
    hist = run_sync_rounds(sim, strat, rounds, eval_every=max(1, rounds // 3))
    wall = time.time() - t0
    best = max((h.acc for h in hist), default=0.0)
    return Result(method, best, rounds, wall,
                  strat.comm_bytes_per_round(),
                  {"final": hist[-1].acc if hist else 0.0,
                   "participants": hist[-1].n_participants if hist else 0})


def csv_row(table: str, r: Result, derived_extra=""):
    us = (r.wall_s / max(1, r.rounds)) * 1e6
    derived = f"acc={r.acc:.4f};comm={r.comm_bytes}"
    if derived_extra:
        derived += ";" + derived_extra
    return f"{table}/{r.name},{us:.0f},{derived}"
