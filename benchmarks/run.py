"""Benchmark orchestrator — one benchmark per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run              # full suite
    PYTHONPATH=src python -m benchmarks.run --fast       # reduced sweep
    PYTHONPATH=src python -m benchmarks.run --only table1,fig9
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (bench_privacy, bench_round, bench_serve, fig3_memory,
               fig8_window, fig9_lambda, roofline, table1_main,
               table2_threshold, table3_instruction, table4_ablation)

SUITES = {
    "fig3": fig3_memory,
    "roofline": roofline,
    "round": bench_round,
    "serve": bench_serve,
    "privacy": bench_privacy,
    "table1": table1_main,
    "table2": table2_threshold,
    "table3": table3_instruction,
    "table4": table4_ablation,
    "fig8": fig8_window,
    "fig9": fig9_lambda,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SUITES))
    args = ap.parse_args(argv)

    names = list(SUITES) if args.only is None else [
        n.strip() for n in args.only.split(",") if n.strip()]
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {', '.join(unknown)}; "
                 f"available: {', '.join(SUITES)}")
    if not names:
        ap.error("--only selected no suites; available: " + ", ".join(SUITES))
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        mod = SUITES[name]
        t0 = time.time()
        try:
            rows, _ = mod.run(fast=args.fast)
            for r in rows:
                print(r, flush=True)
            print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)
        except Exception as e:
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
