"""Serving-throughput benchmark: single-tenant vs mixed-tenant batches on
the multi-tenant serving engine (ISSUE 3 tentpole).

The engine's design claim is that tenant mixing is free at the program
level: tenant ids are traced data routed through one compiled
prefill/decode, so a mixed-tenant batch (every row a different adapter
stack, incl. a fused synthetic tenant) should sustain roughly the
single-tenant tokens/s — the only extra work is the per-layer row gather.
This benchmark measures exactly that ratio, plus the continuous-batching
serve loop (slot admission from a request queue) on the same workload.

Two workloads:

* ``qwen2_sm``  — the qwen2-0.5b smoke trunk (dense GQA + qkv bias), the
  serving config the CLI demo and decode-exactness tests use.
* ``llama_sm``  — the mid-size LLaMA-class trunk shared with
  ``bench_round`` (6 layers, d_model 256): more compute per token, so the
  routing overhead is amortized — the honest end-to-end number.

    PYTHONPATH=src python -m benchmarks.bench_serve            # full sweep
    PYTHONPATH=src python -m benchmarks.bench_serve --fast
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI guard

Writes ``BENCH_serve_throughput.json`` (see --out): per workload the
single-tenant / mixed-tenant / continuous tokens/s and the mixed/single
ratio, plus (ISSUE 9) the **paged-KV** sections — paged-vs-dense continuous
throughput at uniform lengths, the long-tail KV-footprint shrink (KV
bytes/token, dense vs paged peak) and the host-tier **tenancy** run (T
tenants through an R-row LRU resident set, hit rate + bit-equality).  This
file is the serving-perf baseline future PRs are judged against;
``benchmarks.report`` renders it.  ``--smoke`` asserts the regression
gates: mixed-tenant ≥ 0.7× single-tenant tokens/s, paged ≥ 0.9× dense
continuous tokens/s, long-tail KV footprint shrink ≥ 2×, LRU serving
bit-identical with zero steady-state re-jits.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.memory import paged_kv_bytes, serve_kv_bytes
from repro.launch.serve import Request, ServeEngine, _decode_paged_jit
from repro.models import transformer as T

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serve_throughput.json"

GATE = 0.7          # mixed-tenant tokens/s must stay ≥ GATE × single-tenant
PAGED_GATE = 0.9    # paged continuous tokens/s ≥ PAGED_GATE × dense
FOOTPRINT_GATE = 2.0  # long-tail mix: dense KV bytes ≥ 2× paged peak


@dataclasses.dataclass(frozen=True)
class Workload:
    cfg: object
    batch: int
    prompt_len: int
    gen: int
    tenants: int        # registered single-task tenants (a fused one is added)
    page_size: int = 8
    # long-tail mix: most requests stop at ``tail_gen``, a few run to the
    # full ``long_cap`` horizon the dense cache must provision for
    long_cap: int = 48
    tail_gen: int = 4
    # tenancy run: T registered tenants through an R-row resident set
    lib_tenants: int = 12
    lib_resident: int = 4


def workloads(smoke: bool):
    if smoke:
        return {"qwen2_smoke": Workload(get_smoke_config("qwen2_0_5b"),
                                        batch=4, prompt_len=8, gen=8,
                                        tenants=3, page_size=4, long_cap=40,
                                        tail_gen=4, lib_tenants=12,
                                        lib_resident=4)}
    return {
        "qwen2_sm": Workload(get_smoke_config("qwen2_0_5b"), batch=8,
                             prompt_len=16, gen=24, tenants=3,
                             lib_tenants=64, lib_resident=8),
        "llama_sm": Workload(get_config("llama_100m").replace(
                                 n_layers=6, d_model=256, n_heads=8,
                                 n_kv_heads=8, d_ff=768, vocab_size=2048),
                             batch=8, prompt_len=16, gen=24, tenants=3,
                             lib_tenants=64, lib_resident=8),
    }


def build_engine(wl: Workload, seed=0, n_tenants=None, resident=None):
    """Engine with perturbed tenant stacks + a fused tenant (fused only in
    the default small-registry shape)."""
    key = jax.random.PRNGKey(seed)
    params = T.init_lm(key, wl.cfg)
    base = T.init_adapters(key, wl.cfg)
    engine = ServeEngine(params, wl.cfg, base, resident_capacity=resident)
    names = []
    for i in range(n_tenants if n_tenants is not None else wl.tenants):
        k = jax.random.PRNGKey(100 + i)
        stack = jax.tree_util.tree_map(
            lambda x: x + 0.02 * jax.random.normal(k, x.shape, x.dtype), base)
        names.append(engine.register_tenant(f"tenant{i}", stack=stack))
    if n_tenants is None:
        engine.fuse_tenants("fused", names[:2], weights=[0.5, 0.5])
        names.append("fused")
    return engine, names


def time_tok_s(fn, n_tokens, iters):
    """Tokens/s of ``fn`` (one warmup call covers compilation)."""
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return n_tokens * iters / (time.perf_counter() - t0)


def bench_one(wname, wl: Workload, iters, seed=0):
    engine, names = build_engine(wl, seed)
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (wl.batch, wl.prompt_len), 4,
                                 wl.cfg.vocab_size)
    single = [names[0]] * wl.batch
    mixed = [names[i % len(names)] for i in range(wl.batch)]
    n_tok = wl.batch * wl.gen

    out = {}
    for label, rows in (("single", single), ("mixed", mixed)):
        tok_s = time_tok_s(lambda: engine.generate(prompts, rows, wl.gen),
                           n_tok, iters)
        out[label] = {"tokens_per_s": tok_s, "tenants": len(set(rows))}
    out["ratio"] = out["mixed"]["tokens_per_s"] / out["single"]["tokens_per_s"]

    # continuous batching: 2× oversubscribed queue over `batch` slots
    reqs = [Request(i, np.asarray(prompts[i % wl.batch]), mixed[i % wl.batch],
                    wl.gen) for i in range(2 * wl.batch)]
    tok_s = time_tok_s(
        lambda: engine.serve(list(reqs), slots=wl.batch,
                             prompt_len=wl.prompt_len, max_new_cap=wl.gen),
        2 * n_tok, max(1, iters // 2))
    out["continuous"] = {"tokens_per_s": tok_s, "requests": len(reqs),
                         "slots": wl.batch}

    # paged continuous batching, identical uniform workload: throughput must
    # track the dense slot cache (writes are page-routed scatters, reads a
    # page gather / the scalar-prefetch kernel — no horizon-sized copies)
    paged_tok_s = time_tok_s(
        lambda: engine.serve(list(reqs), slots=wl.batch,
                             prompt_len=wl.prompt_len, max_new_cap=wl.gen,
                             paged=True, page_size=wl.page_size),
        2 * n_tok, max(1, iters // 2))
    jits_before = _decode_paged_jit._cache_size()
    uniform = engine.serve(list(reqs), slots=wl.batch,
                           prompt_len=wl.prompt_len, max_new_cap=wl.gen,
                           paged=True, page_size=wl.page_size)
    rejits = _decode_paged_jit._cache_size() - jits_before
    dense_ref = engine.serve(list(reqs), slots=wl.batch,
                             prompt_len=wl.prompt_len, max_new_cap=wl.gen)
    out["paged"] = {
        "tokens_per_s": paged_tok_s,
        "ratio_vs_dense": paged_tok_s / out["continuous"]["tokens_per_s"],
        "page_size": wl.page_size,
        "steady_state_rejits": int(rejits),
        "equal_to_dense": all(np.array_equal(uniform[r.rid],
                                             dense_ref[r.rid])
                              for r in reqs),
    }
    return out


def bench_long_tail(wl: Workload, seed=0):
    """Long-tail request mix: every request decodes ``tail_gen`` tokens
    except one straggler that runs to ``long_cap`` — the dense slot cache
    provisions *every* slot for the straggler's horizon while the paged pool
    pays each request's actual pages.  Returns the KV footprint comparison
    (bytes and bytes/token)."""
    engine, names = build_engine(wl, seed)
    rng = np.random.default_rng(seed + 7)
    n_req = 3 * wl.batch
    reqs = []
    for i in range(n_req):
        gen = wl.long_cap if i == 0 else wl.tail_gen
        toks = rng.integers(4, wl.cfg.vocab_size,
                            wl.prompt_len).astype(np.int32)
        reqs.append(Request(i, toks, names[i % len(names)], gen))
    horizon = wl.prompt_len + wl.long_cap
    mp = -(-horizon // wl.page_size)
    out = engine.serve(list(reqs), slots=wl.batch, prompt_len=wl.prompt_len,
                       max_new_cap=wl.long_cap, paged=True,
                       page_size=wl.page_size, n_pages=wl.batch * mp)
    stats = engine.last_serve_stats["pages"]
    n_tokens = sum(len(v) for v in out.values()) + n_req * wl.prompt_len
    dense_bytes = serve_kv_bytes(wl.cfg, wl.batch, horizon)
    paged_bytes = paged_kv_bytes(wl.cfg, stats["peak_in_use"], wl.page_size)
    return {
        "requests": n_req, "slots": wl.batch, "horizon": horizon,
        "tail_gen": wl.tail_gen, "long_cap": wl.long_cap,
        "dense_kv_bytes": dense_bytes,
        "paged_kv_bytes_peak": paged_bytes,
        "footprint_ratio": dense_bytes / max(1, paged_bytes),
        "dense_kv_bytes_per_token": dense_bytes / n_tokens,
        "paged_kv_bytes_per_token": paged_bytes / n_tokens,
        "peak_pages": stats["peak_in_use"],
    }


def bench_tenancy(wl: Workload, seed=0, check_equal=True):
    """T ≫ resident-set serving: ``lib_tenants`` registered stacks served
    through a ``lib_resident``-row LRU device slab.  Reports the resident-set
    hit rate and (``check_equal``) bit-equality against the fully resident
    library."""
    T_, R = wl.lib_tenants, wl.lib_resident
    eng_lru, names = build_engine(wl, seed, n_tenants=T_, resident=R)
    rng = np.random.default_rng(seed + 3)
    n_req = 3 * wl.batch
    reqs = [Request(i, rng.integers(4, wl.cfg.vocab_size,
                                    wl.prompt_len).astype(np.int32),
                    names[int(rng.integers(0, T_))],
                    wl.gen) for i in range(n_req)]
    out = eng_lru.serve(list(reqs), slots=wl.batch, prompt_len=wl.prompt_len,
                        max_new_cap=wl.gen, paged=True,
                        page_size=wl.page_size)
    stats = eng_lru.last_serve_stats
    rec = {"tenants": T_, "resident": R,
           "hit_rate": stats["adapter_hit_rate"],
           "uploads": stats["adapter"]["uploads"],
           "evictions": stats["adapter"]["evictions"]}
    if check_equal:
        eng_full, _ = build_engine(wl, seed, n_tenants=T_)
        ref = eng_full.serve(list(reqs), slots=wl.batch,
                             prompt_len=wl.prompt_len, max_new_cap=wl.gen,
                             paged=True, page_size=wl.page_size)
        rec["equal_to_full_resident"] = all(
            np.array_equal(out[r.rid], ref[r.rid]) for r in reqs)
    return rec


def run(fast: bool = False, smoke: bool = False, iters: int = None,
        out_path=DEFAULT_OUT):
    iters = iters or (2 if smoke else (3 if fast else 6))
    results, rows = [], []
    for wname, wl in workloads(smoke).items():
        r = bench_one(wname, wl, iters)
        r["long_tail"] = bench_long_tail(wl)
        r["tenancy"] = bench_tenancy(wl, check_equal=smoke or wl.cfg.n_layers <= 4)
        rec = {"arch": wname, "batch": wl.batch, "prompt_len": wl.prompt_len,
               "gen": wl.gen, "n_tenants": wl.tenants + 1, "iters": iters,
               **r}
        results.append(rec)
        rows.append(
            f"serve/{wname},"
            f"{1e6 / r['mixed']['tokens_per_s']:.0f},"
            f"single_tok_s={r['single']['tokens_per_s']:.1f}"
            f";mixed_tok_s={r['mixed']['tokens_per_s']:.1f}"
            f";ratio={r['ratio']:.2f}"
            f";continuous_tok_s={r['continuous']['tokens_per_s']:.1f}"
            f";paged_tok_s={r['paged']['tokens_per_s']:.1f}"
            f";paged_ratio={r['paged']['ratio_vs_dense']:.2f}"
            f";kv_shrink={r['long_tail']['footprint_ratio']:.1f}x"
            f";lru_hit_rate={r['tenancy']['hit_rate']:.2f}")
        print(rows[-1], flush=True)
    doc = {"backend": jax.default_backend(),
           "mode": "smoke" if smoke else ("fast" if fast else "full"),
           "gate_mixed_over_single": GATE,
           "gate_paged_over_dense": PAGED_GATE,
           "gate_long_tail_footprint": FOOTPRINT_GATE,
           "results": results}
    pathlib.Path(out_path).write_text(json.dumps(doc, indent=2) + "\n")
    return rows, doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + regression gate: mixed-tenant "
                         f"tokens/s must be ≥ {GATE}× single-tenant")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args(argv)
    rows, doc = run(fast=args.fast, smoke=args.smoke, iters=args.iters,
                    out_path=args.out)
    if args.smoke:
        for rec in doc["results"]:
            assert rec["ratio"] >= GATE, (
                f"mixed-tenant serving regressed: {rec['arch']} ratio "
                f"{rec['ratio']:.2f} < {GATE} (single "
                f"{rec['single']['tokens_per_s']:.1f} tok/s, mixed "
                f"{rec['mixed']['tokens_per_s']:.1f} tok/s)")
            assert rec["paged"]["ratio_vs_dense"] >= PAGED_GATE, (
                f"paged serving regressed: {rec['arch']} paged/dense "
                f"{rec['paged']['ratio_vs_dense']:.2f} < {PAGED_GATE} "
                f"(dense {rec['continuous']['tokens_per_s']:.1f} tok/s, "
                f"paged {rec['paged']['tokens_per_s']:.1f} tok/s)")
            assert rec["paged"]["equal_to_dense"], (
                f"{rec['arch']}: paged tokens diverge from dense")
            assert rec["paged"]["steady_state_rejits"] == 0, (
                f"{rec['arch']}: paged decode re-jitted in steady state")
            assert rec["long_tail"]["footprint_ratio"] >= FOOTPRINT_GATE, (
                f"long-tail KV footprint: {rec['arch']} shrink "
                f"{rec['long_tail']['footprint_ratio']:.2f}x < "
                f"{FOOTPRINT_GATE}x")
            assert rec["tenancy"].get("equal_to_full_resident", True), (
                f"{rec['arch']}: LRU resident-set serving diverges from "
                f"the fully resident library")
        print(f"# smoke OK: mixed ≥ {GATE}× single; paged ≥ {PAGED_GATE}× "
              f"dense (bit-equal, 0 re-jits); long-tail KV shrink ≥ "
              f"{FOOTPRINT_GATE}×; LRU serving bit-identical")


if __name__ == "__main__":
    main()
