"""Serving-throughput benchmark: single-tenant vs mixed-tenant batches on
the multi-tenant serving engine (ISSUE 3 tentpole).

The engine's design claim is that tenant mixing is free at the program
level: tenant ids are traced data routed through one compiled
prefill/decode, so a mixed-tenant batch (every row a different adapter
stack, incl. a fused synthetic tenant) should sustain roughly the
single-tenant tokens/s — the only extra work is the per-layer row gather.
This benchmark measures exactly that ratio, plus the continuous-batching
serve loop (slot admission from a request queue) on the same workload.

Two workloads:

* ``qwen2_sm``  — the qwen2-0.5b smoke trunk (dense GQA + qkv bias), the
  serving config the CLI demo and decode-exactness tests use.
* ``llama_sm``  — the mid-size LLaMA-class trunk shared with
  ``bench_round`` (6 layers, d_model 256): more compute per token, so the
  routing overhead is amortized — the honest end-to-end number.

    PYTHONPATH=src python -m benchmarks.bench_serve            # full sweep
    PYTHONPATH=src python -m benchmarks.bench_serve --fast
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI guard

Writes ``BENCH_serve_throughput.json`` (see --out): per workload the
single-tenant / mixed-tenant / continuous tokens/s and the mixed/single
ratio.  This file is the serving-perf baseline future PRs are judged
against; ``benchmarks.report`` renders it.  ``--smoke`` asserts the
regression gate: mixed-tenant tokens/s ≥ 0.7× single-tenant.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.serve import Request, ServeEngine
from repro.models import transformer as T

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serve_throughput.json"

GATE = 0.7          # mixed-tenant tokens/s must stay ≥ GATE × single-tenant


@dataclasses.dataclass(frozen=True)
class Workload:
    cfg: object
    batch: int
    prompt_len: int
    gen: int
    tenants: int        # registered single-task tenants (a fused one is added)


def workloads(smoke: bool):
    if smoke:
        return {"qwen2_smoke": Workload(get_smoke_config("qwen2_0_5b"),
                                        batch=4, prompt_len=8, gen=8,
                                        tenants=3)}
    return {
        "qwen2_sm": Workload(get_smoke_config("qwen2_0_5b"), batch=8,
                             prompt_len=16, gen=24, tenants=3),
        "llama_sm": Workload(get_config("llama_100m").replace(
                                 n_layers=6, d_model=256, n_heads=8,
                                 n_kv_heads=8, d_ff=768, vocab_size=2048),
                             batch=8, prompt_len=16, gen=24, tenants=3),
    }


def build_engine(wl: Workload, seed=0):
    """Engine with ``wl.tenants`` perturbed tenant stacks + a fused tenant."""
    key = jax.random.PRNGKey(seed)
    params = T.init_lm(key, wl.cfg)
    base = T.init_adapters(key, wl.cfg)
    engine = ServeEngine(params, wl.cfg, base)
    names = []
    for i in range(wl.tenants):
        k = jax.random.PRNGKey(100 + i)
        stack = jax.tree_util.tree_map(
            lambda x: x + 0.02 * jax.random.normal(k, x.shape, x.dtype), base)
        names.append(engine.register_tenant(f"tenant{i}", stack=stack))
    engine.fuse_tenants("fused", names[:2], weights=[0.5, 0.5])
    return engine, names + ["fused"]


def time_tok_s(fn, n_tokens, iters):
    """Tokens/s of ``fn`` (one warmup call covers compilation)."""
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return n_tokens * iters / (time.perf_counter() - t0)


def bench_one(wname, wl: Workload, iters, seed=0):
    engine, names = build_engine(wl, seed)
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (wl.batch, wl.prompt_len), 4,
                                 wl.cfg.vocab_size)
    single = [names[0]] * wl.batch
    mixed = [names[i % len(names)] for i in range(wl.batch)]
    n_tok = wl.batch * wl.gen

    out = {}
    for label, rows in (("single", single), ("mixed", mixed)):
        tok_s = time_tok_s(lambda: engine.generate(prompts, rows, wl.gen),
                           n_tok, iters)
        out[label] = {"tokens_per_s": tok_s, "tenants": len(set(rows))}
    out["ratio"] = out["mixed"]["tokens_per_s"] / out["single"]["tokens_per_s"]

    # continuous batching: 2× oversubscribed queue over `batch` slots
    reqs = [Request(i, np.asarray(prompts[i % wl.batch]), mixed[i % wl.batch],
                    wl.gen) for i in range(2 * wl.batch)]
    tok_s = time_tok_s(
        lambda: engine.serve(list(reqs), slots=wl.batch,
                             prompt_len=wl.prompt_len, max_new_cap=wl.gen),
        2 * n_tok, max(1, iters // 2))
    out["continuous"] = {"tokens_per_s": tok_s, "requests": len(reqs),
                         "slots": wl.batch}
    return out


def run(fast: bool = False, smoke: bool = False, iters: int = None,
        out_path=DEFAULT_OUT):
    iters = iters or (2 if smoke else (3 if fast else 6))
    results, rows = [], []
    for wname, wl in workloads(smoke).items():
        r = bench_one(wname, wl, iters)
        rec = {"arch": wname, "batch": wl.batch, "prompt_len": wl.prompt_len,
               "gen": wl.gen, "n_tenants": wl.tenants + 1, "iters": iters,
               **r}
        results.append(rec)
        rows.append(
            f"serve/{wname},"
            f"{1e6 / r['mixed']['tokens_per_s']:.0f},"
            f"single_tok_s={r['single']['tokens_per_s']:.1f}"
            f";mixed_tok_s={r['mixed']['tokens_per_s']:.1f}"
            f";ratio={r['ratio']:.2f}"
            f";continuous_tok_s={r['continuous']['tokens_per_s']:.1f}")
        print(rows[-1], flush=True)
    doc = {"backend": jax.default_backend(),
           "mode": "smoke" if smoke else ("fast" if fast else "full"),
           "gate_mixed_over_single": GATE,
           "results": results}
    pathlib.Path(out_path).write_text(json.dumps(doc, indent=2) + "\n")
    return rows, doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + regression gate: mixed-tenant "
                         f"tokens/s must be ≥ {GATE}× single-tenant")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args(argv)
    rows, doc = run(fast=args.fast, smoke=args.smoke, iters=args.iters,
                    out_path=args.out)
    if args.smoke:
        for rec in doc["results"]:
            assert rec["ratio"] >= GATE, (
                f"mixed-tenant serving regressed: {rec['arch']} ratio "
                f"{rec['ratio']:.2f} < {GATE} (single "
                f"{rec['single']['tokens_per_s']:.1f} tok/s, mixed "
                f"{rec['mixed']['tokens_per_s']:.1f} tok/s)")
        print(f"# smoke OK: mixed-tenant ≥ {GATE}× single-tenant tokens/s")


if __name__ == "__main__":
    main()
