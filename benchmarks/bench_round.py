"""Round-throughput benchmark: legacy per-client dispatch loop vs the batched
cohort step (ISSUE 2 tentpole).  The single-host simulation is dispatch-bound
at reproduction scale — the legacy path issues ``clients_per_round ×
local_steps`` separate jitted calls per round plus host-side optimizer init,
delta extraction and FedAvg, while the cohort path issues ONE jitted call per
plan-group (scan over local steps × vmap over clients, FedAvg fused).

The sweep covers both gradient regimes: backprop strategies (chainfed,
full_adapters, fedra, flora) and the perturbation-based ``fwdllm`` (the
``"spsa"`` GradProgram — 2·n_samples forwards per step, no backward), which
since ISSUE 4 rides the same batched cohort step and is gated by the same
CI smoke job.

Two workloads per strategy:

* ``bert_tiny``   — the paper's bert-tiny trunk in the *dispatch-bound
  regime* (batch 1, short sequences, adapter-only trainables): per-step
  compute is negligible, so the measured gap is the round-path overhead the
  tentpole removes.  This is the cell the ≥3× acceptance bar reads.
* ``llama_sm``    — a mid-size LLaMA-class trunk on a realistic workload
  (batch 4, seq 32, trained head): compute amortizes the dispatch win, the
  honest end-to-end number.

    PYTHONPATH=src python -m benchmarks.bench_round            # full sweep
    PYTHONPATH=src python -m benchmarks.bench_round --fast
    PYTHONPATH=src python -m benchmarks.bench_round --smoke    # CI guard

Writes ``BENCH_round_throughput.json`` (see --out): per (workload, strategy)
the rounds/sec and steps/sec of both paths and the cohort speedup.  This
file is the baseline every future round-path perf PR is judged against.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax

from repro.configs import get_config
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim
from repro.models.config import ChainConfig, FedConfig

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_round_throughput.json"

STRATEGIES = ["chainfed", "full_adapters", "fedra", "flora", "fwdllm"]


@dataclasses.dataclass(frozen=True)
class Workload:
    cfg: object
    seq_len: int
    batch_size: int
    n_clients: int
    clients_per_round: int
    local_steps: int
    train_head: bool


def workloads(smoke: bool):
    if smoke:
        return {"bert_smoke": Workload(get_config("bert_tiny").reduced(),
                                       seq_len=4, batch_size=1, n_clients=8,
                                       clients_per_round=4, local_steps=1,
                                       train_head=False)}
    return {
        "bert_tiny": Workload(get_config("bert_tiny"), seq_len=4,
                              batch_size=1, n_clients=48,
                              clients_per_round=16, local_steps=1,
                              train_head=False),
        "llama_sm": Workload(get_config("llama_100m").replace(
                                 n_layers=6, d_model=256, n_heads=8,
                                 n_kv_heads=8, d_ff=768, vocab_size=2048),
                             seq_len=32, batch_size=4, n_clients=12,
                             clients_per_round=8, local_steps=2,
                             train_head=True),
    }


def make_bench_sim(wl: Workload, seed=0):
    spec = dataclasses.replace(DATASETS["agnews"], seq_len=wl.seq_len,
                               n_samples=1024, vocab=wl.cfg.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: classification_batch(spec, tokens, labels,
                                                idx)
    fed = FedConfig(n_clients=wl.n_clients,
                    clients_per_round=wl.clients_per_round, seed=seed)
    return FedSim(wl.cfg, fed, tokens, labels, batch_fn,
                  batch_size=wl.batch_size, memory_constrained=False)


def _block(strategy):
    jax.block_until_ready(strategy.adapters)
    if strategy.head is not None:
        jax.block_until_ready(strategy.head)


def time_path(strategy, sim, rounds, warmup_rounds, path):
    """Time ``rounds`` federated rounds on one path.  Warmup covers every
    plan in a cyclic schedule (chainfed's DLCT offsets) so the timed region
    hits only cached compilations — steady-state round throughput."""
    run = strategy.sequential_round if path == "legacy" else strategy.round
    for r in range(warmup_rounds):
        clients = sim.sample_clients(strategy.memory_method,
                                     **strategy.memory_kwargs(r))
        if clients:
            run(sim, clients, r)
    _block(strategy)
    t0 = time.perf_counter()
    for r in range(rounds):
        clients = sim.sample_clients(strategy.memory_method,
                                     **strategy.memory_kwargs(r))
        if clients:
            run(sim, clients, r)
    _block(strategy)
    return (time.perf_counter() - t0) / rounds


def bench_one(name, wl: Workload, chain, rounds, seed=0):
    """One (workload, strategy) cell: fresh strategy + sim per path so jit
    caches and sampler state don't leak across the comparison."""
    from repro.fed.registry import make_strategy
    out = {}
    n_offsets = max(1, wl.cfg.total_chain_layers - chain.window + 1)
    warmup = n_offsets if name == "chainfed" else 1
    opts = {"use_foat": False} if name == "chainfed" else {}
    for path in ("legacy", "cohort"):
        sim = make_bench_sim(wl, seed=seed)
        strat = make_strategy(name, wl.cfg, chain, jax.random.PRNGKey(seed),
                              **opts)
        if name == "chainfed":
            strat._foat_done = True   # FOAT is one-off setup, not round cost
        s_per_round = time_path(strat, sim, rounds, warmup, path)
        steps = wl.clients_per_round * chain.local_steps
        round_bytes = strat.comm_bytes_per_round() * wl.clients_per_round
        out[path] = {"s_per_round": s_per_round,
                     "rounds_per_s": 1.0 / s_per_round,
                     "steps_per_s": steps / s_per_round,
                     "bytes_per_round": round_bytes,
                     "bytes_per_s": round_bytes / s_per_round}
    out["speedup"] = out["legacy"]["s_per_round"] / out["cohort"]["s_per_round"]
    return out


def mode_workload(smoke: bool) -> Workload:
    """The scheduler-mode sweep runs a mildly compute-bound workload (batch
    4, seq 16): the modes share the same per-update math, so the comparison
    isolates the *scheduling* overhead (event heap, buffered commits,
    staleness weighting) rather than re-measuring dispatch latency."""
    cfg = get_config("bert_tiny").reduced() if smoke else get_config("bert_tiny")
    return Workload(cfg, seq_len=16, batch_size=4, n_clients=8,
                    clients_per_round=4, local_steps=2, train_head=False)


def bench_modes(modes, smoke: bool, rounds: int, seed=0):
    """Throughput + wallclock-vs-accuracy sweep over scheduler modes: one
    fresh (sim, strategy) per mode, a warmup schedule covering every DLCT
    offset, then ``rounds`` timed server commits.  ``steps_per_s`` counts
    committed client-updates × local steps per host-wall second — the
    number the CI gate compares (async must hold ≥ 0.9× sync)."""
    from repro.fed.registry import make_strategy
    from repro.fed.runtime import FedScheduler

    rounds = max(rounds, 6)     # enough commits for a stable steps/s gate
    wl = mode_workload(smoke)
    chain = ChainConfig(window=3, local_steps=wl.local_steps, lr=1e-3,
                        train_head=wl.train_head)
    n_offsets = max(1, wl.cfg.total_chain_layers - chain.window + 1)
    out = {}
    for mode in modes:
        sim = make_bench_sim(wl, seed=seed)
        strat = make_strategy("chainfed", wl.cfg, chain,
                              jax.random.PRNGKey(seed), use_foat=False)
        # warmup covers every window offset so the timed region hits only
        # cached compilations (same protocol as time_path)
        FedScheduler(sim, strat, mode=mode).run(n_offsets,
                                                eval_every=n_offsets + 1)
        _block(strat)
        sched = FedScheduler(sim, strat, mode=mode)
        t0 = time.perf_counter()
        hist = sched.run(rounds, eval_every=max(1, rounds // 4))
        _block(strat)
        dt = time.perf_counter() - t0
        steps = sched.committed_updates * chain.local_steps
        bytes_moved = sched.committed_updates * strat.comm_bytes_per_round()
        out[mode] = {
            "s_per_commit": dt / max(1, rounds),
            "steps_per_s": steps / dt,
            "bytes_moved": bytes_moved,
            "bytes_per_s": bytes_moved / dt,
            "committed_updates": sched.committed_updates,
            "virtual_wallclock_s": hist[-1].wallclock if hist else 0.0,
            "stale_updates": sum(m.stale_updates for m in hist),
            "history": [{"round": m.round, "wallclock": m.wallclock,
                         "loss": m.loss, "acc": m.acc,
                         "stale_updates": m.stale_updates} for m in hist],
        }
        print(f"round/modes/{mode},{out[mode]['s_per_commit']*1e6:.0f},"
              f"steps_per_s={out[mode]['steps_per_s']:.2f}"
              f";virtual_s={out[mode]['virtual_wallclock_s']:.1f}",
              flush=True)
    return out


def bench_population(smoke: bool, seed=0):
    """Planet-scale sweep (ISSUE 8): lazy ``ClientPool`` populations from
    10³ to 10⁶ clients, flat vs hierarchical (silo-tier) aggregation, on the
    reduced trunk so the measurement isolates *scheduler* work — event-loop
    events/s and resident client-state bytes, which must stay O(active
    cohort) while the population grows three orders of magnitude.

    The CI gate (--smoke --population) checks the 10⁵ cell's lazy run
    against the resident-memory ceiling, and requires the hierarchical
    path to hold ≥ 0.8× the flat path's events/s in its *best* cell —
    every cell runs the identical per-commit workload, so the best-cell
    ratio is the noise-robust throughput estimate."""
    from repro.fed.registry import make_strategy
    from repro.fed.runtime import FedScheduler, Topology

    sizes = [1_000, 10_000, 100_000] if smoke \
        else [1_000, 10_000, 100_000, 1_000_000]
    cfg = get_config("bert_tiny").reduced()
    chain = ChainConfig(window=3, local_steps=1, lr=1e-3)
    spec = dataclasses.replace(DATASETS["agnews"], seq_len=4, n_samples=256,
                               vocab=cfg.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: classification_batch(spec, tokens, labels, idx)
    # best-of-N timing: each rep's window is whole rounds of steady-state
    # scheduling; the max filters one-sided noise (GC pauses, CPU
    # contention, stray compiles) that would otherwise dominate the
    # sub-second windows and make the flat/hier ratio meaningless
    warm, timed, reps = 2, (4 if smoke else 8), (2 if smoke else 3)
    out = {}
    for n in sizes:
        rec = {}
        for topo_name, topo in (
                ("flat", None),
                ("hier", Topology(n_silos=min(32, max(2, n // 250)),
                                  assign="mod"))):
            fed = FedConfig(n_clients=n, clients_per_round=8, seed=seed)
            sim = FedSim(cfg, fed, tokens, labels, batch_fn, batch_size=1,
                         memory_constrained=False, lazy=True, shard_size=8)
            strat = make_strategy("full_adapters", cfg, chain,
                                  jax.random.PRNGKey(seed))
            sched = FedScheduler(sim, strat, mode="semisync", topology=topo)
            sched.run(warm, eval_every=9999)
            _block(strat)
            best, total = 0.0, warm
            for _ in range(reps):
                ev0 = sched.events
                total += timed
                t0 = time.perf_counter()
                sched.run(total, eval_every=9999)
                _block(strat)
                dt = time.perf_counter() - t0
                best = max(best, (sched.events - ev0) / dt)
            rec[topo_name] = {
                "events_per_s": best,
                "commits": int(sched.committed_updates),
                "max_resident": int(sim.pool.max_resident),
                "max_resident_bytes": int(sim.pool.max_resident_bytes),
                "n_silos": topo.n_silos if topo else 1,
                "edge_bytes": int(sched.tier_bytes["edge"]),
                "silo_bytes": int(sched.tier_bytes["silo"]),
            }
            print(f"round/population/{n}/{topo_name},"
                  f"{rec[topo_name]['events_per_s']:.1f},"
                  f"max_resident={rec[topo_name]['max_resident']}"
                  f";max_resident_bytes="
                  f"{rec[topo_name]['max_resident_bytes']}", flush=True)
        rec["hier_vs_flat"] = (rec["hier"]["events_per_s"]
                               / rec["flat"]["events_per_s"])
        out[str(n)] = rec
    return out


# ---------------------------------------------------- fused-optimizer cells
# Analytic HBM traffic per element per AdamW step (the roofline inputs —
# ``benchmarks.roofline`` falls back to these when no dryrun artifacts
# exist).  unfused: four materialized passes (clip-scale g, mu, nu, p);
# fused: every stream read once, written once; int8: moments are 1-byte
# streams (+ per-128 fp32 scales, amortized to ~0.25 B/elem).
OPTIM_BYTES_PER_ELEM = {"unfused_fp32": 48.0, "fused_fp32": 28.0,
                        "fused_int8": 16.25}
ADAMW_FLOPS_PER_ELEM = 15   # mul/add chain + sqrt + div, clip scale applied


def bench_fused_optim(smoke: bool, seed=0, reps=None):
    """Cohort-shaped optimizer hot-path microbench (ISSUE 10 tentpole):
    one vmapped AdamW step over a ``(C, ...)`` trainable stack, sized past
    LLC so the step is memory-bound — the regime where the chainfed cohort
    round spends its optimizer time.

    Three cells:

    * ``unfused_fp32`` — the legacy multi-``tree_map`` step (``fused=False``)
      dispatched without a wrapping jit, materializing every intermediate:
      the seed's op-by-op behavior and the bytes-moved baseline.
    * ``fused_fp32``   — the single-pass path (``fused=None``) under jit:
      one fused chain per leaf (Pallas kernel on TPU, XLA elsewhere).
    * ``fused_int8``   — the single-pass path with block-quantized moments
      (``opt_bits=8``): 4× less resident optimizer state and ~16 vs 28
      B/elem of moment traffic; on CPU the in-tile requant costs compute,
      so its *throughput* win only materializes on HBM-bound accelerators —
      the cell reports resident bytes alongside steps/s for exactly that
      reason.

    The CI gate reads ``fused_fp32``: ≥ 1.1× the unfused steps/s."""
    from repro.core.memory import optimizer_state_bytes
    from repro.optim.base import adamw

    C, N = (4, 250_000) if smoke else (8, 1_000_000)
    reps = reps or (4 if smoke else 8)
    key = jax.random.PRNGKey(seed)
    # two adapter-like leaves so the per-leaf dispatch cost is represented
    p = {"down": jax.random.normal(key, (C, N // 2)) * 0.1,
         "up": jax.random.normal(jax.random.fold_in(key, 1), (C, N // 2))
         * 0.1}
    g = {k: jax.random.normal(jax.random.fold_in(key, 2 + i), v.shape)
         for i, (k, v) in enumerate(p.items())}
    elems = C * N

    def cell(opt_bits, fused, use_jit):
        opt = adamw(1e-3, clip=1.0, opt_bits=opt_bits, fused=fused)
        step = jax.vmap(opt.step)
        if use_jit:
            step = jax.jit(step)
        st = jax.vmap(opt.init)(p)
        p2, _ = step(p, g, st)           # compile / trace warmup
        jax.block_until_ready(p2)
        cp, cst = p, st
        t0 = time.perf_counter()
        for _ in range(reps):
            cp, cst = step(cp, g, cst)
        jax.block_until_ready(cp)
        return (time.perf_counter() - t0) / reps

    out = {}
    for tag, (bits, fused, use_jit) in (
            ("unfused_fp32", (32, False, False)),
            ("fused_fp32", (32, None, True)),
            ("fused_int8", (8, None, True))):
        s = cell(bits, fused, use_jit)
        out[tag] = {
            "s_per_step": s, "steps_per_s": 1.0 / s,
            "elems": elems,
            "bytes_per_step": int(OPTIM_BYTES_PER_ELEM[tag] * elems),
            "bytes_per_s": OPTIM_BYTES_PER_ELEM[tag] * elems / s,
            "opt_state_bytes_per_client": optimizer_state_bytes(
                N, opt_bits=bits),
        }
    for tag in ("fused_fp32", "fused_int8"):
        out[tag]["speedup_vs_unfused"] = (
            out["unfused_fp32"]["s_per_step"] / out[tag]["s_per_step"])
    for tag, rec in out.items():
        extra = ""
        if "speedup_vs_unfused" in rec:
            extra = f";speedup={rec['speedup_vs_unfused']:.2f}"
        print(f"round/fused_optim/{tag},{rec['s_per_step']*1e6:.0f},"
              f"steps_per_s={rec['steps_per_s']:.2f}"
              f";bytes_per_step={rec['bytes_per_step']}"
              f";opt_state_B={rec['opt_state_bytes_per_client']}"
              f"{extra}", flush=True)
    return out


def bench_comm(smoke: bool, seed=0):
    """Per-round per-client uplink bytes across the communication ladder:
    dense chainfed, compressed chainfed (top-k 5%, int8 QSGD), and
    FedKSeed's accumulated-coefficient protocol — including the paper's
    headline cell, 18 KB *total* (up + down) at K=1152
    (``core.memory.fedkseed_total_comm``)."""
    from repro.core.memory import fedkseed_total_comm
    from repro.fed.compress import CompressionConfig
    from repro.fed.registry import make_strategy

    cfg = get_config("bert_tiny").reduced() if smoke else get_config(
        "bert_tiny")
    chain = ChainConfig(window=3, local_steps=1, lr=1e-3)
    dense = make_strategy("chainfed", cfg, chain, jax.random.PRNGKey(seed),
                          use_foat=False).comm_bytes_per_round()
    kseed = make_strategy("fedkseed", cfg, chain, jax.random.PRNGKey(seed))
    out = {
        "chainfed_dense": dense,
        "chainfed_topk05": CompressionConfig(
            kind="topk", ratio=0.05).compressed_bytes(dense),
        "chainfed_qsgd8": CompressionConfig(
            kind="qsgd").compressed_bytes(dense),
        "fedkseed_uplink": kseed.comm_bytes_per_round(),
        "fedkseed_total": kseed.total_comm_bytes(),
        "fedkseed_paper_k1152_total": fedkseed_total_comm(1152),
    }
    for tag, b in out.items():
        print(f"round/comm/{tag},0,bytes={b}", flush=True)
    return out


# the 10⁵-client smoke gate: lazy resident state must stay under this —
# the whole point of the pool is O(active cohort), not O(population)
POPULATION_RESIDENT_CEILING = 1 << 20


def run(fast: bool = False, smoke: bool = False, rounds: int = None,
        out_path=DEFAULT_OUT, modes=None, population: bool = False):
    rounds = rounds or (2 if smoke else (4 if fast else 8))
    # smoke keeps one windowed, one full-stack and one perturbation-based
    # strategy so the CI gate covers every grad-program dispatch shape
    strategies = ["chainfed", "full_adapters", "fwdllm"] if smoke \
        else STRATEGIES
    results, rows = [], []
    for wname, wl in workloads(smoke).items():
        chain = ChainConfig(window=3, local_steps=wl.local_steps, lr=1e-3,
                            train_head=wl.train_head)
        for name in strategies:
            r = bench_one(name, wl, chain, rounds)
            rec = {"arch": wname, "strategy": name,
                   "clients_per_round": wl.clients_per_round,
                   "local_steps": wl.local_steps, "batch_size": wl.batch_size,
                   "seq_len": wl.seq_len, "train_head": wl.train_head,
                   "rounds": rounds, **r}
            results.append(rec)
            rows.append(
                f"round/{wname}/{name},{r['cohort']['s_per_round']*1e6:.0f},"
                f"speedup={r['speedup']:.2f}"
                f";legacy_us={r['legacy']['s_per_round']*1e6:.0f}"
                f";steps_per_s={r['cohort']['steps_per_s']:.2f}"
                f";bytes_per_round={r['cohort']['bytes_per_round']}")
            print(rows[-1], flush=True)
    doc = {"backend": jax.default_backend(),
           "mode": "smoke" if smoke else ("fast" if fast else "full"),
           "results": results}
    doc["fused_optim"] = bench_fused_optim(smoke)
    doc["comm"] = bench_comm(smoke)
    if modes:
        doc["modes"] = bench_modes(modes, smoke, rounds)
    if population:
        doc["population"] = bench_population(smoke)
    pathlib.Path(out_path).write_text(json.dumps(doc, indent=2) + "\n")
    return rows, doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + regression guard: cohort per-step "
                         "time must be ≤ 1.5× the legacy path, and (with "
                         "--modes) async ≥ 0.9× sync steps/s")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--modes", default=None,
                    help="comma-separated scheduler modes to sweep "
                         "(e.g. sync,semisync,async)")
    ap.add_argument("--population", action="store_true",
                    help="lazy-population sweep 10³→10⁶ clients, flat vs "
                         "hierarchical; with --smoke gates 10⁵ resident "
                         "bytes under ceiling and best-cell hier ≥ 0.8× "
                         "flat events/s")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args(argv)
    modes = [m.strip() for m in args.modes.split(",")] if args.modes else None
    rows, doc = run(fast=args.fast, smoke=args.smoke, rounds=args.rounds,
                    out_path=args.out, modes=modes,
                    population=args.population)
    if args.smoke:
        for rec in doc["results"]:
            per_step_cohort = 1.0 / rec["cohort"]["steps_per_s"]
            per_step_legacy = 1.0 / rec["legacy"]["steps_per_s"]
            assert per_step_cohort <= 1.5 * per_step_legacy, (
                f"cohort path regressed: {rec['arch']}/{rec['strategy']} "
                f"{per_step_cohort:.4f}s/step vs legacy "
                f"{per_step_legacy:.4f}s/step")
        print("# smoke OK: cohort path within 1.5× of legacy per step")
        fo = doc["fused_optim"]
        sp = fo["fused_fp32"]["speedup_vs_unfused"]
        assert sp >= 1.1, (
            f"fused optimizer path regressed: {sp:.2f}× unfused steps/s "
            f"on the memory-bound cohort microbench (gate: ≥ 1.1×)")
        ratio = (fo["unfused_fp32"]["opt_state_bytes_per_client"]
                 / fo["fused_int8"]["opt_state_bytes_per_client"])
        assert ratio >= 3.5, (
            f"int8 optimizer-state cut regressed: {ratio:.2f}× (≈4× "
            f"expected; scales cost ~3% of the fp32 payload)")
        print(f"# smoke OK: fused optimizer {sp:.2f}× unfused steps/s "
              f"(≥ 1.1×), int8 state {ratio:.2f}× smaller")
        k1152 = doc["comm"]["fedkseed_paper_k1152_total"]
        assert k1152 == 18 * 1024 == 18432, (
            f"FedKSeed paper-scale total communication drifted: {k1152} B "
            f"(expected exactly 18 KiB at K=1152)")
        print("# smoke OK: fedkseed K=1152 total comm = 18 KiB exactly")
        if modes and "sync" in doc.get("modes", {}) \
                and "async" in doc.get("modes", {}):
            s = doc["modes"]["sync"]["steps_per_s"]
            a = doc["modes"]["async"]["steps_per_s"]
            assert a >= 0.9 * s, (
                f"async runtime regressed: {a:.2f} steps/s vs sync "
                f"{s:.2f} steps/s (gate: ≥ 0.9×)")
            print(f"# smoke OK: async {a:.2f} steps/s ≥ 0.9× sync "
                  f"{s:.2f} steps/s")
        if args.population:
            cell = doc["population"]["100000"]
            res = cell["flat"]["max_resident_bytes"]
            assert res < POPULATION_RESIDENT_CEILING, (
                f"lazy pool resident state blew up: {res} bytes at 10⁵ "
                f"clients (ceiling {POPULATION_RESIDENT_CEILING})")
            # every cell runs the IDENTICAL per-commit workload (the
            # population only changes pool bookkeeping), so the best
            # cell's ratio is the noise-robust estimate — single cells
            # swing ±30% with machine load even under best-of timing
            ratio = max(c["hier_vs_flat"]
                        for c in doc["population"].values())
            assert ratio >= 0.8, (
                f"hierarchical runtime regressed: best {ratio:.2f}× flat "
                f"events/s across populations (gate: ≥ 0.8×)")
            print(f"# smoke OK: 10⁵-client lazy run resident={res}B "
                  f"(< {POPULATION_RESIDENT_CEILING}), hier "
                  f"{ratio:.2f}× flat events/s best-cell (≥ 0.8×)")


if __name__ == "__main__":
    main()
