"""Roofline analysis (deliverable g): derive the three roofline terms from
every dry-run artifact in experiments/dryrun/ and identify each case's
dominant bottleneck.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for the train shape;
the ratio MODEL_FLOPS / (chips·HLO_FLOPs) flags remat/redundancy waste.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import get_config
from repro.core.memory import total_param_count, layer_param_count

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

SHAPE_TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
                "decode_32k": 128, "long_500k": 1}


def active_params(cfg) -> int:
    """Activated parameters per token (MoE: shared + top-k routed)."""
    if cfg.n_experts:
        dense_like = cfg.replace(n_experts=0, n_shared_experts=0)
        attn_side = layer_param_count(dense_like) - 3 * cfg.d_model * cfg.d_ff
        expert = 3 * cfg.d_model * cfg.expert_d_ff
        per_layer = (attn_side + (cfg.top_k + cfg.n_shared_experts) * expert
                     + cfg.d_model * cfg.n_experts)
        return cfg.padded_vocab * cfg.d_model + cfg.n_layers * per_layer
    return total_param_count(cfg)


def analyze(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    chips = rec["chips"]
    flops = rec["cost"]["flops_per_chip"]
    hbytes = rec["cost"]["bytes_per_chip"]
    cbytes = rec["collectives"]["total_bytes"]
    t_compute = flops / PEAK_FLOPS
    t_memory = hbytes / HBM_BW
    t_coll = cbytes / ICI_BW
    dominant = max([("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)], key=lambda kv: kv[1])[0]
    tokens = SHAPE_TOKENS[rec["shape"]]
    n_active = active_params(cfg)
    mult = 6 if rec["shape"] == "train_4k" else 2   # fwd+bwd vs fwd-only
    model_flops = mult * n_active * tokens
    useful = model_flops / max(1.0, flops * chips)
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "chips")},
        "seq_shard": rec.get("seq_shard", False),
        "step": rec.get("step", "chain"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_flops, "useful_ratio": useful,
        "peak_gib": rec["memory"]["peak_per_chip"] / 2 ** 30,
    }


def load_records(mesh=None, step="chain", seq_shard=None, optimized=False):
    recs = []
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("skipped"):
            continue
        if mesh and r["mesh"] != mesh:
            continue
        if step and r.get("step", "chain") != step:
            continue
        if seq_shard is not None and r.get("seq_shard", False) != seq_shard:
            continue
        is_opt = bool(r.get("ssm_ckpt") or r.get("decode_align")
                      or r.get("gpo_seq"))
        if is_opt != optimized:
            continue
        recs.append(r)
    return recs


BENCH_ROUND = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_round_throughput.json"

# AdamW update chain ops per element (matches bench_round's constant)
ADAMW_FLOPS_PER_ELEM = 15


def optim_rows(path=BENCH_ROUND):
    """Fallback roofline source (ISSUE 10): when no ``experiments/dryrun/``
    artifacts exist, derive the terms from ``bench_round``'s fused-optimizer
    bytes-moved cells instead — the optimizer step has no collectives, so
    the verdict is the compute-vs-memory ratio at hardware peaks.  Every
    cell should come out memory-dominant (that is the premise of fusing the
    update chain into one pass)."""
    path = pathlib.Path(path)
    if not path.exists():
        return [], {}
    doc = json.loads(path.read_text())
    rows, table = [], {}
    for tag, rec in doc.get("fused_optim", {}).items():
        t_compute = ADAMW_FLOPS_PER_ELEM * rec["elems"] / PEAK_FLOPS
        t_memory = rec["bytes_per_step"] / HBM_BW
        a = {"cell": tag, "elems": rec["elems"],
             "bytes_per_step": rec["bytes_per_step"],
             "t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": 0.0,
             "dominant": "memory" if t_memory >= t_compute else "compute",
             "measured_s_per_step": rec["s_per_step"],
             "measured_bytes_per_s": rec["bytes_per_s"]}
        key = f"optim/{tag}"
        table[key] = a
        rows.append(
            f"roofline/{key},0,"
            f"t_comp={t_compute:.3e};t_mem={t_memory:.3e};"
            f"dom={a['dominant']};bytes_per_step={rec['bytes_per_step']};"
            f"measured_GBps={rec['bytes_per_s']/1e9:.2f}")
    return rows, table


def run(rounds=0, fast=False):
    rows, table = [], {}
    recs = [r for r in load_records(mesh="16x16", step="chain",
                                    seq_shard=False)
            if not r.get("cost_unroll")]
    if not recs:
        return optim_rows()
    cost = {(r["arch"], r["shape"]): r
            for r in load_records(mesh="16x16", step="chain", seq_shard=False)
            if r.get("cost_unroll")}
    for r in recs:
        key = (r["arch"], r["shape"])
        if key in cost:   # memory from scan compile, cost from unrolled
            r = {**r, "cost": cost[key]["cost"],
                 "collectives": cost[key]["collectives"]}
        a = analyze(r)
        key = f"{a['arch']}/{a['shape']}"
        table[key] = a
        rows.append(
            f"roofline/{key},0,"
            f"t_comp={a['t_compute_s']:.3e};t_mem={a['t_memory_s']:.3e};"
            f"t_coll={a['t_collective_s']:.3e};dom={a['dominant']};"
            f"useful={a['useful_ratio']:.3f};peak_gib={a['peak_gib']:.2f}")
    return rows, table


def markdown_table(recs):
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "dominant | useful FLOP ratio | peak GiB/chip |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        a = analyze(r)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['t_compute_s']:.2e} | {a['t_memory_s']:.2e} "
            f"| {a['t_collective_s']:.2e} | **{a['dominant']}** "
            f"| {a['useful_ratio']:.2f} | {a['peak_gib']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows, _ = run()
    print("\n".join(rows))
