"""Privacy & robustness gates (ISSUE 6 tentpole).

Three scenario checks over the privacy subsystem (``repro.fed.privacy`` /
``repro.fed.faults``), each a CI gate under ``--smoke``:

* **secure-agg equality** — a secure-aggregation run with zero dropouts must
  match plain FedAvg to fixed-point quantization precision, and the pairwise
  masks must cancel bit-exactly in the int32 field (checked directly on a
  ``SecureSession``).
* **DP smoke** — a DP-enabled chainfed run completes with finite loss and a
  growing ε, and is bit-reproducible from its seed.
* **fault tolerance** — a 20%-dropout + 10%-byzantine async run under
  trimmed-mean must complete every requested commit through the event heap
  via re-dispatch, with no recompiles inside the loop (``_cache_size``) and
  a final loss within tolerance of the clean run.

    PYTHONPATH=src python -m benchmarks.bench_privacy --smoke

Writes ``BENCH_privacy.json`` (see --out).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.config import ChainConfig, FedConfig

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_privacy.json"

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=1, lr=3e-3)
FED = FedConfig(n_clients=8, clients_per_round=4, seed=0)


def _run(method="full_adapters", rounds=4, **kw):
    from repro.fed.registry import run_experiment
    return run_experiment(method, cfg=CFG, chain=CHAIN, fed=FED,
                          rounds=rounds, eval_every=rounds, batch_size=4,
                          memory_constrained=False, **kw)


def _max_diff(a, b):
    from repro.utils.tree import tree_map
    leaves = jax.tree_util.tree_leaves(
        tree_map(lambda x, y: jnp.max(jnp.abs(x.astype(jnp.float32)
                                              - y.astype(jnp.float32))),
                 a, b))
    return float(max(jnp.stack(leaves)))


def secure_equality(rounds=3):
    """Masked aggregation ≡ plain FedAvg with zero dropouts, plus bit-exact
    mask cancellation on a toy session.  One round = one aggregation: later
    rounds re-train from the quantized weights, amplifying the ~2⁻¹⁷
    fixed-point error into trajectory divergence."""
    del rounds
    t0 = time.time()
    plain = _run(rounds=1)
    masked = _run(rounds=1, secure_agg=True)
    diff = _max_diff(plain.strategy.adapters, masked.strategy.adapters)

    # field-level check: Σ masked uploads == Σ quantized plaintext, bit-exact
    from repro.fed.privacy import SecureAggConfig, SecureSession
    sess = SecureSession(SecureAggConfig(), jax.random.PRNGKey(7), (3, 1, 4))
    trees = [{"w": jnp.asarray(np.random.default_rng(c).normal(size=(5, 3)),
                               jnp.float32)} for c in sess.cids]
    total = sess.unmask_sum([sess.mask_update(c, t)
                             for c, t in zip(sess.cids, trees)], sess.cids)
    expect = {"w": sum(sess.quantize(t)["w"] for t in trees)}
    exact = bool(jnp.all(total["w"] == expect["w"]))
    return {"max_adapter_diff": diff, "masks_cancel_bitexact": exact,
            "wall_s": time.time() - t0}


def dp_smoke(rounds=3):
    """DP-enabled chainfed: finite loss, ε > 0, reproducible from seed."""
    t0 = time.time()
    dp = {"clip": 0.5, "noise_multiplier": 1.2, "seed": 5}
    kw = dict(rounds=rounds, dp=dp, strategy_opts={"use_foat": False})
    a = _run("chainfed", **kw)
    b = _run("chainfed", **kw)
    ha, hb = a.history[-1], b.history[-1]
    return {"final_loss": ha.loss, "epsilon": ha.dp_epsilon,
            "reproducible": bool(ha.loss == hb.loss
                                 and ha.dp_epsilon == hb.dp_epsilon),
            "finite": bool(np.isfinite(ha.loss) and ha.dp_epsilon > 0),
            "wall_s": time.time() - t0}


def fault_tolerance(commits=6):
    """20%-dropout + 10%-byzantine async run under trimmed-mean: completes
    through the event heap via re-dispatch, no recompiles, loss within
    tolerance of the clean run."""
    from repro.fed.runtime import FedScheduler

    t0 = time.time()
    clean = _run(rounds=commits, mode="async")
    faulty = _run(rounds=commits, mode="async",
                  aggregator="trimmed_mean", aggregator_opts={"trim": 0.25},
                  faults={"dropout_prob": 0.2, "byzantine_frac": 0.1,
                          "seed": 3})
    # counters + compile-cache check need the scheduler itself
    from repro.fed.registry import make_strategy
    from repro.data.synthetic import (DATASETS, classification_batch,
                                      make_classification)
    from repro.fed.engine import FedSim
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    sim = FedSim(CFG, FED, tokens, labels,
                 lambda idx: classification_batch(spec, tokens, labels, idx),
                 batch_size=4, memory_constrained=False)
    strat = make_strategy("full_adapters", CFG, CHAIN, jax.random.PRNGKey(0))
    strat.aggregator, strat.aggregator_opts = "trimmed_mean", {"trim": 0.25}
    from repro.fed.faults import ClientBehavior
    sched = FedScheduler(sim, strat, mode="async",
                         faults=ClientBehavior(dropout_prob=0.2,
                                               byzantine_frac=0.1, seed=3))
    hist = sched.run(commits, eval_every=commits)
    caches = [f._cache_size() for f in strat.engine._cohort_updates.values()
              if hasattr(f, "_cache_size")]
    return {"clean_loss": clean.history[-1].loss,
            "faulty_loss": faulty.history[-1].loss,
            "commits": len(hist) and sched.version,
            "requested_commits": commits,
            "fault_dropouts": sched.fault_dropouts,
            "redispatches": sched.redispatches,
            "cohort_cache_sizes": caches,
            "wall_s": time.time() - t0}


def churn_resilience(commits=5):
    """ISSUE 7: trace-driven availability (staggered short windows with
    all-offline gaps) + 10% byzantine population under multi-Krum.  The
    async run must reach every requested commit by riding offline-cut
    timeouts, capped-backoff retry events and re-dispatch — with no
    recompiles inside the loop.  Reports completed-commit throughput and
    the churn overhead counters."""
    from repro.data.partition import AvailabilityTrace
    from repro.data.synthetic import (DATASETS, classification_batch,
                                      make_classification)
    from repro.fed.engine import FedSim
    from repro.fed.faults import ClientBehavior
    from repro.fed.registry import make_strategy
    from repro.fed.runtime import FedScheduler

    t0 = time.time()
    win = (((0.0, 0.30),), ((0.0, 0.35),), ((0.55, 0.95),),
           ((0.60, 1.00),), ((1.25, 1.60),), ((1.30, 1.65),))
    fed = FedConfig(n_clients=6, clients_per_round=3, seed=3)
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    sim = FedSim(CFG, fed, tokens, labels,
                 lambda idx: classification_batch(spec, tokens, labels, idx),
                 batch_size=4, memory_constrained=False)
    strat = make_strategy("full_adapters", CFG, CHAIN, jax.random.PRNGKey(0))
    strat.aggregator, strat.aggregator_opts = "multi_krum", {"f": 1}
    sched = FedScheduler(sim, strat, mode="async",
                         trace=AvailabilityTrace(windows=win, period=2.0),
                         faults=ClientBehavior(byzantine_frac=0.1, seed=3),
                         buffer_size=2, concurrency=2,
                         backoff_base=0.05, backoff_cap=0.4)
    hist = sched.run(commits, eval_every=commits)
    wall = time.time() - t0
    caches = [f._cache_size() for f in strat.engine._cohort_updates.values()
              if hasattr(f, "_cache_size")]
    return {"commits": sched._done, "requested_commits": commits,
            "commits_per_s": sched._done / wall,
            "virtual_wallclock": hist[-1].wallclock if hist else 0.0,
            "trace_dropouts": sched.trace_dropouts,
            "backoff_retries": sched.backoff_retries,
            "redispatches": sched.redispatches,
            "final_loss": hist[-1].loss if hist else float("nan"),
            "cohort_cache_sizes": caches, "wall_s": wall}


def run(fast: bool = False, smoke: bool = False, out_path=DEFAULT_OUT,
        churn: bool = False):
    rounds = 2 if (fast or smoke) else 4
    commits = 5 if (fast or smoke) else 8
    doc = {"backend": jax.default_backend(),
           "secure": secure_equality(rounds=rounds),
           "dp": dp_smoke(rounds=rounds),
           "faults": fault_tolerance(commits=commits)}
    if churn:
        doc["churn"] = churn_resilience(commits=5)
    rows = [
        f"privacy/secure_equality,{doc['secure']['wall_s']*1e6:.0f},"
        f"max_diff={doc['secure']['max_adapter_diff']:.2e}"
        f";bitexact={doc['secure']['masks_cancel_bitexact']}",
        f"privacy/dp_smoke,{doc['dp']['wall_s']*1e6:.0f},"
        f"eps={doc['dp']['epsilon']:.2f}"
        f";reproducible={doc['dp']['reproducible']}",
        f"privacy/fault_tolerance,{doc['faults']['wall_s']*1e6:.0f},"
        f"redispatches={doc['faults']['redispatches']}"
        f";dropouts={doc['faults']['fault_dropouts']}"
        f";faulty_loss={doc['faults']['faulty_loss']:.4f}",
    ]
    if churn:
        c = doc["churn"]
        rows.append(
            f"privacy/churn_resilience,{c['wall_s']*1e6:.0f},"
            f"commits_per_s={c['commits_per_s']:.2f}"
            f";trace_dropouts={c['trace_dropouts']}"
            f";backoff={c['backoff_retries']}"
            f";redispatches={c['redispatches']}")
    for r in rows:
        print(r, flush=True)
    pathlib.Path(out_path).write_text(json.dumps(doc, indent=2) + "\n")
    return rows, doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="assert the three gates (CI)")
    ap.add_argument("--churn", action="store_true",
                    help="add the trace-churn + byzantine multi-Krum "
                         "resilience scenario (ISSUE 7)")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args(argv)
    _, doc = run(fast=args.fast, smoke=args.smoke, out_path=args.out,
                 churn=args.churn)
    if args.smoke:
        s, d, f = doc["secure"], doc["dp"], doc["faults"]
        assert s["masks_cancel_bitexact"], "pairwise masks did not cancel"
        assert s["max_adapter_diff"] <= 1e-4, (
            f"secure-agg deviates from plain FedAvg: {s['max_adapter_diff']}")
        print("# smoke OK: secure-agg ≡ FedAvg "
              f"(max diff {s['max_adapter_diff']:.2e})")
        assert d["finite"] and d["reproducible"], f"DP gate failed: {d}"
        print(f"# smoke OK: DP run ε={d['epsilon']:.2f}, reproducible")
        assert f["commits"] == f["requested_commits"], (
            f"fault run did not complete: {f['commits']}/"
            f"{f['requested_commits']} commits")
        assert f["fault_dropouts"] > 0 and f["redispatches"] > 0, (
            f"fault injection inert: {f}")
        assert all(c == 1 for c in f["cohort_cache_sizes"]), (
            f"recompiles inside the event loop: {f['cohort_cache_sizes']}")
        assert f["faulty_loss"] <= 1.25 * f["clean_loss"] + 0.5, (
            f"byzantine not neutralized: {f['faulty_loss']} vs clean "
            f"{f['clean_loss']}")
        print(f"# smoke OK: {f['fault_dropouts']} dropouts recovered via "
              f"{f['redispatches']} re-dispatches, no recompiles")
        if args.churn:
            c = doc["churn"]
            assert c["commits"] == c["requested_commits"], (
                f"churn run did not complete: {c['commits']}/"
                f"{c['requested_commits']} commits")
            assert c["trace_dropouts"] > 0 and c["backoff_retries"] > 0, (
                f"trace churn inert: {c}")
            assert all(s == 1 for s in c["cohort_cache_sizes"]), (
                f"recompiles under churn: {c['cohort_cache_sizes']}")
            print(f"# smoke OK: churn run completed "
                  f"{c['commits']} commits at {c['commits_per_s']:.2f}/s "
                  f"({c['trace_dropouts']} trace dropouts, "
                  f"{c['backoff_retries']} backoff retries)")


if __name__ == "__main__":
    main()
