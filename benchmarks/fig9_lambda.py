"""Paper Fig. 9: GPO global-loss weight λ — λ=0 (pure local) is worst;
moderate λ best; λ=1.0 over-weights the global objective."""
from __future__ import annotations

import time

import jax

from .common import base_params, make_sim
from repro.configs import get_config
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import make_strategy
from repro.models.config import ChainConfig


def run(rounds=16, fast=False):
    cfg = get_config("bert_tiny")
    rows, table = [], {}
    sim, tokens, labels, spec = make_sim("agnews", True, cfg)
    params = base_params(cfg, tokens)
    for lam in ([0.0, 0.2] if fast else [0.0, 0.1, 0.2, 0.5, 1.0]):
        chain = ChainConfig(window=2, lam=lam, foat_threshold=0.8,
                            local_steps=2, lr=3e-3)
        strat = make_strategy("chainfed", cfg, chain, jax.random.PRNGKey(0))
        strat.params = params
        t0 = time.time()
        hist = run_sync_rounds(sim, strat, rounds, eval_every=3)
        acc = max(h.acc for h in hist)
        table[lam] = acc
        rows.append(f"fig9/lam={lam},{(time.time()-t0)/rounds*1e6:.0f},"
                    f"acc={acc:.4f}")
    return rows, table
