"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts in experiments/dryrun/.

    PYTHONPATH=src python -m benchmarks.report [--write]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from .bench_privacy import DEFAULT_OUT as PRIVACY_JSON
from .bench_round import DEFAULT_OUT as ROUND_JSON
from .bench_serve import DEFAULT_OUT as SERVE_JSON
from .roofline import DRYRUN, PEAK_FLOPS, HBM_BW, ICI_BW, analyze

ORDER = ["gemma_2b", "olmoe_1b_7b", "deepseek_67b", "qwen2_0_5b",
         "deepseek_moe_16b", "hymba_1_5b", "qwen2_1_5b", "falcon_mamba_7b",
         "seamless_m4t_large_v2", "qwen2_vl_72b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh, cost_unroll, step="chain", seq_shard=False, ssm_ckpt=False,
         decode_align=False):
    out = {}
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("skipped"):
            continue
        if (r["mesh"] != mesh or r.get("step", "chain") != step
                or r.get("seq_shard", False) != seq_shard
                or bool(r.get("cost_unroll", False)) != cost_unroll
                or bool(r.get("ssm_ckpt", False)) != ssm_ckpt
                or bool(r.get("gpo_seq", False))
                or bool(r.get("decode_align", False)) != decode_align):
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def merged_records(mesh="16x16", step="chain", seq_shard=False):
    """Memory from the scan compile, cost/collectives from the unrolled one."""
    mem = load(mesh, False, step, seq_shard)
    cost = load(mesh, True, step, seq_shard)
    recs = []
    for k, r in mem.items():
        m = dict(r)
        if k in cost:
            m["cost"] = cost[k]["cost"]
            m["collectives"] = cost[k]["collectives"]
            m["cost_source"] = "unrolled"
        else:
            m["cost_source"] = "scan (while bodies counted once — lower bound)"
        recs.append(m)
    recs.sort(key=lambda r: (ORDER.index(r["arch"]), SHAPES.index(r["shape"])))
    return recs


def dryrun_table(recs):
    lines = ["| arch | shape | mesh | compile s | args GiB | temp GiB | "
             "peak GiB/chip | collective MiB/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {m['argument_bytes']/2**30:.2f} | {m['temp_bytes']/2**30:.2f} "
            f"| {m['peak_per_chip']/2**30:.2f} "
            f"| {r['collectives']['total_bytes']/2**20:.0f} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful-FLOP ratio | peak GiB | next lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        a = analyze(r)
        lever = suggest_lever(a)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.2e} "
            f"| {a['t_memory_s']:.2e} | {a['t_collective_s']:.2e} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['peak_gib']:.1f} | {lever} |")
    return "\n".join(lines)


def suggest_lever(a):
    if a["dominant"] == "collective":
        return ("shard attention heads / overlap FedAvg all-reduce; "
                "reduce per-layer all-gathers")
    if a["dominant"] == "memory":
        if a["shape"].startswith("decode"):
            return "cut cache rewrite traffic (DUS sharding), quantize cache"
        return "sequence-parallel residual; tighter remat"
    return "increase per-chip batch; fuse adapter chain"


def round_throughput_table(path=ROUND_JSON):
    """§Round-throughput table from BENCH_round_throughput.json (written by
    ``benchmarks.bench_round``); None when the artifact is absent."""
    path = pathlib.Path(path)
    if not path.exists():
        return None
    doc = json.loads(path.read_text())
    lines = [f"backend: {doc.get('backend', '?')}, "
             f"mode: {doc.get('mode', '?')}", "",
             "| workload | strategy | legacy rounds/s | cohort rounds/s | "
             "cohort steps/s | speedup |",
             "|---|---|---|---|---|---|"]
    for r in doc.get("results", []):
        lines.append(
            f"| {r['arch']} | {r['strategy']} "
            f"| {r['legacy']['rounds_per_s']:.2f} "
            f"| {r['cohort']['rounds_per_s']:.2f} "
            f"| {r['cohort']['steps_per_s']:.2f} "
            f"| {r['speedup']:.2f}× |")
    return "\n".join(lines)


def fused_optim_table(path=ROUND_JSON):
    """§Fused-optimizer table from the ``fused_optim`` section of
    BENCH_round_throughput.json (ISSUE 10): the three cohort-microbench
    cells with their analytic bytes-moved and resident optimizer state;
    None when absent (pre-ISSUE-10 artifacts)."""
    path = pathlib.Path(path)
    if not path.exists():
        return None
    doc = json.loads(path.read_text())
    fo = doc.get("fused_optim")
    if not fo:
        return None
    lines = ["| cell | ms/step | steps/s | vs unfused | bytes/step | "
             "opt state B/client |",
             "|---|---|---|---|---|---|"]
    for tag, r in fo.items():
        sp = (f"{r['speedup_vs_unfused']:.2f}×"
              if "speedup_vs_unfused" in r else "—")
        lines.append(
            f"| {tag} | {r['s_per_step'] * 1e3:.1f} "
            f"| {r['steps_per_s']:.2f} | {sp} "
            f"| {r['bytes_per_step']:,} "
            f"| {r['opt_state_bytes_per_client']:,} |")
    comm = doc.get("comm")
    if comm:
        lines += ["", "Uplink bytes per client per round "
                  "(fedkseed_paper_k1152_total is up+down, pinned to "
                  "18 KiB):", "",
                  "| payload | bytes |", "|---|---|"]
        lines += [f"| {tag} | {b:,} |" for tag, b in comm.items()]
    return "\n".join(lines)


def scheduler_modes_table(path=ROUND_JSON):
    """§Scheduler-modes tables from the ``modes`` section of
    BENCH_round_throughput.json (written by ``benchmarks.bench_round
    --modes ...``): a per-mode throughput summary plus the
    wall-clock-vs-accuracy trajectory that makes async/semisync runs
    comparable to sync on the virtual clock; None when absent."""
    path = pathlib.Path(path)
    if not path.exists():
        return None
    doc = json.loads(path.read_text())
    modes = doc.get("modes")
    if not modes:
        return None
    sync_sps = modes.get("sync", {}).get("steps_per_s")
    lines = ["| mode | s/commit | steps/s | vs sync | virtual wallclock s | "
             "stale updates |",
             "|---|---|---|---|---|---|"]
    for mode, r in modes.items():
        rel = (f"{r['steps_per_s'] / sync_sps:.2f}×"
               if sync_sps else "—")
        lines.append(
            f"| {mode} | {r['s_per_commit'] * 1e3:.1f}ms "
            f"| {r['steps_per_s']:.2f} | {rel} "
            f"| {r['virtual_wallclock_s']:.1f} | {r['stale_updates']} |")
    lines += ["", "Wall-clock vs accuracy (virtual seconds → eval accuracy):",
              "",
              "| mode | " + " | ".join(
                  f"eval {i}" for i in range(max(
                      len(r.get("history", [])) for r in modes.values()))) +
              " |",
              "|---|" + "---|" * max(len(r.get("history", []))
                                     for r in modes.values())]
    for mode, r in modes.items():
        cells = [f"{h['wallclock']:.1f}s → {h['acc']:.3f}"
                 for h in r.get("history", [])]
        lines.append(f"| {mode} | " + " | ".join(cells) + " |")
    return "\n".join(lines)


def population_table(path=ROUND_JSON):
    """§Population-scaling table from the ``population`` section of
    BENCH_round_throughput.json (written by ``benchmarks.bench_round
    --population``): events/s and resident client-state bytes of the lazy
    pool, flat vs hierarchical, as the population grows 10³ → 10⁶; None
    when absent."""
    path = pathlib.Path(path)
    if not path.exists():
        return None
    doc = json.loads(path.read_text())
    pop = doc.get("population")
    if not pop:
        return None
    lines = ["| population | flat events/s | hier events/s (silos) | "
             "hier/flat | max resident clients | max resident bytes |",
             "|---|---|---|---|---|---|"]
    for n in sorted(pop, key=int):
        r = pop[n]
        lines.append(
            f"| {int(n):,} | {r['flat']['events_per_s']:.1f} "
            f"| {r['hier']['events_per_s']:.1f} "
            f"({r['hier']['n_silos']}) "
            f"| {r['hier_vs_flat']:.2f}× "
            f"| {r['flat']['max_resident']} "
            f"| {r['flat']['max_resident_bytes']:,} |")
    return "\n".join(lines)


def serve_throughput_table(path=SERVE_JSON):
    """§Serve-throughput table from BENCH_serve_throughput.json (written by
    ``benchmarks.bench_serve``); None when the artifact is absent."""
    path = pathlib.Path(path)
    if not path.exists():
        return None
    doc = json.loads(path.read_text())
    lines = [f"backend: {doc.get('backend', '?')}, "
             f"mode: {doc.get('mode', '?')}, "
             f"gates: mixed ≥ {doc.get('gate_mixed_over_single', '?')}× "
             f"single, paged ≥ {doc.get('gate_paged_over_dense', '?')}× "
             f"dense, long-tail KV shrink ≥ "
             f"{doc.get('gate_long_tail_footprint', '?')}×",
             "",
             "| workload | batch | tenants | single tok/s | mixed tok/s | "
             "mixed/single | continuous tok/s | paged tok/s | paged/dense |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in doc.get("results", []):
        pg = r.get("paged", {})
        lines.append(
            f"| {r['arch']} | {r['batch']} | {r['n_tenants']} "
            f"| {r['single']['tokens_per_s']:.1f} "
            f"| {r['mixed']['tokens_per_s']:.1f} "
            f"| {r['ratio']:.2f}× "
            f"| {r['continuous']['tokens_per_s']:.1f} "
            f"| {pg.get('tokens_per_s', float('nan')):.1f} "
            f"| {pg.get('ratio_vs_dense', float('nan')):.2f}× |")
    if any("long_tail" in r for r in doc.get("results", [])):
        lines += ["", "Paged KV footprint (long-tail mix) and tenant "
                  "library (LRU resident set):", "",
                  "| workload | dense KV B/token | paged KV B/token | "
                  "KV shrink | peak pages | tenants (T/R) | LRU hit rate | "
                  "evictions |",
                  "|---|---|---|---|---|---|---|---|"]
        for r in doc.get("results", []):
            lt, tn = r.get("long_tail", {}), r.get("tenancy", {})
            if not lt:
                continue
            lines.append(
                f"| {r['arch']} "
                f"| {lt['dense_kv_bytes_per_token']:.0f} "
                f"| {lt['paged_kv_bytes_per_token']:.0f} "
                f"| {lt['footprint_ratio']:.1f}× "
                f"| {lt['peak_pages']} "
                f"| {tn.get('tenants', '?')}/{tn.get('resident', '?')} "
                f"| {tn.get('hit_rate', float('nan')):.2f} "
                f"| {tn.get('evictions', '?')} |")
    return "\n".join(lines)


def privacy_table(path=PRIVACY_JSON):
    """§Privacy-and-robustness table from BENCH_privacy.json (written by
    ``benchmarks.bench_privacy``); None when the artifact is absent."""
    path = pathlib.Path(path)
    if not path.exists():
        return None
    doc = json.loads(path.read_text())
    s, d, f = doc.get("secure", {}), doc.get("dp", {}), doc.get("faults", {})
    lines = [f"backend: {doc.get('backend', '?')}", "",
             "| gate | result |",
             "|---|---|",
             f"| secure-agg vs FedAvg (zero dropouts) | max diff "
             f"{s.get('max_adapter_diff', float('nan')):.2e}, masks cancel "
             f"bit-exactly: {s.get('masks_cancel_bitexact', '?')} |",
             f"| DP chainfed smoke | ε = {d.get('epsilon', float('nan')):.2f}"
             f", final loss {d.get('final_loss', float('nan')):.4f}, "
             f"seed-reproducible: {d.get('reproducible', '?')} |",
             f"| fault injection (20% drop + 10% byz, trimmed-mean) | "
             f"{f.get('commits', '?')}/{f.get('requested_commits', '?')} "
             f"commits, {f.get('fault_dropouts', '?')} dropouts recovered "
             f"via {f.get('redispatches', '?')} re-dispatches, loss "
             f"{f.get('faulty_loss', float('nan')):.4f} "
             f"(clean {f.get('clean_loss', float('nan')):.4f}) |"]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--seq-shard", action="store_true")
    args = ap.parse_args()
    recs = merged_records(mesh=args.mesh, seq_shard=args.seq_shard)
    print(f"## §Dry-run ({args.mesh})\n")
    print(dryrun_table(recs))
    print(f"\n## §Roofline ({args.mesh})\n")
    print(roofline_table(recs))
    rt = round_throughput_table()
    if rt is not None:
        print("\n## §Round throughput (single host)\n")
        print(rt)
    ft = fused_optim_table()
    if ft is not None:
        print("\n## §Fused optimizer & communication ladder\n")
        print(ft)
    mt = scheduler_modes_table()
    if mt is not None:
        print("\n## §Scheduler modes (event-driven runtime, virtual clock)\n")
        print(mt)
    pop = population_table()
    if pop is not None:
        print("\n## §Population scaling (lazy pool, flat vs hierarchical)\n")
        print(pop)
    st = serve_throughput_table()
    if st is not None:
        print("\n## §Serve throughput (single host)\n")
        print(st)
    pt = privacy_table()
    if pt is not None:
        print("\n## §Privacy & robustness gates\n")
        print(pt)


if __name__ == "__main__":
    main()
