"""Paper Table 3: instruction tuning with varying co-tuning window Q —
CHAINFED vs Full Adapters† on the causal-LM task, with memory reduction.

Claims validated: CHAINFED matches/exceeds the upper bound at a multiple
lower peak memory; larger Q trades memory for accuracy.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from .common import csv_row
from repro.configs import get_config
from repro.core.memory import peak_memory
from repro.data.synthetic import lm_batch, make_instruction
from repro.fed.engine import FedSim
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import make_strategy
from repro.models.config import ChainConfig, FedConfig
from repro.train.pretrain import pretrained_base


def run(rounds=24, fast=False):
    cfg = get_config("llama_100m").replace(
        n_layers=8, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=4096)
    # pretrain on mapping 0; the federated task carries NEW associations
    # (mapping 1) that adapters must memorize — instruction-tuning semantics
    pt_tokens, _ = make_instruction(n_samples=2048, seq_len=32,
                                    vocab=cfg.vocab_size, n_keys=32,
                                    mapping_seed=0)
    tokens, labels2d = make_instruction(n_samples=2048, seq_len=32,
                                        vocab=cfg.vocab_size, n_keys=32,
                                        seed=8, mapping_seed=1)
    labels = np.zeros(len(tokens), np.int64)
    fed = FedConfig(n_clients=10, clients_per_round=4, iid=True)
    batch_fn = lambda idx: lm_batch(tokens, labels2d, idx)
    sim = FedSim(cfg, fed, tokens, labels, batch_fn, batch_size=16,
                 memory_constrained=False)
    params = pretrained_base(cfg, pt_tokens, steps=300)
    chain0 = ChainConfig(window=3, lam=0.2, local_steps=2, lr=3e-3,
                         optimizer="adamw", train_head=True)

    rows, table = [], {}
    # upper bound
    fa = make_strategy("full_adapters", cfg, chain0, jax.random.PRNGKey(0))
    fa.params = params
    t0 = time.time()
    hist = run_sync_rounds(sim, fa, rounds, eval_every=3)
    fa_acc = max(h.acc for h in hist)
    fa_mem = peak_memory(cfg, "full_adapters", 16, 32)["total"]
    table["full_adapters"] = {"acc": fa_acc, "mem_red": 1.0}
    rows.append(f"table3/full_adapters,{(time.time()-t0)/rounds*1e6:.0f},"
                f"acc={fa_acc:.4f};mem_red=1.0")

    for Q in ([3] if fast else [2, 3, 4]):
        chain = dataclasses.replace(chain0, window=Q)
        strat = make_strategy("chainfed", cfg, chain, jax.random.PRNGKey(0))
        strat.params = params
        t0 = time.time()
        hist = run_sync_rounds(sim, strat, rounds, eval_every=3)
        acc = max(h.acc for h in hist)
        mem = peak_memory(cfg, "chainfed", 16, 32, window=Q,
                          l_start=strat.l_start)["total"]
        red = fa_mem / mem
        table[f"Q={Q}"] = {"acc": acc, "mem_red": red}
        rows.append(f"table3/chainfed_Q{Q},{(time.time()-t0)/rounds*1e6:.0f},"
                    f"acc={acc:.4f};mem_red={red:.2f}")
    return rows, table
