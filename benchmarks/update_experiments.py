"""Splice the generated roofline table and paper-validation summary into
EXPERIMENTS.md (between the <!-- ROOFLINE_TABLE --> / <!-- PAPER_TABLE -->
markers).

    PYTHONPATH=src python -m benchmarks.update_experiments
"""
from __future__ import annotations

import pathlib
import re

from .report import merged_records, roofline_table

ROOT = pathlib.Path(__file__).resolve().parents[1]


def parse_bench_output(path):
    """bench_output.txt CSV -> {table: {name: derived-dict}}."""
    out = {}
    if not path.exists():
        return out
    for line in path.read_text().splitlines():
        if "," not in line or line.startswith(("name,", "#")):
            continue
        name, _, derived = line.split(",", 2)
        d = {}
        for kv in derived.split(";"):
            if "=" in kv:
                k, v = kv.split("=", 1)
                try:
                    d[k] = float(v)
                except ValueError:
                    d[k] = v
        out[name] = d
    return out


def paper_summary(rows):
    """Render the claims-validation checklist from bench rows."""
    g = lambda n: rows.get(n, {})
    lines = ["| paper claim | our measurement | verdict |", "|---|---|---|"]

    def acc(name):
        return g(name).get("acc", g(name).get("acc_iid"))

    def add(claim, measure, ok):
        lines.append(f"| {claim} | {measure} | "
                     f"{'✅ reproduced' if ok else '❌ not reproduced'} |")

    # Table 1 ordering
    for ds in ("yelp_p", "agnews"):
        for dist in ("iid", "noniid"):
            cf = acc(f"table1/{ds}/{dist}/chainfed")
            if cf is None:
                continue
            base_accs = {m: acc(f"table1/{ds}/{dist}/{m}")
                         for m in ("no_ft", "linear_probing", "fedadapter",
                                   "c2a", "fwdllm", "fedkseed", "flora",
                                   "fedra", "full_adapters")}
            base_accs = {k: v for k, v in base_accs.items() if v is not None}
            beats = [m for m, v in base_accs.items() if cf >= v - 1e-9]
            add(f"Table 1 {ds}/{dist}: CHAINFED ≥ all baselines",
                f"chainfed {cf:.3f} vs max-baseline "
                f"{max(base_accs.values()):.3f} (beats {len(beats)}/"
                f"{len(base_accs)})",
                len(beats) == len(base_accs))
    # Table 2: T=0.8 beats T=1.0
    for ds in ("yelp_p", "agnews"):
        a08 = g(f"table2/{ds}/T=0.8").get("acc_iid")
        a10 = g(f"table2/{ds}/T=1.0").get("acc_iid")
        if a08 is not None and a10 is not None:
            sp = g(f"table2/{ds}/T=0.8").get("speedup", 1)
            c08 = g(f"table2/{ds}/T=0.8").get("comm", 0)
            c10 = g(f"table2/{ds}/T=1.0").get("comm", 1)
            add(f"Table 2 {ds}: T=0.8 > T=1.0, faster + less comm",
                f"{a08:.3f} vs {a10:.3f}, speedup ×{sp:.2f}, "
                f"comm ×{c10/max(1,c08):.2f} less",
                a08 >= a10)
    # Table 3: chainfed ≥ upper bound at lower memory
    fa3 = g("table3/full_adapters").get("acc")
    if fa3 is not None:
        for Q in (2, 3, 4):
            r = g(f"table3/chainfed_Q{Q}")
            if r:
                add(f"Table 3 Q={Q}: CHAINFED ≥ Full-Adapters† @ less memory",
                    f"{r.get('acc',0):.3f} vs {fa3:.3f}, mem ×{r.get('mem_red',0):.2f} less",
                    r.get("acc", 0) >= fa3 - 0.02 and r.get("mem_red", 0) > 1)
    # Table 4 ablations
    for ds in ("yelp_p", "agnews"):
        full = g(f"table4/{ds}/iid/chainfed").get("acc")
        if full is None:
            continue
        drops = {v: g(f"table4/{ds}/iid/{v}").get("acc")
                 for v in ("wo_dlct", "wo_gpo", "wo_foat")}
        drops = {k: v for k, v in drops.items() if v is not None}
        add(f"Table 4 {ds}: removing DLCT/GPO/FOAT hurts",
            f"full {full:.3f} vs " + ", ".join(f"{k} {v:.3f}"
                                               for k, v in drops.items()),
            all(v <= full + 1e-9 for v in drops.values()))
    # Fig 8: Q↑ -> acc↑, mem↑
    q_rows = {int(n.split("=")[1]): g(n) for n in rows if n.startswith("fig8/")}
    if len(q_rows) >= 3:
        qs = sorted(q_rows)
        mem_mono = all(q_rows[a]["peak_mem"] < q_rows[b]["peak_mem"]
                       for a, b in zip(qs, qs[1:]))
        acc_trend = q_rows[qs[-1]]["acc"] >= q_rows[qs[0]]["acc"]
        add("Fig 8: larger Q → better acc, more memory",
            "; ".join(f"Q={q}: acc {q_rows[q]['acc']:.3f}, "
                      f"mem {q_rows[q]['peak_mem']/2**20:.0f} MiB" for q in qs),
            mem_mono and acc_trend)
    # Fig 9: lam=0 worst, 1.0 < best
    lam_rows = {float(n.split("=")[1]): g(n)["acc"] for n in rows
                if n.startswith("fig9/")}
    if len(lam_rows) >= 3:
        best = max(lam_rows.values())
        ok = (lam_rows.get(0.0, 1) <= best
              and lam_rows.get(0.0, 1) <= lam_rows.get(0.2, 0) + 1e-9)
        add("Fig 9: λ=0 (pure local) is worst; moderate λ best",
            "; ".join(f"λ={k}: {v:.3f}" for k, v in sorted(lam_rows.items())),
            ok)
    # Fig 3: parameter dominance
    for arch in ("deepseek_67b",):
        r = g(f"fig3/{arch}")
        if r:
            add("Fig 3: base params dominate memory (paper: 91.2%→94.1%)",
                f"{arch}: params {100*r['params_frac']:.1f}%, "
                f"acts {100*r['act_frac']:.1f}%, adapters "
                f"{100*r['adapter_frac']:.1f}%",
                r["params_frac"] > 0.85)
    return "\n".join(lines)


def splice(text, marker, payload):
    pat = re.compile(rf"<!-- {marker} -->.*?(?=\n## |\Z)", re.S)
    repl = f"<!-- {marker} -->\n\n{payload}\n"
    if pat.search(text):
        return pat.sub(repl, text)
    return text


def main():
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    recs = merged_records(mesh="16x16")
    table = roofline_table(recs)
    # mark scan-mode (lower-bound) rows
    out_lines = []
    for line, r in zip(table.splitlines()[2:], recs):
        if r.get("cost_source") != "unrolled":
            line = line.replace(f"| {r['arch']} |", f"| {r['arch']}·scan |", 1)
        out_lines.append(line)
    table = "\n".join(table.splitlines()[:2] + out_lines)
    text = splice(text, "ROOFLINE_TABLE", table)
    rows = parse_bench_output(ROOT / "bench_output.txt")
    if rows:
        text = splice(text, "PAPER_TABLE", paper_summary(rows))
    exp.write_text(text)
    print("EXPERIMENTS.md updated;", len(recs), "roofline rows,",
          len(rows), "bench rows")


if __name__ == "__main__":
    main()
