"""Paper Fig. 8: co-tuning window size Q — accuracy rises with Q, peak
memory rises proportionally (the Q ↔ memory trade-off)."""
from __future__ import annotations

import time

import jax

from .common import base_params, make_sim
from repro.configs import get_config
from repro.core.memory import peak_memory
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import make_strategy
from repro.models.config import ChainConfig


def run(rounds=16, fast=False):
    cfg = get_config("bert_tiny")
    rows, table = [], {}
    sim, tokens, labels, spec = make_sim("agnews", True, cfg)
    params = base_params(cfg, tokens)
    for Q in ([2, 4] if fast else [1, 2, 3, 4, 5]):
        chain = ChainConfig(window=Q, lam=0.2, foat_threshold=0.8,
                            local_steps=2, lr=3e-3)
        strat = make_strategy("chainfed", cfg, chain, jax.random.PRNGKey(0))
        strat.params = params
        t0 = time.time()
        hist = run_sync_rounds(sim, strat, rounds, eval_every=3)
        acc = max(h.acc for h in hist)
        mem = peak_memory(cfg, "chainfed", 8, spec.seq_len, window=Q,
                          l_start=strat.l_start)["total"]
        table[Q] = {"acc": acc, "mem": mem}
        rows.append(f"fig8/Q={Q},{(time.time()-t0)/rounds*1e6:.0f},"
                    f"acc={acc:.4f};peak_mem={mem}")
    return rows, table
