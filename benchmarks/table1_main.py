"""Paper Table 1: CHAINFED vs lower bound (No-FT), memory-unaware methods
(Linear Probing, FedAdapter, C2A), memory-aware methods (FwdLLM, FedKSeed,
FLoRA, FedRA, layerwise pruning/dropout — Wu et al. arXiv:2508.17209, Wang
et al. arXiv:2503.10217) and the idealized upper bound (Full Adapters†), on
text classification, IID + non-IID, under the memory wall.

Claim validated: CHAINFED orders above every baseline (incl. the upper bound)
because the memory wall excludes clients from memory-hungry methods while
CHAINFED recruits everyone and tunes selectively.
"""
from __future__ import annotations

from .common import Result, base_params, csv_row, make_sim, run_method
from repro.configs import get_config
from repro.models.config import ChainConfig

DATASETS_USED = ["yelp_p", "agnews"]
METHODS = ["no_ft", "linear_probing", "fedadapter", "c2a", "fwdllm",
           "fedkseed", "flora", "fedra", "layer_pruning", "layer_dropout",
           "chainfed", "full_adapters"]


def run(rounds=16, fast=False):
    cfg = get_config("bert_tiny")
    chain = ChainConfig(window=3, lam=0.2, foat_threshold=0.8, local_steps=2,
                        lr=3e-3, optimizer="adamw")
    methods = METHODS if not fast else ["no_ft", "linear_probing", "fwdllm",
                                        "chainfed", "full_adapters"]
    datasets = DATASETS_USED if not fast else ["agnews"]
    rows, table = [], {}
    for ds in datasets:
        for iid in (True, False):
            sim, tokens, labels, spec = make_sim(ds, iid, cfg)
            params = base_params(cfg, tokens)
            for m in methods:
                # Full Adapters† is the *idealized* bound: no memory wall
                sim.memory_constrained = (m != "full_adapters")
                r = run_method(m, cfg, chain, sim, params, rounds=rounds)
                key = f"{ds}/{'iid' if iid else 'noniid'}"
                table[(m, key)] = r.acc
                rows.append(csv_row(f"table1/{key}", r))
    return rows, table
