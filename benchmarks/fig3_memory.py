"""Paper Fig. 3 / §3.2: memory breakdown of adapter-based fine-tuning for
LLaMA-class configs — parameters dominate (>90%), activations and adapter
state are secondary.  Analytic (core/memory.py), validated against the
paper's reported fractions."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.memory import peak_memory


def run(rounds=0, fast=False):
    rows, table = [], {}
    for arch, batch, seq in [("qwen2_1_5b", 8, 256), ("deepseek_67b", 8, 256),
                             ("falcon_mamba_7b", 8, 256)]:
        cfg = get_config(arch)
        m = peak_memory(cfg, "full_adapters", batch, seq)
        total = m["total"]
        fr = {k: m[k] / total for k in ("params", "activations", "adapter_state")}
        table[arch] = fr
        rows.append(f"fig3/{arch},0,"
                    f"params_frac={fr['params']:.3f};"
                    f"act_frac={fr['activations']:.3f};"
                    f"adapter_frac={fr['adapter_state']:.3f};"
                    f"total_gb={total/2**30:.1f}")
    return rows, table
