"""Quickstart: CHAINFED in ~40 lines of public API.

Fine-tunes a tiny BERT-class model on a synthetic 4-class task with the full
paper protocol — FOAT boundary selection, DLCT sliding-window co-tuning, GPO
dual loss, federated aggregation — and prints the accuracy trajectory.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.data.synthetic import DATASETS, classification_batch, make_classification
from repro.fed.engine import FedSim
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import make_strategy
from repro.models.config import ChainConfig, FedConfig


def main():
    cfg = get_config("bert_tiny")
    chain = ChainConfig(window=2, lam=0.2, foat_threshold=0.8,
                        local_steps=2, lr=3e-3, optimizer="adamw")
    fed = FedConfig(n_clients=12, clients_per_round=4, iid=False,
                    dirichlet_alpha=1.0)

    spec = DATASETS["agnews"]
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: classification_batch(spec, tokens, labels, idx)
    sim = FedSim(cfg, fed, tokens, labels, batch_fn, batch_size=8)

    strat = make_strategy("chainfed", cfg, chain, jax.random.PRNGKey(0))
    # stand-in for a pretrained checkpoint: label-free LM pretraining on the
    # corpus bodies (the paper fine-tunes pretrained BERT/LLaMA backbones)
    from repro.train.pretrain import pretrained_base
    strat.params = pretrained_base(cfg, tokens, steps=300, verbose=True)
    strat.maybe_setup_foat(sim)
    print(f"FOAT picked L_start = {strat.l_start} "
          f"(threshold T = {chain.foat_threshold})")
    print(f"DLCT schedule: offsets {strat.schedule.offsets}, "
          f"window Q = {chain.window}")

    hist = run_sync_rounds(sim, strat, rounds=20, eval_every=4, verbose=True)
    print(f"\nfinal accuracy: {hist[-1].acc:.3f} "
          f"(comm {hist[-1].comm_bytes / 1024:.0f} KiB/round/client)")


if __name__ == "__main__":
    main()
