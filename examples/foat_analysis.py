"""FOAT layer-function analysis (paper §4.4, Fig. 7): per-layer CKA of
representations vs the initial embedding, aggregated across simulated
clients, and the resulting chain entry point for several thresholds.

    PYTHONPATH=src python examples/foat_analysis.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import foat
from repro.data.synthetic import DATASETS, classification_batch, make_classification
from repro.models import transformer as T


def main():
    cfg = get_config("bert_tiny")
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    adapters = T.init_adapters(key, cfg)

    spec = DATASETS["yahoo"]
    tokens, labels = make_classification(spec)
    batches = []
    for c in range(6):   # six clients, one local mini-batch each (Fig. 7)
        idx = jnp.arange(c * 32, (c + 1) * 32)
        b = classification_batch(spec, tokens, labels, idx)
        batches.append({k: jnp.asarray(v) for k, v in b.items()})

    scores_per_client = []
    for b in batches:
        outs = T.collect_layer_outputs(params, adapters, b, cfg)
        scores_per_client.append(foat.foat_scores(outs))
    agg = foat.aggregate_scores(scores_per_client)

    print("layer | aggregated CKA(Z_i, Z_0)")
    for i, s in enumerate(agg):
        bar = "#" * int(40 * float(s))
        print(f"  {i:3d} | {float(s):.4f} {bar}")
    for T_ in (1.0, 0.9, 0.8):
        print(f"threshold T={T_}: chain starts at layer "
              f"{foat.select_start_layer(agg, T_)}")


if __name__ == "__main__":
    main()
