"""End-to-end driver (deliverable b): federated instruction tuning of the
~100M-parameter LLaMA-class model for a few hundred local steps.

Mirrors the paper's §5.7 protocol at CPU scale: Alpaca-style next-token
supervision (synthetic key-value recall corpus), AdamW, 10% client
participation, CHAINFED chain optimization vs the Full Adapters† upper
bound — and reports accuracy + analytic peak memory for both.

    PYTHONPATH=src python examples/federated_instruction_tuning.py           # fast preset
    PYTHONPATH=src python examples/federated_instruction_tuning.py --full    # ~100M, hundreds of steps
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.memory import peak_memory
from repro.data.synthetic import lm_batch, make_instruction
from repro.fed.engine import FedSim
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import make_strategy
from repro.models.config import ChainConfig, FedConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 40 rounds x 4 clients x 2 steps")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config("llama_100m")
    if not args.full:   # fast preset for CI-style runs
        cfg = cfg.replace(n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
                          d_ff=512, vocab_size=4096)
    rounds = args.rounds or (40 if args.full else 12)

    chain = ChainConfig(window=3, lam=0.2, foat_threshold=0.8,
                        local_steps=2, lr=1e-3, optimizer="adamw")
    fed = FedConfig(n_clients=20, clients_per_round=2, iid=True)  # 10% of 20

    pt_tokens, _ = make_instruction(n_samples=2048, seq_len=32,
                                    vocab=cfg.vocab_size, n_keys=32,
                                    mapping_seed=0)
    tokens, labels2d = make_instruction(n_samples=2048, seq_len=32,
                                        vocab=cfg.vocab_size, n_keys=32,
                                        seed=8, mapping_seed=1)
    labels = np.zeros(len(tokens), np.int64)
    batch_fn = lambda idx: lm_batch(tokens, labels2d, idx)
    sim = FedSim(cfg, fed, tokens, labels, batch_fn, batch_size=16,
                 memory_constrained=False)

    from repro.train.pretrain import pretrained_base
    base = pretrained_base(cfg, pt_tokens, steps=400 if args.full else 200)

    results = {}
    for name in ("chainfed", "full_adapters"):
        t0 = time.time()
        strat = make_strategy(name, cfg, chain, jax.random.PRNGKey(0))
        strat.params = base
        hist = run_sync_rounds(sim, strat, rounds, eval_every=max(2, rounds // 5),
                          verbose=True)
        mem = peak_memory(cfg, "chainfed" if name == "chainfed" else "full_adapters",
                          batch=16, seq=32, window=chain.window)
        results[name] = (hist[-1].acc, mem["total"], time.time() - t0)
        print(f"[{name}] acc={hist[-1].acc:.3f} "
              f"peak-mem={mem['total']/2**20:.0f} MiB  ({results[name][2]:.0f}s)")

    cf, fa = results["chainfed"], results["full_adapters"]
    print(f"\nmemory reduction: ×{fa[1] / cf[1]:.2f}   "
          f"accuracy delta: {cf[0] - fa[0]:+.3f} (paper: CHAINFED ≥ upper bound)")


if __name__ == "__main__":
    main()
