"""Event-driven federation across scheduler modes (ISSUE 5).

Runs the same fwdllm experiment — with memory-stratified per-tier
perturbation budgets — under the three scheduler modes and prints each
trajectory on the *virtual* wall clock, the axis on which straggler-aware
scheduling actually pays: sync waits for the slowest sampled device every
round, semisync cuts it off at a deadline quantile, async never waits at
all (staleness-discounted buffered commits).

    PYTHONPATH=src python -m examples.async_federation
"""
from repro.fed.registry import run_experiment


def main():
    common = dict(
        arch="bert_tiny", dataset="agnews", rounds=16, eval_every=4,
        batch_size=4, seed=0,
        # per-tier n_samples: the runtime buckets each tier into its own
        # compiled step — big devices draw more perturbation directions
        strategy_opts={"samples_by_tier": {"low": 2, "mid": 4, "high": 8}},
    )
    runs = [
        ("sync", None),
        ("semisync", {"deadline_quantile": 0.6, "straggler": "carry"}),
        ("async", {"buffer_size": 2}),
    ]
    for mode, opts in runs:
        res = run_experiment("fwdllm", mode=mode, scheduler_opts=opts,
                             **common)
        print(f"\n== fwdllm / {mode}"
              + (f" {opts}" if opts else ""))
        for m in res.history:
            print(f"  commit {m.round:3d}  virtual {m.wallclock:8.1f}s  "
                  f"acc={m.acc:.4f}  n={m.n_participants}  "
                  f"stale={m.stale_updates}")
    print("\nsync pays the slowest device every round; semisync/async reach "
          "the same commit count in less virtual time.")


if __name__ == "__main__":
    main()
