"""Batched serving demo (deliverable b): prefill + KV-cached greedy decode
for three architecture families — dense (GQA), SSM (Mamba state), and MoE —
verifying the incremental path against the full forward.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models import transformer as T


def run(arch, batch=4, prompt_len=12, gen=12):
    cfg = get_smoke_config(arch)
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=None)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(1)
    params = T.init_lm(key, cfg)
    adapters = T.init_adapters(key, cfg)
    prompts = jax.random.randint(key, (batch, prompt_len), 4, cfg.vocab_size)

    t0 = time.time()
    toks = generate(params, adapters, cfg, prompts, gen)
    dt = time.time() - t0

    # verify the first generated token against the non-cached forward
    full, _ = T.forward_full(params, adapters, {"tokens": prompts}, cfg,
                             remat=False)
    expect = jnp.argmax(full[:, -1], axis=-1)
    ok = bool(jnp.all(toks[:, 0] == expect))
    print(f"{arch:20s} {batch}×({prompt_len}+{gen})  {batch*gen/dt:6.1f} tok/s  "
          f"cache-vs-full first-token match: {ok}")
    assert ok, arch


def main():
    for arch in ["qwen2_0_5b", "falcon_mamba_7b", "olmoe_1b_7b"]:
        run(arch)


if __name__ == "__main__":
    main()
