"""Federated data partitioning: IID and Dirichlet non-IID (paper §5.1,
α = 1), per-client batch iteration, device-profile sampling (the
heterogeneous edge population the event-driven runtime schedules over), and
the lazy ``ClientPool`` that makes planet-scale populations representable —
clients are synthesized deterministically from ``(seed, cid)`` at dispatch
time and released after commit, so resident state is O(active cohort)."""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np


# ========================================================== device profiles
@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Static hardware description of one edge client.

    ``flops`` is the effective *training* throughput (FLOP/s, all overheads
    amortized in), ``bandwidth`` the uplink in bytes/s, ``memory`` the
    device RAM budget in bytes.  ``repro.fed.runtime`` derives each client's
    per-round virtual wall-clock from these plus the analytic cost model in
    ``repro.core.memory``; strategies may read ``tier``/``memory`` to assign
    memory-stratified perturbation budgets (per-tier SPSA ``n_samples``,
    FedKSeed ``K``)."""
    tier: str
    flops: float        # effective compute throughput (FLOP/s)
    bandwidth: float    # uplink bytes/s
    memory: int         # bytes


# (name, memory-budget ceiling as a fraction of the full-adapter reference
# footprint, effective FLOP/s, uplink bytes/s) — mirrors the paper's device
# spread (§5.1: 4–12 GB phones/SBCs vs the ~27 GB LLaMA2-7B requirement):
# low ≈ a phone-class NPU on metered uplink, mid ≈ a flagship phone / SBC,
# high ≈ a desktop-class edge box on broadband.
DEVICE_TIERS: Tuple[Tuple[str, float, float, float], ...] = (
    ("low", 0.40, 2.0e9, 2.5e6),
    ("mid", 0.90, 8.0e9, 1.0e7),
    ("high", float("inf"), 2.5e10, 4.0e7),
)


def profile_tier(mem_ratio: float,
                 tiers=DEVICE_TIERS) -> Tuple[str, float, float]:
    """Tier row for a device whose memory budget is ``mem_ratio`` × the
    reference footprint."""
    for name, ceil, flops, bw in tiers:
        if mem_ratio <= ceil:
            return name, flops, bw
    name, _, flops, bw = tiers[-1]
    return name, flops, bw


def sample_profiles(budgets, ref: int, seed: int = 0, jitter: float = 0.2,
                    tiers=DEVICE_TIERS) -> List[DeviceProfile]:
    """Device profiles for a client population with known memory ``budgets``.

    The tier is deterministic in ``budget / ref`` (so the memory wall and
    the compute/link speeds tell one consistent story per device);
    compute/link throughputs are jittered ±``jitter`` with an rng private to
    this function — the caller's sampling stream is untouched, so adding
    profiles to an existing testbed never perturbs client selection."""
    rng = np.random.default_rng(np.uint32(seed) ^ np.uint32(0x9E3779B9))
    out = []
    for b in np.asarray(budgets, np.int64):
        name, flops, bw = profile_tier(float(b) / float(max(1, ref)), tiers)
        jf, jb = 1.0 + jitter * rng.uniform(-1, 1, 2)
        out.append(DeviceProfile(tier=name, flops=flops * jf,
                                 bandwidth=bw * jb, memory=int(b)))
    return out


def uniform_profiles(n: int, flops: float = 1.0e10, bandwidth: float = 1.0e7,
                     memory: Optional[int] = None) -> List[DeviceProfile]:
    """A homogeneous population (every device identical) — the degenerate
    case where ``async``/``semisync`` scheduling reduces to ``sync``."""
    return [DeviceProfile(tier="uniform", flops=flops, bandwidth=bandwidth,
                          memory=int(memory) if memory else 0)
            for _ in range(n)]


def iid_partition(n_samples: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_samples)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float = 1.0,
                        seed: int = 0, min_per_client: int = 2):
    """Class-wise Dirichlet split: for each class, proportions over clients
    are drawn from Dir(α); smaller α → more skew."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    shards = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx, cuts)):
            shards[client].append(part)
    out = [np.sort(np.concatenate(s)) if s else np.array([], np.int64) for s in shards]
    # guarantee a floor so every client can form at least one batch
    pool = np.concatenate(out)
    for i, s in enumerate(out):
        if len(s) < min_per_client:
            extra = rng.choice(pool, min_per_client - len(s), replace=False)
            out[i] = np.sort(np.concatenate([s, extra]))
    return out


# ======================================================= availability traces
@dataclasses.dataclass(frozen=True)
class AvailabilityTrace:
    """Replayable per-client availability (ISSUE 7): each client owns a
    sorted tuple of ``(start, end)`` online windows over one period of
    virtual time, replayed cyclically.  This replaces Bernoulli dropout
    coin-flips with the diurnal / flaky connectivity structure real device
    fleets exhibit — the same trace replays bit-identically across runs and
    across checkpoint/resume (it is *config*, not mutable state).

    Windows live in ``[0, period)``; a generator that draws a window
    spanning the wrap splits it in two.  A client with no windows is never
    available."""
    windows: Tuple[Tuple[Tuple[float, float], ...], ...]  # per client
    period: float

    @property
    def n_clients(self) -> int:
        return len(self.windows)

    def available(self, cid: int, t: float) -> bool:
        lt = t % self.period
        return any(s <= lt < e for s, e in self.windows[cid])

    def online_until(self, cid: int, t: float) -> float:
        """Absolute time the window containing ``t`` closes (== ``t`` when
        the client is offline at ``t``)."""
        lt = t % self.period
        for s, e in self.windows[cid]:
            if s <= lt < e:
                return t + (e - lt)
        return t

    def offline_cut(self, cid: int, t0: float, t1: float):
        """First moment in ``[t0, t1)`` the client is offline, or ``None``
        if its connectivity covers the whole interval.  Windows are treated
        as independent sessions: a client whose window closes mid-round has
        dropped that round even if a later window reopens before ``t1``."""
        if not self.available(cid, t0):
            return t0
        end = self.online_until(cid, t0)
        # merge back-to-back windows (including across the cyclic wrap)
        while end < t1 and self.available(cid, end):
            nxt = self.online_until(cid, end)
            if nxt <= end:
                break
            end = nxt
        return None if end >= t1 else end


def _split_wrap(start: float, end: float, period: float):
    """Clamp one online interval into ``[0, period)`` windows, splitting at
    the cyclic wrap."""
    if end - start >= period:
        return [(0.0, period)]
    dur = end - start
    start %= period
    end = start + dur
    if end <= period:
        return [(start, end)] if end > start else []
    return [(start, period), (0.0, end - period)]


def diurnal_traces(n_clients: int, period: float = 1000.0,
                   uptime: float = 0.45, jitter: float = 0.25,
                   seed: int = 0) -> AvailabilityTrace:
    """One contiguous online window per client per period — phones that
    charge overnight.  Phases are uniform over the period, duty cycles are
    ``uptime`` jittered ±``jitter`` (relative)."""
    rng = np.random.default_rng((seed, 0xD1))
    wins = []
    for _ in range(n_clients):
        duty = float(np.clip(uptime * (1.0 + jitter * rng.uniform(-1, 1)),
                             0.02, 1.0))
        phase = float(rng.uniform(0.0, period))
        w = _split_wrap(phase, phase + duty * period, period)
        wins.append(tuple(sorted(w)))
    return AvailabilityTrace(windows=tuple(wins), period=float(period))


def flaky_traces(n_clients: int, period: float = 1000.0,
                 mean_up: float = 120.0, mean_down: float = 60.0,
                 seed: int = 0) -> AvailabilityTrace:
    """Alternating exponential up/down sessions over one period (replayed
    cyclically) — cellular links that flap."""
    rng = np.random.default_rng((seed, 0xF7))
    wins = []
    for _ in range(n_clients):
        t = float(rng.exponential(mean_down)) if rng.random() < 0.5 else 0.0
        w = []
        while t < period:
            up = float(rng.exponential(mean_up))
            w.extend(_split_wrap(t, min(t + up, period), period))
            t += up + float(rng.exponential(mean_down))
        wins.append(tuple(sorted((s, e) for s, e in w if e > s)))
    return AvailabilityTrace(windows=tuple(wins), period=float(period))


TRACE_KINDS = {"diurnal": diurnal_traces, "flaky": flaky_traces}


def make_trace(kind: str, n_clients: int, **kw) -> AvailabilityTrace:
    """Build a named synthetic trace (``diurnal`` / ``flaky``)."""
    try:
        fn = TRACE_KINDS[kind]
    except KeyError:
        raise KeyError(f"unknown trace kind {kind!r}; "
                       f"have {sorted(TRACE_KINDS)}") from None
    return fn(n_clients, **kw)


class ClientSampler:
    """Iterates minibatches from a client's shard, reshuffling per epoch."""

    def __init__(self, shard: np.ndarray, batch_size: int, seed=0):
        self.shard = shard
        self.bs = min(batch_size, max(1, len(shard)))
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(len(shard))
        self._pos = 0

    def next_indices(self) -> np.ndarray:
        if self._pos + self.bs > len(self.shard):
            self._order = self.rng.permutation(len(self.shard))
            self._pos = 0
        sel = self._order[self._pos:self._pos + self.bs]
        self._pos += self.bs
        return self.shard[sel]


# ============================================================ lazy client pool
class ClientPool:
    """Lazy client population (ISSUE 8): nothing is materialized up front.

    A client is *synthesized* — data shard, minibatch rng stream,
    ``DeviceProfile`` — deterministically from ``(seed, cid)`` the moment it
    is dispatched (``acquire``) and torn down after its update commits
    (``release``), so the resident set is O(active cohort) however large
    ``n_clients`` is; a 10⁶-client population costs a dict of a few dozen
    entries, not 10⁶ ``Client`` objects.

    Determinism contract: the synthesis factory receives ``(cid, visit)``
    where ``visit`` counts this cid's materializations so far (the **pool
    cursor** — checkpointed, so kill/resume replays the identical stream).
    Static per-client facts (shard membership, budget, profile) must depend
    only on ``(seed, cid)``; only the minibatch rng advances with ``visit``.
    Because each cid owns its cursor, the synthesized client is bit-identical
    regardless of when — and interleaved with whom — it is dispatched.

    ``acquire`` refcounts residency (a cid can be held by an in-flight
    entry *and* a probe), ``peek`` rebuilds a resident-equivalent handle
    without advancing the cursor (checkpoint restore of in-flight entries —
    their dispatch already advanced it pre-crash), and
    ``resident_bytes``/``max_resident`` expose the O(active cohort) bound
    ``bench_round --population`` gates on."""

    def __init__(self, n_clients: int, synth: Callable[[int, int], object],
                 nbytes: Optional[Callable[[object], int]] = None):
        self.n_clients = int(n_clients)
        self._synth = synth
        self._nbytes = nbytes or (lambda c: 0)
        self._visits = {}          # cid -> materializations so far (cursor)
        self._resident = {}        # cid -> [client, refcount]
        self.max_resident = 0      # peak resident client count
        self.max_resident_bytes = 0
        self._resident_bytes = 0

    # ------------------------------------------------------------ lifecycle
    def _admit(self, cid: int, client) -> None:
        self._resident[cid] = [client, 1]
        self._resident_bytes += self._nbytes(client)
        self.max_resident = max(self.max_resident, len(self._resident))
        self.max_resident_bytes = max(self.max_resident_bytes,
                                      self._resident_bytes)

    def acquire(self, cid: int):
        """Materialize ``cid`` at its current cursor (advancing it), or bump
        the refcount when already resident."""
        ent = self._resident.get(cid)
        if ent is not None:
            ent[1] += 1
            return ent[0]
        visit = self._visits.get(cid, 0)
        self._visits[cid] = visit + 1
        client = self._synth(cid, visit)
        self._admit(cid, client)
        return client

    def peek(self, cid: int):
        """Resident-equivalent handle *without* advancing the cursor: the
        client as its latest dispatch synthesized it (static facts are
        visit-independent; the sampler stream restarts at that visit).  Used
        to rehydrate checkpoint-restored in-flight entries, whose original
        dispatch already advanced the cursor before the crash."""
        ent = self._resident.get(cid)
        if ent is not None:
            ent[1] += 1
            return ent[0]
        client = self._synth(cid, max(0, self._visits.get(cid, 1) - 1))
        self._admit(cid, client)
        return client

    def release(self, cid: int) -> None:
        ent = self._resident.get(cid)
        if ent is None:
            return
        ent[1] -= 1
        if ent[1] <= 0:
            self._resident_bytes -= self._nbytes(ent[0])
            del self._resident[cid]

    # ------------------------------------------------------------- sampling
    def sample(self, k: int, rng: np.random.Generator, busy=frozenset(),
               eligible: Optional[Callable[[int], bool]] = None,
               max_tries: Optional[int] = None) -> list:
        """Rejection-sample ``k`` distinct eligible, non-busy cids and
        acquire them.  Candidate cids come from ``rng`` (the caller's
        sampling stream — deterministic given its state) and eligibility is
        tested with the cheap per-cid predicate, never by enumerating the
        population: the only O(population) quantity is the integer range the
        candidates are drawn from."""
        n = self.n_clients
        k = max(0, min(k, n - len(busy)))
        got, chosen = [], set()
        tries, cap = 0, max_tries if max_tries is not None else max(64, 32 * k)
        while len(got) < k and tries < cap:
            cid = int(rng.integers(n))
            tries += 1
            if cid in busy or cid in chosen:
                continue
            if eligible is not None and not eligible(cid):
                continue
            chosen.add(cid)
            got.append(self.acquire(cid))
        return got

    # ------------------------------------------------------------ telemetry
    @property
    def resident(self) -> int:
        return len(self._resident)

    def resident_bytes(self) -> int:
        return self._resident_bytes

    # -------------------------------------------------- durable cursor state
    def state_dict(self) -> dict:
        """The pool cursor: per-cid visit counts (only touched cids — still
        O(participants ever dispatched), never O(population)).  Residency is
        *not* state — restored in-flight entries re-acquire via ``peek``."""
        cids = np.fromiter(self._visits.keys(), np.int64,
                           count=len(self._visits))
        visits = np.fromiter(self._visits.values(), np.int64,
                             count=len(self._visits))
        order = np.argsort(cids, kind="stable")
        return {"cids": cids[order], "visits": visits[order],
                "max_resident": int(self.max_resident),
                "max_resident_bytes": int(self.max_resident_bytes)}

    def load_state_dict(self, s: dict) -> None:
        self._visits = {int(c): int(v)
                        for c, v in zip(np.asarray(s["cids"]),
                                        np.asarray(s["visits"]))}
        self.max_resident = int(s.get("max_resident", 0))
        self.max_resident_bytes = int(s.get("max_resident_bytes", 0))
        self._resident.clear()
        self._resident_bytes = 0
