"""Federated data partitioning: IID and Dirichlet non-IID (paper §5.1,
α = 1), plus per-client batch iteration."""
from __future__ import annotations

import numpy as np


def iid_partition(n_samples: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_samples)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float = 1.0,
                        seed: int = 0, min_per_client: int = 2):
    """Class-wise Dirichlet split: for each class, proportions over clients
    are drawn from Dir(α); smaller α → more skew."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    shards = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx, cuts)):
            shards[client].append(part)
    out = [np.sort(np.concatenate(s)) if s else np.array([], np.int64) for s in shards]
    # guarantee a floor so every client can form at least one batch
    pool = np.concatenate(out)
    for i, s in enumerate(out):
        if len(s) < min_per_client:
            extra = rng.choice(pool, min_per_client - len(s), replace=False)
            out[i] = np.sort(np.concatenate([s, extra]))
    return out


class ClientSampler:
    """Iterates minibatches from a client's shard, reshuffling per epoch."""

    def __init__(self, shard: np.ndarray, batch_size: int, seed: int = 0):
        self.shard = shard
        self.bs = min(batch_size, max(1, len(shard)))
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(len(shard))
        self._pos = 0

    def next_indices(self) -> np.ndarray:
        if self._pos + self.bs > len(self.shard):
            self._order = self.rng.permutation(len(self.shard))
            self._pos = 0
        sel = self._order[self._pos:self._pos + self.bs]
        self._pos += self.bs
        return self.shard[sel]
