"""Federated data partitioning: IID and Dirichlet non-IID (paper §5.1,
α = 1), per-client batch iteration, and device-profile sampling (the
heterogeneous edge population the event-driven runtime schedules over)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


# ========================================================== device profiles
@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Static hardware description of one edge client.

    ``flops`` is the effective *training* throughput (FLOP/s, all overheads
    amortized in), ``bandwidth`` the uplink in bytes/s, ``memory`` the
    device RAM budget in bytes.  ``repro.fed.runtime`` derives each client's
    per-round virtual wall-clock from these plus the analytic cost model in
    ``repro.core.memory``; strategies may read ``tier``/``memory`` to assign
    memory-stratified perturbation budgets (per-tier SPSA ``n_samples``,
    FedKSeed ``K``)."""
    tier: str
    flops: float        # effective compute throughput (FLOP/s)
    bandwidth: float    # uplink bytes/s
    memory: int         # bytes


# (name, memory-budget ceiling as a fraction of the full-adapter reference
# footprint, effective FLOP/s, uplink bytes/s) — mirrors the paper's device
# spread (§5.1: 4–12 GB phones/SBCs vs the ~27 GB LLaMA2-7B requirement):
# low ≈ a phone-class NPU on metered uplink, mid ≈ a flagship phone / SBC,
# high ≈ a desktop-class edge box on broadband.
DEVICE_TIERS: Tuple[Tuple[str, float, float, float], ...] = (
    ("low", 0.40, 2.0e9, 2.5e6),
    ("mid", 0.90, 8.0e9, 1.0e7),
    ("high", float("inf"), 2.5e10, 4.0e7),
)


def profile_tier(mem_ratio: float,
                 tiers=DEVICE_TIERS) -> Tuple[str, float, float]:
    """Tier row for a device whose memory budget is ``mem_ratio`` × the
    reference footprint."""
    for name, ceil, flops, bw in tiers:
        if mem_ratio <= ceil:
            return name, flops, bw
    name, _, flops, bw = tiers[-1]
    return name, flops, bw


def sample_profiles(budgets, ref: int, seed: int = 0, jitter: float = 0.2,
                    tiers=DEVICE_TIERS) -> List[DeviceProfile]:
    """Device profiles for a client population with known memory ``budgets``.

    The tier is deterministic in ``budget / ref`` (so the memory wall and
    the compute/link speeds tell one consistent story per device);
    compute/link throughputs are jittered ±``jitter`` with an rng private to
    this function — the caller's sampling stream is untouched, so adding
    profiles to an existing testbed never perturbs client selection."""
    rng = np.random.default_rng(np.uint32(seed) ^ np.uint32(0x9E3779B9))
    out = []
    for b in np.asarray(budgets, np.int64):
        name, flops, bw = profile_tier(float(b) / float(max(1, ref)), tiers)
        jf, jb = 1.0 + jitter * rng.uniform(-1, 1, 2)
        out.append(DeviceProfile(tier=name, flops=flops * jf,
                                 bandwidth=bw * jb, memory=int(b)))
    return out


def uniform_profiles(n: int, flops: float = 1.0e10, bandwidth: float = 1.0e7,
                     memory: Optional[int] = None) -> List[DeviceProfile]:
    """A homogeneous population (every device identical) — the degenerate
    case where ``async``/``semisync`` scheduling reduces to ``sync``."""
    return [DeviceProfile(tier="uniform", flops=flops, bandwidth=bandwidth,
                          memory=int(memory) if memory else 0)
            for _ in range(n)]


def iid_partition(n_samples: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_samples)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float = 1.0,
                        seed: int = 0, min_per_client: int = 2):
    """Class-wise Dirichlet split: for each class, proportions over clients
    are drawn from Dir(α); smaller α → more skew."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    shards = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx, cuts)):
            shards[client].append(part)
    out = [np.sort(np.concatenate(s)) if s else np.array([], np.int64) for s in shards]
    # guarantee a floor so every client can form at least one batch
    pool = np.concatenate(out)
    for i, s in enumerate(out):
        if len(s) < min_per_client:
            extra = rng.choice(pool, min_per_client - len(s), replace=False)
            out[i] = np.sort(np.concatenate([s, extra]))
    return out


class ClientSampler:
    """Iterates minibatches from a client's shard, reshuffling per epoch."""

    def __init__(self, shard: np.ndarray, batch_size: int, seed: int = 0):
        self.shard = shard
        self.bs = min(batch_size, max(1, len(shard)))
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(len(shard))
        self._pos = 0

    def next_indices(self) -> np.ndarray:
        if self._pos + self.bs > len(self.shard):
            self._order = self.rng.permutation(len(self.shard))
            self._pos = 0
        sel = self._order[self._pos:self._pos + self.bs]
        self._pos += self.bs
        return self.shard[sel]
