"""Deterministic synthetic corpora (offline environment — no downloads).

Two task families mirroring the paper's evaluation:

* text classification (YELP-P-like binary … YAHOO-like 10-class): each class
  has its own token distribution over a class-specific "topic" slice of the
  vocabulary mixed with common tokens; the label is recoverable from token
  statistics, so small models can learn it in a few federated rounds.
* instruction tuning: next-token prediction on structured prompt→response
  pairs (key-value recall patterns), learnable by a ~100M causal LM.

All generation is seeded numpy — runs reproduce bit-for-bit.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_classes: int
    seq_len: int
    n_samples: int
    vocab: int
    seed: int = 0
    topic_strength: float = 0.35    # fraction of positions drawn from class topics


# paper's four classification benchmarks, scaled
DATASETS = {
    "yelp_p": DatasetSpec("yelp_p", 2, 64, 4096, 1024, seed=11),
    "agnews": DatasetSpec("agnews", 4, 32, 4096, 1024, seed=12),
    "yahoo": DatasetSpec("yahoo", 10, 64, 4096, 1024, seed=13),
    "news20": DatasetSpec("news20", 20, 64, 4096, 1024, seed=14),
}

IGNORE = -100


def label_token(spec: DatasetSpec, c: int) -> int:
    """Classes map to reserved label tokens at the top of the vocab."""
    return spec.vocab - 1 - c


def make_classification(spec: DatasetSpec):
    """Returns (tokens (N, S) int32, labels (N,) int32).

    Sequence layout: [body ... body, MASK_SLOT]; the model predicts the label
    token at the final position (CLS-style readout through the LM head)."""
    rng = np.random.default_rng(spec.seed)
    n_reserved = spec.n_classes + 1
    body_vocab = spec.vocab - n_reserved
    topic_size = max(8, body_vocab // (2 * spec.n_classes))
    common = np.arange(body_vocab - spec.n_classes * topic_size)
    topics = [body_vocab - (c + 1) * topic_size + np.arange(topic_size)
              for c in range(spec.n_classes)]

    labels = rng.integers(0, spec.n_classes, spec.n_samples)
    tokens = np.empty((spec.n_samples, spec.seq_len), np.int32)
    body = spec.seq_len - 1
    for i, c in enumerate(labels):
        is_topic = rng.random(body) < spec.topic_strength
        toks = np.where(is_topic,
                        rng.choice(topics[c], body),
                        rng.choice(common, body))
        tokens[i, :body] = toks
        tokens[i, body] = 0          # slot whose prediction is the class
    return tokens.astype(np.int32), labels.astype(np.int32)


def classification_batch(spec: DatasetSpec, tokens, labels, idx):
    """Build a model batch: labels are IGNORE everywhere except the final
    position, which carries the class's label token.  ``class_tokens`` lets
    eval restrict the argmax to the label-token set (classifier semantics)."""
    t = tokens[idx]
    y = np.full_like(t, IGNORE)
    y[:, -1] = np.array([label_token(spec, int(c)) for c in labels[idx]])
    cls = np.array([label_token(spec, c) for c in range(spec.n_classes)],
                   np.int32)
    return {"tokens": t, "labels": y, "class_tokens": cls}


# ------------------------------------------------------------------ instruction
def make_instruction(n_samples=2048, seq_len=64, vocab=8192, n_keys=64, seed=7,
                     mapping_seed=0):
    """Instruction tuning miniature: the response value is a *memorized*
    per-corpus function of the queried key (NOT present in the context), so
    fine-tuning must store new associations — pretraining on a different
    ``mapping_seed`` transfers the format but not the answers.

    Sequence: [filler topic tokens …, Q, key, A, value]; loss only at the
    answer position."""
    rng = np.random.default_rng(seed)
    Q, A = 2, 3
    keys_pool = 16 + np.arange(n_keys)
    vals_pool = 16 + n_keys + np.arange(n_keys)
    map_rng = np.random.default_rng(10_000 + mapping_seed)
    mapping = map_rng.permutation(vals_pool)         # key i -> mapping[i]
    filler_pool = 16 + 2 * n_keys + np.arange(max(16, vocab // 4 - 2 * n_keys))
    tokens = np.zeros((n_samples, seq_len), np.int32)
    labels = np.full((n_samples, seq_len), IGNORE, np.int32)
    for i in range(n_samples):
        ki = rng.integers(0, n_keys)
        fill = rng.choice(filler_pool, seq_len - 4)
        seq = list(fill) + [Q, int(keys_pool[ki]), A, int(mapping[ki])]
        tokens[i] = seq
        labels[i, seq_len - 2] = int(mapping[ki])    # predict the value
    return tokens, labels


def lm_batch(tokens, labels, idx):
    return {"tokens": tokens[idx], "labels": labels[idx]}
