"""msgpack pytree checkpointing (host-local; restore re-shards under the
current mesh via device_put with the ruleset's NamedShardings).

Two layers live here:

* the pytree save/load pair (``save_pytree``/``load_pytree``) used for model
  artifacts — leaves only, structure supplied by the caller at load time;
* a generic *state* serializer (``save_state``/``load_state``) for runtime
  checkpoints (ISSUE 7): arbitrarily nested dicts/lists/tuples mixing array
  leaves with host scalars, big integers (numpy PCG64 bit-generator state
  carries 128-bit ints msgpack cannot encode) and non-string dict keys.  The
  encoding is self-describing, so no template is needed on load.

All writes are atomic: bytes go to ``<name>.tmp`` in the target directory and
are renamed over the destination, so a crash mid-write never corrupts the
previous checkpoint.
"""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_INT64_MIN, _INT64_MAX = -(2 ** 63), 2 ** 63 - 1


def _atomic_write_bytes(path, data: bytes) -> pathlib.Path:
    """Write-tmp-then-rename.  ``with_name`` (not ``with_suffix``) so dotted
    stems round-trip and two files differing only in suffix cannot collide
    on the same tmp path."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_bytes(data)
    tmp.replace(path)                        # atomic swap
    return path


def _pack_leaf(x):
    x = np.asarray(x)
    dt = str(x.dtype)
    if dt == "bfloat16":
        return {"__nd__": True, "dtype": "bfloat16",
                "shape": list(x.shape),
                "data": x.view(np.uint16).tobytes()}
    return {"__nd__": True, "dtype": dt, "shape": list(x.shape),
            "data": x.tobytes()}


def _unpack_leaf(d):
    if d["dtype"] == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    return jnp.asarray(np.frombuffer(d["data"], d["dtype"]).reshape(d["shape"]))


def save_pytree(path, tree, step: int = 0, meta: dict | None = None):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "step": step,
        "meta": meta or {},
        "treedef": str(treedef),
        "leaves": [_pack_leaf(jax.device_get(l)) for l in leaves],
    }
    return _atomic_write_bytes(path, msgpack.packb(payload, use_bin_type=True))


def _restore(payload, like):
    """Rebuild an unpacked payload into the structure of ``like``
    (shape-checked)."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    got = [_unpack_leaf(d) for d in payload["leaves"]]
    assert len(got) == len(leaves), (len(got), len(leaves))
    for g, l in zip(got, leaves):
        assert tuple(g.shape) == tuple(l.shape), (g.shape, l.shape)
    return jax.tree_util.tree_unflatten(treedef, got)


def load_pytree(path, like):
    """Restore into the structure of ``like`` (shape-checked)."""
    payload = msgpack.unpackb(pathlib.Path(path).read_bytes(), raw=False)
    return _restore(payload, like), payload["step"]


def save_adapter_stack(path, stack, tenant: str = "", meta: dict | None = None):
    """Persist one chain-tuned adapter stack — the per-task artifact a tenant
    registers with the serving engine.  ``meta`` can carry the trainable span
    (``l_start``/``window``) so partial-chain checkpoints re-register through
    the matching ``ActiveAdapters`` spec."""
    return save_pytree(path, {"adapters": stack},
                       meta={"tenant": tenant, **(meta or {})})


def load_adapter_stack(path, like):
    """Restore a tenant adapter stack into the structure of ``like``
    (shape-checked).  Returns (stack, meta)."""
    payload = msgpack.unpackb(pathlib.Path(path).read_bytes(), raw=False)
    tree = _restore(payload, {"adapters": like})
    return tree["adapters"], payload.get("meta", {})


def save_train_state(path, params, adapters, round_idx, extra=None):
    return save_pytree(path, {"params": params, "adapters": adapters},
                       step=round_idx, meta=extra or {})


def load_train_state(path, params_like, adapters_like):
    tree, step = load_pytree(path, {"params": params_like,
                                    "adapters": adapters_like})
    return tree["params"], tree["adapters"], step


# ---------------------------------------------------------------- run state
# Self-describing encoding for runtime checkpoints.  Markers:
#   __nd__  array leaf (shape/dtype/bytes; bf16 via uint16 view)
#   __tu__  tuple (msgpack would silently return a list)
#   __bi__  integer outside the int64 range, as a decimal string
#   __kv__  dict with non-string (or marker-colliding) keys, as [k, v] pairs
_MARKERS = frozenset({"__nd__", "__tu__", "__bi__", "__kv__"})


def _enc(x):
    if isinstance(x, (np.ndarray, jnp.ndarray)):
        return _pack_leaf(jax.device_get(x))
    if isinstance(x, (bool, np.bool_)):
        return bool(x)
    if isinstance(x, (int, np.integer)):
        v = int(x)
        if v < _INT64_MIN or v > _INT64_MAX:
            return {"__bi__": str(v)}
        return v
    if isinstance(x, (float, np.floating)):
        return float(x)
    if x is None or isinstance(x, (str, bytes)):
        return x
    if isinstance(x, tuple):
        return {"__tu__": [_enc(v) for v in x]}
    if isinstance(x, list):
        return [_enc(v) for v in x]
    if isinstance(x, dict):
        if all(isinstance(k, str) for k in x) and \
                not (_MARKERS & set(x.keys())):
            return {k: _enc(v) for k, v in x.items()}
        return {"__kv__": [[_enc(k), _enc(v)] for k, v in x.items()]}
    raise TypeError(f"save_state cannot encode {type(x).__name__}: {x!r}")


def _dec(x):
    if isinstance(x, dict):
        if x.get("__nd__"):
            return _unpack_leaf(x)
        if "__tu__" in x:
            return tuple(_dec(v) for v in x["__tu__"])
        if "__bi__" in x:
            return int(x["__bi__"])
        if "__kv__" in x:
            return {_dec(k): _dec(v) for k, v in x["__kv__"]}
        return {k: _dec(v) for k, v in x.items()}
    if isinstance(x, list):
        return [_dec(v) for v in x]
    return x


def save_state(path, state) -> pathlib.Path:
    """Serialize an arbitrary nested runtime state atomically.  Accepts
    dicts/lists/tuples of array leaves (dtype-preserving, bf16 included),
    scalars, strings, ``None`` and arbitrarily large ints (PCG64 state)."""
    return _atomic_write_bytes(
        path, msgpack.packb(_enc(state), use_bin_type=True))


def load_state(path):
    """Inverse of :func:`save_state`; array leaves come back as jnp arrays
    with their saved dtypes, tuples as tuples, big ints as ints."""
    raw = msgpack.unpackb(pathlib.Path(path).read_bytes(), raw=False,
                          strict_map_key=False)
    return _dec(raw)
