"""msgpack pytree checkpointing (host-local; restore re-shards under the
current mesh via device_put with the ruleset's NamedShardings)."""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x):
    x = np.asarray(x)
    dt = str(x.dtype)
    if dt == "bfloat16":
        return {"__nd__": True, "dtype": "bfloat16",
                "shape": list(x.shape),
                "data": x.view(np.uint16).tobytes()}
    return {"__nd__": True, "dtype": dt, "shape": list(x.shape),
            "data": x.tobytes()}


def _unpack_leaf(d):
    if d["dtype"] == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    return jnp.asarray(np.frombuffer(d["data"], d["dtype"]).reshape(d["shape"]))


def save_pytree(path, tree, step: int = 0, meta: dict | None = None):
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "step": step,
        "meta": meta or {},
        "treedef": str(treedef),
        "leaves": [_pack_leaf(jax.device_get(l)) for l in leaves],
    }
    tmp = path.with_suffix(".tmp")
    tmp.write_bytes(msgpack.packb(payload, use_bin_type=True))
    tmp.replace(path)                        # atomic swap
    return path


def _restore(payload, like):
    """Rebuild an unpacked payload into the structure of ``like``
    (shape-checked)."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    got = [_unpack_leaf(d) for d in payload["leaves"]]
    assert len(got) == len(leaves), (len(got), len(leaves))
    for g, l in zip(got, leaves):
        assert tuple(g.shape) == tuple(l.shape), (g.shape, l.shape)
    return jax.tree_util.tree_unflatten(treedef, got)


def load_pytree(path, like):
    """Restore into the structure of ``like`` (shape-checked)."""
    payload = msgpack.unpackb(pathlib.Path(path).read_bytes(), raw=False)
    return _restore(payload, like), payload["step"]


def save_adapter_stack(path, stack, tenant: str = "", meta: dict | None = None):
    """Persist one chain-tuned adapter stack — the per-task artifact a tenant
    registers with the serving engine.  ``meta`` can carry the trainable span
    (``l_start``/``window``) so partial-chain checkpoints re-register through
    the matching ``ActiveAdapters`` spec."""
    return save_pytree(path, {"adapters": stack},
                       meta={"tenant": tenant, **(meta or {})})


def load_adapter_stack(path, like):
    """Restore a tenant adapter stack into the structure of ``like``
    (shape-checked).  Returns (stack, meta)."""
    payload = msgpack.unpackb(pathlib.Path(path).read_bytes(), raw=False)
    tree = _restore(payload, {"adapters": like})
    return tree["adapters"], payload.get("meta", {})


def save_train_state(path, params, adapters, round_idx, extra=None):
    return save_pytree(path, {"params": params, "adapters": adapters},
                       step=round_idx, meta=extra or {})


def load_train_state(path, params_like, adapters_like):
    tree, step = load_pytree(path, {"params": params_like,
                                    "adapters": adapters_like})
    return tree["params"], tree["adapters"], step
