"""Block-wise absmax int8 quantization for optimizer state (ISSUE 10).

8-bit optimizers (Dettmers et al., arXiv:2110.02861) keep the Adam moments in
int8 with one fp32 scale per block of ``QBLOCK`` contiguous elements: the
stored value is ``round(127 · x / absmax(block))`` and the scale is
``absmax / 127``, so dequantization is a single multiply.  At the default
block of 128 the scale overhead is 4 B per 128 payload bytes (~3%), cutting
per-client optimizer moment memory 4× — the edge-memory lever the cohort
engine's resident-client ceiling reads (``core.memory.optimizer_state_bytes``).

``QBLOCK = 128`` deliberately equals the TPU lane width: a leaf flattened to
``(rows, 128)`` makes every quantization block one kernel row, so the fused
optimizer kernel (``kernels/fused_optim.py``) dequantizes/requantizes with a
row-local reduction and no cross-tile traffic.

Zero blocks quantize to scale 0 and an all-zero payload; dequantization maps
them back to exact zeros (the ``jnp.where`` guard keeps requantization of a
dead block from dividing by zero).
"""
from __future__ import annotations

import jax.numpy as jnp

QBLOCK = 128


def _pad_flat(x, qblock):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % qblock
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def n_blocks(n: int, qblock: int = QBLOCK) -> int:
    return (n + qblock - 1) // qblock


def quantize_blockwise(x, qblock: int = QBLOCK):
    """``x`` (any shape, float) → ``(q, scales)``: ``q`` int8 in the leaf's
    own shape, ``scales`` fp32 of shape ``(n_blocks,)`` over the flattened
    order.  ``scales[i] = absmax(block_i) / 127``."""
    flat, _ = _pad_flat(x.astype(jnp.float32), qblock)
    blocks = flat.reshape(-1, qblock)
    scales = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    inv = jnp.where(scales > 0, 1.0 / scales, 0.0)
    q = jnp.round(blocks * inv[:, None]).astype(jnp.int8)
    n = int(x.size)
    return q.reshape(-1)[:n].reshape(x.shape), scales


def dequantize_blockwise(q, scales, qblock: int = QBLOCK):
    """Inverse of :func:`quantize_blockwise` — fp32, the leaf's shape."""
    flat, _ = _pad_flat(q.astype(jnp.float32), qblock)
    out = flat.reshape(-1, qblock) * scales[:, None]
    n = int(q.size)
    return out.reshape(-1)[:n].reshape(q.shape)


def zeros_quantized(shape, qblock: int = QBLOCK):
    """Quantized representation of an all-zero moment buffer."""
    n = 1
    for s in shape:
        n *= int(s)
    return (jnp.zeros(shape, jnp.int8),
            jnp.zeros((n_blocks(n, qblock),), jnp.float32))
