"""Optimizers (no optax in env): SGD(+momentum), AdamW, schedules, clipping.

API mirrors optax minimally:
    opt = make_optimizer("adamw", lr=1e-3)
    state = opt.init(params)
    params, state = opt.step(params, grads, state)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..utils.tree import global_norm, tree_map


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    step: Callable          # (params, grads, state) -> (params, state)
    name: str = ""


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return tree_map(lambda g: g * scale, grads), gn


def _resolve_lr(lr, count):
    return lr(count) if callable(lr) else lr


def sgd(lr, momentum=0.0, clip=None):
    def init(params):
        st = {"count": jnp.zeros((), jnp.int32)}
        if momentum:
            st["mu"] = tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return st

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        lr_t = _resolve_lr(lr, state["count"])
        if momentum:
            mu = tree_map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
            new_p = tree_map(lambda p, m: (p - lr_t * m).astype(p.dtype), params, mu)
            return new_p, {"count": state["count"] + 1, "mu": mu}
        new_p = tree_map(lambda p, g: (p - lr_t * g.astype(jnp.float32)).astype(p.dtype),
                         params, grads)
        return new_p, {"count": state["count"] + 1}

    return Optimizer(init, step, "sgd")


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01, clip=1.0):
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"count": jnp.zeros((), jnp.int32),
                "mu": tree_map(z, params), "nu": tree_map(z, params)}

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        c = state["count"] + 1
        lr_t = _resolve_lr(lr, state["count"])
        mu = tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
        nu = tree_map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state["nu"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return (p - lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                                + weight_decay * p.astype(jnp.float32))).astype(p.dtype)

        return tree_map(upd, params, mu, nu), {"count": c, "mu": mu, "nu": nu}

    return Optimizer(init, step, "adamw")


def make_optimizer(name, lr, **kw):
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(name)


# ------------------------------------------------------------------ schedules
def cosine_schedule(base_lr, warmup_steps, total_steps, min_ratio=0.1):
    def sched(count):
        c = count.astype(jnp.float32)
        warm = c / jnp.maximum(1.0, warmup_steps)
        prog = jnp.clip((c - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(c < warmup_steps, warm, cos)
    return sched
