"""Optimizers (no optax in env): SGD(+momentum), AdamW, schedules, clipping.

API mirrors optax minimally:
    opt = make_optimizer("adamw", lr=1e-3)
    state = opt.init(params)
    params, state = opt.step(params, grads, state)

ISSUE 10 adds two plan-level knobs, threaded from ``ChainConfig`` /
``TrainablePlan`` by the engine:

* ``fused`` — ``None`` (default) runs the single-pass update: clip-scale →
  moment update → bias-corrected parameter update as ONE chain per leaf —
  the Pallas fused-optimizer kernel on TPU (``kernels/fused_optim.py``), the
  op-identical XLA fallback elsewhere (XLA fuses the chain into one loop; a
  CPU interpret-mode kernel would only slow it down).  ``True`` forces the
  kernel (interpret on CPU — the parity tests' route), ``False`` keeps the
  legacy multi-``tree_map`` step (the ``bench_round`` unfused baseline).
* ``opt_bits`` — 32 (fp32 moments, default) or 8: block-wise absmax int8
  moments + per-128-block fp32 scales (``optim.quant``), dequant/requant
  fused into the same pass, 4× less resident optimizer state per client
  (``core.memory.optimizer_state_bytes``).  int8 always runs single-pass.
  AdamW's ``nu`` is stored as ``√nu`` so the absmax dead zone can't zero
  small second moments under the ``1/√ν̂`` preconditioner (see
  ``kernels/fused_optim.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..utils.tree import global_norm, tree_map
from .quant import zeros_quantized


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    step: Callable          # (params, grads, state) -> (params, state)
    name: str = ""


def clip_by_global_norm(grads, max_norm):
    """Scale ``grads`` so their global norm is at most ``max_norm``.

    The scale is ``jnp.where``-guarded: a zero-gradient (or empty) tree has
    ``gn == 0`` and yields scale 1.0 *exactly* — the old
    ``max_norm / (gn + 1e-9)`` form produced a spurious ~1e9 scale there,
    clamped to 1 only by the ``minimum`` and drifting the no-op case by one
    ulp whenever ``gn`` was merely tiny rather than zero."""
    gn = global_norm(grads)
    scale = _clip_scale(gn, max_norm)
    return tree_map(lambda g: g * scale, grads), gn


def _clip_scale(gn, max_norm):
    return jnp.where(gn > max_norm, max_norm / jnp.maximum(gn, 1e-30),
                     jnp.float32(1.0))


def _resolve_lr(lr, count):
    return lr(count) if callable(lr) else lr


def _check_bits(opt_bits):
    if opt_bits not in (32, 8):
        raise ValueError(f"opt_bits must be 32 or 8, got {opt_bits!r}")


def _kernel_route(fused) -> bool:
    """True when the single-pass step should call the Pallas kernel:
    forced, or backend-aware on TPU (interpret mode on CPU is strictly
    slower than the op-identical XLA fallback)."""
    return fused is True or (fused is None
                             and jax.default_backend() == "tpu")


def sgd(lr, momentum=0.0, clip=None, opt_bits=32, fused=None):
    _check_bits(opt_bits)
    quantized = opt_bits == 8 and momentum
    use_kernel = _kernel_route(fused)
    single_pass = fused is not False or quantized

    def init(params):
        st = {"count": jnp.zeros((), jnp.int32)}
        if quantized:
            qs = _tuple_tree_map(lambda p: zeros_quantized(p.shape), params)
            st["mu_q"] = _unzip(qs, params, 0)
            st["mu_s"] = _unzip(qs, params, 1)
        elif momentum:
            st["mu"] = tree_map(lambda p: jnp.zeros_like(p, jnp.float32),
                                params)
        return st

    def step(params, grads, state):
        lr_t = _resolve_lr(lr, state["count"])
        new_state = {"count": state["count"] + 1}
        if not single_pass:                      # legacy multi-pass baseline
            if clip is not None:
                grads, _ = clip_by_global_norm(grads, clip)
            if momentum:
                mu = tree_map(
                    lambda m, g: momentum * m + g.astype(jnp.float32),
                    state["mu"], grads)
                new_p = tree_map(lambda p, m: (p - lr_t * m).astype(p.dtype),
                                 params, mu)
                return new_p, {**new_state, "mu": mu}
            new_p = tree_map(
                lambda p, g: (p - lr_t * g.astype(jnp.float32)
                              ).astype(p.dtype), params, grads)
            return new_p, new_state
        scale = (_clip_scale(global_norm(grads), clip) if clip is not None
                 else jnp.float32(1.0))
        if not momentum:
            new_p = tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr_t * (g.astype(jnp.float32) * scale)
                              ).astype(p.dtype), params, grads)
            return new_p, new_state
        if quantized:
            if use_kernel:
                from ..kernels import ops
                from ..kernels.fused_optim import pack_scalars
                sc = pack_scalars(scale, lr_t, 1.0, 1.0)
                out = tree_map(
                    lambda p, g, mq, ms: ops.fused_sgdm8(
                        p, g, mq, ms, sc, momentum=momentum),
                    params, grads, state["mu_q"], state["mu_s"])
            else:
                from ..kernels.fused_optim import sgdm8_ref
                out = tree_map(
                    lambda p, g, mq, ms: sgdm8_ref(p, g, mq, ms, scale,
                                                   lr_t, momentum),
                    params, grads, state["mu_q"], state["mu_s"])
            return (_unzip(out, params, 0),
                    {**new_state, "mu_q": _unzip(out, params, 1),
                     "mu_s": _unzip(out, params, 2)})
        if use_kernel:
            from ..kernels import ops
            from ..kernels.fused_optim import pack_scalars
            sc = pack_scalars(scale, lr_t, 1.0, 1.0)
            out = tree_map(
                lambda p, g, m: ops.fused_sgdm(p, g, m, sc,
                                               momentum=momentum),
                params, grads, state["mu"])
        else:
            from ..kernels.fused_optim import sgdm_ref
            out = tree_map(
                lambda p, g, m: sgdm_ref(p, g, m, scale, lr_t, momentum),
                params, grads, state["mu"])
        return (_unzip(out, params, 0),
                {**new_state, "mu": _unzip(out, params, 1)})

    return Optimizer(init, step, "sgd")


def _tuple_tree_map(fn, *trees):
    """tree_map whose per-leaf results are tuples to be split by
    :func:`_unzip` (the tuples sit at leaf positions of the input tree)."""
    return jax.tree_util.tree_map(fn, *trees)


def _unzip(out, like, i):
    """Pick component ``i`` out of a tree shaped like ``like`` whose leaves
    are result tuples from the per-leaf fused step."""
    del like
    return jax.tree_util.tree_map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01, clip=1.0,
          opt_bits=32, fused=None):
    _check_bits(opt_bits)
    quantized = opt_bits == 8
    use_kernel = _kernel_route(fused)
    single_pass = fused is not False or quantized

    def init(params):
        st = {"count": jnp.zeros((), jnp.int32)}
        if quantized:
            qs = _tuple_tree_map(lambda p: zeros_quantized(p.shape), params)
            for mom in ("mu", "nu"):
                st[mom + "_q"] = _unzip(qs, params, 0)
                st[mom + "_s"] = _unzip(qs, params, 1)
            return st
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {**st, "mu": tree_map(z, params), "nu": tree_map(z, params)}

    def step(params, grads, state):
        c = state["count"] + 1
        lr_t = _resolve_lr(lr, state["count"])
        if not single_pass:                      # legacy multi-pass baseline
            if clip is not None:
                grads, _ = clip_by_global_norm(grads, clip)
            mu = tree_map(
                lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                state["mu"], grads)
            nu = tree_map(
                lambda v, g: b2 * v
                + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                state["nu"], grads)
            bc1 = 1 - b1 ** c.astype(jnp.float32)
            bc2 = 1 - b2 ** c.astype(jnp.float32)

            def upd(p, m, v):
                mhat = m / bc1
                vhat = v / bc2
                return (p - lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                                    + weight_decay * p.astype(jnp.float32))
                        ).astype(p.dtype)

            return tree_map(upd, params, mu, nu), {"count": c, "mu": mu,
                                                   "nu": nu}
        scale = (_clip_scale(global_norm(grads), clip) if clip is not None
                 else jnp.float32(1.0))
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        if quantized:
            if use_kernel:
                from ..kernels import ops
                from ..kernels.fused_optim import pack_scalars
                sc = pack_scalars(scale, lr_t, bc1, bc2)
                out = tree_map(
                    lambda p, g, mq, ms, vq, vs: ops.fused_adamw8(
                        p, g, mq, ms, vq, vs, sc, b1=b1, b2=b2, eps=eps,
                        wd=weight_decay),
                    params, grads, state["mu_q"], state["mu_s"],
                    state["nu_q"], state["nu_s"])
            else:
                from ..kernels.fused_optim import adamw8_ref
                out = tree_map(
                    lambda p, g, mq, ms, vq, vs: adamw8_ref(
                        p, g, mq, ms, vq, vs, scale, lr_t, bc1, bc2, b1, b2,
                        eps, weight_decay),
                    params, grads, state["mu_q"], state["mu_s"],
                    state["nu_q"], state["nu_s"])
            return (_unzip(out, params, 0),
                    {"count": c,
                     "mu_q": _unzip(out, params, 1),
                     "mu_s": _unzip(out, params, 2),
                     "nu_q": _unzip(out, params, 3),
                     "nu_s": _unzip(out, params, 4)})
        if use_kernel:
            from ..kernels import ops
            from ..kernels.fused_optim import pack_scalars
            sc = pack_scalars(scale, lr_t, bc1, bc2)
            out = tree_map(
                lambda p, g, m, v: ops.fused_adamw(p, g, m, v, sc, b1=b1,
                                                   b2=b2, eps=eps,
                                                   wd=weight_decay),
                params, grads, state["mu"], state["nu"])
        else:
            from ..kernels.fused_optim import adamw_ref
            out = tree_map(
                lambda p, g, m, v: adamw_ref(p, g, m, v, scale, lr_t, bc1,
                                             bc2, b1, b2, eps,
                                             weight_decay),
                params, grads, state["mu"], state["nu"])
        return (_unzip(out, params, 0),
                {"count": c, "mu": _unzip(out, params, 1),
                 "nu": _unzip(out, params, 2)})

    return Optimizer(init, step, "adamw")


def make_optimizer(name, lr, **kw):
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(name)


# ------------------------------------------------------------------ schedules
def cosine_schedule(base_lr, warmup_steps, total_steps, min_ratio=0.1):
    def sched(count):
        c = count.astype(jnp.float32)
        warm = c / jnp.maximum(1.0, warmup_steps)
        prog = jnp.clip((c - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(c < warmup_steps, warm, cos)
    return sched
