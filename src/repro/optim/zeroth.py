"""Zeroth-order / forward-gradient optimizers for the memory-aware baselines.

* FwdLLM [arXiv:2308.13894]: backprop-free fine-tuning via forward-mode
  directional derivatives (here the SPSA central-difference estimator with
  antithetic perturbations — activation-free like the paper's forward grads).
* FedKSeed [arXiv:2312.06353]: zeroth-order steps restricted to K shared
  random seeds; a client round is summarised by K scalar coefficients
  ("communication under 18 KB").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..utils.tree import tree_axpy, tree_map


def _perturbation(key, params):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    vs = [jax.random.normal(k, l.shape, jnp.float32) for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vs)


def spsa_grad(loss_fn, params, key, eps=1e-3, n_samples=1):
    """SPSA gradient estimate: mean over antithetic direction pairs.
    loss_fn: params -> scalar.  Two forward passes per sample, no backprop."""
    def one(key):
        v = _perturbation(key, params)
        lp = loss_fn(tree_axpy(eps, v, params))
        lm = loss_fn(tree_axpy(-eps, v, params))
        coeff = (lp - lm) / (2 * eps)
        return tree_map(lambda u: coeff * u, v), coeff

    keys = jax.random.split(key, n_samples)
    grads, coeffs = jax.vmap(one)(keys)
    g = tree_map(lambda u: jnp.mean(u, axis=0), grads)
    return g, coeffs


def kseed_coeffs(loss_fn, params, seeds, eps=1e-3):
    """FedKSeed client step: for each of K fixed seeds, estimate the
    directional derivative.  Returns (K,) coefficients — the entire client
    upload."""
    def one(seed):
        v = _perturbation(jax.random.PRNGKey(seed), params)
        lp = loss_fn(tree_axpy(eps, v, params))
        lm = loss_fn(tree_axpy(-eps, v, params))
        return (lp - lm) / (2 * eps)

    return jnp.stack([one(int(s)) for s in seeds])


def kseed_apply(params, seeds, coeffs, lr):
    """Server/client replay: θ ← θ − lr Σ_k c_k v_k (seed-reconstructed)."""
    for s, c in zip(seeds, coeffs):
        v = _perturbation(jax.random.PRNGKey(int(s)), params)
        params = tree_axpy(-lr * c, v, params)
    return params
