"""Zeroth-order / forward-gradient estimators for the backprop-free grad
programs (``repro.fed.strategies.GRAD_PROGRAMS``).

* FwdLLM [arXiv:2308.13894]: backprop-free fine-tuning via forward-mode
  directional derivatives (here the SPSA central-difference estimator with
  antithetic perturbations, vectorized over perturbation samples with
  ``vmap`` — activation-free like the paper's forward grads).
* FedKSeed [arXiv:2312.06353]: zeroth-order steps restricted to K shared
  random seeds; a client round is summarised by K scalar coefficients
  ("communication under 18 KB").  ``kseed_directional`` is the traceable
  per-client estimator (``lax.scan`` over the seed axis keeps a single
  perturbation live at a time — the method's memory frugality survives the
  trace); ``kseed_apply`` is the one-shot server-side materialization.

Everything here is jit/vmap-compatible: the federated engine calls these
inside its batched cohort step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..utils.tree import tree_axpy, tree_map


def _perturbation(key, params):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    vs = [jax.random.normal(k, l.shape, jnp.float32) for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vs)


def spsa_value_and_grad(loss_fn, params, key, eps=1e-3, n_samples=1):
    """SPSA estimate of (loss, grad): mean over antithetic direction pairs,
    vectorized over the sample axis.  ``loss_fn: params -> scalar``.  Two
    forward passes per sample, no backprop; the returned loss is the mean of
    the central pair evaluations — ``loss(params) + O(eps²)``, so no extra
    forward pass is spent on reporting."""
    def one(key):
        v = _perturbation(key, params)
        lp = loss_fn(tree_axpy(eps, v, params))
        lm = loss_fn(tree_axpy(-eps, v, params))
        coeff = (lp - lm) / (2 * eps)
        return tree_map(lambda u: coeff * u, v), coeff, (lp + lm) / 2

    keys = jax.random.split(key, n_samples)
    grads, coeffs, losses = jax.vmap(one)(keys)
    g = tree_map(lambda u: jnp.mean(u, axis=0), grads)
    return jnp.mean(losses), g, coeffs


def forward_value_and_grad(loss_fn, params, key, n_samples=1):
    """True forward-mode estimate of (loss, grad): for each random direction
    v, ``jax.jvp`` evaluates the *exact* directional derivative ⟨∇loss, v⟩
    in one forward pass (no finite-difference bias, no eps knob), and the
    gradient estimate is the mean of ⟨∇loss, v⟩·v over ``n_samples``
    directions — FwdLLM's actual forward-gradient estimator, vs the SPSA
    central-difference surrogate which matches its memory profile only.
    Directions are drawn exactly like ``spsa_value_and_grad`` (same key →
    same perturbations), so on a quadratic the two agree to float precision
    (central differences are exact there)."""
    def one(k):
        v = _perturbation(k, params)
        loss, dl = jax.jvp(loss_fn, (params,), (v,))
        return tree_map(lambda u: dl * u, v), dl, loss

    keys = jax.random.split(key, n_samples)
    grads, coeffs, losses = jax.vmap(one)(keys)
    g = tree_map(lambda u: jnp.mean(u, axis=0), grads)
    return jnp.mean(losses), g, coeffs


def spsa_grad(loss_fn, params, key, eps=1e-3, n_samples=1):
    """Gradient-only view of ``spsa_value_and_grad`` (legacy signature)."""
    _, g, coeffs = spsa_value_and_grad(loss_fn, params, key, eps=eps,
                                       n_samples=n_samples)
    return g, coeffs


def kseed_directional(loss_fn, params, seeds, eps=1e-3):
    """FedKSeed client estimator: directional derivative along each of the K
    fixed seed-reconstructed directions.  ``seeds`` is a (K,) int array —
    traced, so one compilation serves any seed set; ``lax.scan`` over the
    seed axis keeps one perturbation live at a time.  Returns ((K,) coeffs —
    the entire client upload — and the mean central loss estimate)."""
    def one(_, s):
        v = _perturbation(jax.random.PRNGKey(s), params)
        lp = loss_fn(tree_axpy(eps, v, params))
        lm = loss_fn(tree_axpy(-eps, v, params))
        return None, ((lp - lm) / (2 * eps), (lp + lm) / 2)

    _, (coeffs, losses) = jax.lax.scan(one, None,
                                       jnp.asarray(seeds, jnp.int32))
    return coeffs, jnp.mean(losses)


def kseed_coeffs(loss_fn, params, seeds, eps=1e-3):
    """Legacy list-of-seeds wrapper around ``kseed_directional``."""
    coeffs, _ = kseed_directional(loss_fn, params, seeds, eps=eps)
    return coeffs


def kseed_apply(params, seeds, coeffs, lr):
    """Server/client replay: θ ← θ − lr Σ_k c_k v_k (seed-reconstructed).
    The perturbation for seed k depends on the *tree structure* of ``params``
    — materialization must use the same structure the coefficients were
    estimated on (see ``FedKSeed.commit_trainable``)."""
    for s, c in zip(seeds, coeffs):
        v = _perturbation(jax.random.PRNGKey(int(s)), params)
        params = tree_axpy(-lr * c, v, params)
    return params
