"""Durable run state for the event-driven federation runtime (ISSUE 7).

A checkpoint is everything a *freshly constructed, identically configured*
``FedScheduler`` needs to continue a run **bit-identically** to the
uninterrupted one:

* scheduler loop state — virtual clock, model version, dispatch counter,
  fault/backoff tallies, the adaptive-deadline latency window, and where
  the loop is (``_round`` for sync/semisync, ``_done`` for async);
* the in-flight entries a crash would otherwise lose — the async event
  heap, the partial FedBuff buffer and the semisync carry set, each
  ``_Pending`` row pointing into a **deduplicated** table of stacked
  dispatch buckets (entries sharing a bucket share one decoded tree on
  restore, which preserves the ``is``-identity fast path in
  ``_stack_updates``) and of ``TrainablePlan``s (restored plans are
  hash-equal to freshly built ones, so no jit cache entry is ever added
  by a resume);
* ``Strategy.state_dict`` — trainable leaves, stage machine, DP accountant
  and adaptive clip;
* every host RNG the run consumes — the sim's sampling generator and each
  client's minibatch sampler (PCG64 state round-trips through
  ``ckpt.io.save_state``'s big-int encoding).

What is deliberately **not** here: static config (arch/chain/fed,
DP/secure/fault settings, availability traces) — the caller rebuilds those
identically and ``load_scheduler_state`` validates the load-bearing ones
via the ``meta`` block; jit caches (recompiled once per process — restoring
never adds *extra* entries); per-client round-time caches (recomputed
deterministically); and secure-aggregation sessions — checkpoints fall on
commit boundaries where no masking session is open, and ``save`` refuses
an in-flight session outright.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from ..core.adapters import ActiveAdapters, AdapterSegment
from ..ckpt.io import load_state, save_state
from .engine import RoundMetrics
from .runtime import _Pending
from .strategies import TrainablePlan


# ------------------------------------------------------------------- plans
def plan_state(plan: TrainablePlan) -> dict:
    """Field-wise encoding of a (hashable) plan.  ``grad_cfg`` keeps its
    nested-tuple form (``save_state`` preserves tuples), so the restored
    plan hashes — and jit-cache-keys — identically to a fresh one."""
    ad = plan.adapters
    return {
        "adapters": None if ad is None else {
            "n_layers": int(ad.n_layers),
            "segments": [[s.name, int(s.start), int(s.stop), s.role]
                         for s in ad.segments]},
        "train_head": plan.train_head,
        "train_embedding": plan.train_embedding,
        "layer_masked": plan.layer_masked,
        "rank_masked": plan.rank_masked,
        "loss": plan.loss,
        "lam": plan.lam,
        "remat": plan.remat,
        "grad": plan.grad,
        "grad_cfg": plan.grad_cfg,
        "transform": plan.transform,
        "opt_bits": plan.opt_bits,
    }


def plan_from_state(d: dict) -> TrainablePlan:
    ad = d["adapters"]
    adapters = None if ad is None else ActiveAdapters(
        ad["n_layers"],
        tuple(AdapterSegment(s[0], s[1], s[2], s[3])
              for s in ad["segments"]))
    return TrainablePlan(
        adapters=adapters, train_head=d["train_head"],
        train_embedding=d["train_embedding"],
        layer_masked=d["layer_masked"], rank_masked=d["rank_masked"],
        loss=d["loss"], lam=d["lam"], remat=d["remat"], grad=d["grad"],
        grad_cfg=d["grad_cfg"], transform=d["transform"],
        opt_bits=d.get("opt_bits"))  # absent in pre-ISSUE-10 checkpoints


# ----------------------------------------------------------- pending rows
def _pending_state(e: _Pending, plan_ix, bucket_ix) -> dict:
    if e.session is not None:
        raise ValueError(
            "in-flight secure-aggregation masking sessions are not "
            "checkpointable; checkpoints fall on commit boundaries where "
            "no session is open")
    return {"finish": float(e.finish),
            "cid": None if e.client is None else int(e.client.cid),
            "plan": plan_ix, "bucket": bucket_ix, "bi": int(e.bi),
            "masks": e.masks, "weight": float(e.weight),
            "version": int(e.version), "seq": int(e.seq), "loss": e.loss,
            "start": float(e.start), "failed": bool(e.failed),
            "retry": int(e.retry)}


def _pending_from_state(d: dict, plans, buckets, clients) -> _Pending:
    return _Pending(
        finish=d["finish"],
        client=None if d["cid"] is None else clients[d["cid"]],
        plan=None if d["plan"] is None else plans[d["plan"]],
        bucket=None if d["bucket"] is None else buckets[d["bucket"]],
        bi=d["bi"], masks=d["masks"], weight=d["weight"],
        version=d["version"], seq=d["seq"], loss=d["loss"],
        start=d["start"], failed=d["failed"], retry=d["retry"])


# -------------------------------------------------------------------- sim
def _sim_state(sim) -> dict:
    """The testbed's mutable pieces: the server-side sampling generator and
    — eager path — each client's minibatch sampler (generator + epoch
    permutation + cursor).  Shards, budgets and profiles are derived
    deterministically at construction and never mutate.  The lazy path has
    no client list: its durable state is the pool *cursor* (per-cid visit
    counts) — samplers are visit-seeded, so replaying a visit reproduces
    its draws without storing any sampler state."""
    if sim.lazy:
        return {"rng": sim.rng.bit_generator.state,
                "pool": sim.pool.state_dict()}
    return {"rng": sim.rng.bit_generator.state,
            "samplers": [
                {"rng": c.sampler.rng.bit_generator.state,
                 "order": np.asarray(c.sampler._order),
                 "pos": int(c.sampler._pos)}
                for c in sim.clients]}


def _load_sim_state(sim, s: dict) -> None:
    sim.rng.bit_generator.state = s["rng"]
    if sim.lazy:
        if "pool" not in s:
            raise ValueError(
                "checkpoint was taken from an eager (materialized) sim but "
                "this run is configured lazy — config mismatch")
        sim.pool.load_state_dict(s["pool"])
        return
    if "samplers" not in s:
        raise ValueError(
            "checkpoint was taken from a lazy ClientPool sim but this run "
            "is configured eager — config mismatch")
    if len(s["samplers"]) != len(sim.clients):
        raise ValueError(
            f"checkpoint has {len(s['samplers'])} client samplers but the "
            f"sim has {len(sim.clients)} clients — config mismatch")
    for c, cs in zip(sim.clients, s["samplers"]):
        c.sampler.rng.bit_generator.state = cs["rng"]
        c.sampler._order = np.asarray(cs["order"])
        c.sampler._pos = int(cs["pos"])


# -------------------------------------------------------------- scheduler
def scheduler_state(sched) -> dict:
    plans, plan_ix = [], {}
    buckets, bucket_ix = [], {}

    def pref(p):
        if p is None:
            return None
        if p not in plan_ix:
            plan_ix[p] = len(plans)
            plans.append(p)
        return plan_ix[p]

    def bref(b):
        if b is None:
            return None
        k = id(b)
        if k not in bucket_ix:
            bucket_ix[k] = len(buckets)
            buckets.append(b)
        return bucket_ix[k]

    def rows(es):
        return [_pending_state(e, pref(e.plan), bref(e.bucket)) for e in es]

    # reference the tables *before* emitting them: rows() populates both
    heap = rows(sched._heap)
    buffered = rows(sched._buffered)
    carried = rows(sched._carried)
    return {
        "meta": {"mode": sched.mode,
                 "strategy": sched.strategy.name,
                 "n_clients": int(sched.sim.fed.n_clients),
                 "clients_per_round": int(sched.sim.fed.clients_per_round),
                 "seed": int(sched.sim.fed.seed),
                 "bucket_pad": int(sched.bucket_pad),
                 "concurrency": int(sched.concurrency),
                 "buffer_size": int(sched.buffer_size),
                 "lazy": bool(sched.sim.lazy),
                 "pad_policy": sched.pad_policy,
                 "n_silos": (int(sched.topology.n_silos)
                             if sched.topology is not None else 1)},
        "spec": (sched.spec.to_dict() if sched.spec is not None else None),
        "sched": {"clock": float(sched.clock),
                  "version": int(sched.version),
                  "seq": int(sched._seq),
                  "committed_updates": int(sched.committed_updates),
                  "fault_dropouts": int(sched.fault_dropouts),
                  "trace_dropouts": int(sched.trace_dropouts),
                  "silo_dropouts": int(sched.silo_dropouts),
                  "events": int(sched.events),
                  "tier_bytes": {k: int(v)
                                 for k, v in sched.tier_bytes.items()},
                  "redispatches": int(sched.redispatches),
                  "backoff_retries": int(sched.backoff_retries),
                  "round": int(sched._round),
                  "done": int(sched._done),
                  "started": bool(sched._started),
                  "async_seeded": bool(sched._async_seeded),
                  "lat_window": [float(x) for x in sched._lat_window]},
        "silo": (sched._silo.state_dict()
                 if sched._silo is not None else None),
        "plans": [plan_state(p) for p in plans],
        "buckets": buckets,
        "heap": heap, "buffered": buffered, "carried": carried,
        "history": [dataclasses.asdict(m) for m in sched._history],
        "strategy": sched.strategy.state_dict(),
        "sim": _sim_state(sched.sim),
    }


def _check(meta, key, got):
    if meta[key] != got:
        raise ValueError(
            f"checkpoint/scheduler mismatch on {key!r}: checkpoint has "
            f"{meta[key]!r}, this run is configured with {got!r}")


def _check_spec(sched, s: dict) -> None:
    """Whole-configuration validation (ISSUE 8): a checkpoint written under
    the spec API refuses to resume into a scheduler whose spec differs on
    *any* field — not just the load-bearing handful in ``meta``."""
    saved = s.get("spec")
    if saved is None or sched.spec is None:
        return
    from .spec import ExperimentSpec
    mismatch = sched.spec.diff(ExperimentSpec.from_dict(saved))
    if mismatch:
        lines = "; ".join(f"{k}: checkpoint={a!r}, run={b!r}"
                          for k, (a, b) in sorted(mismatch.items()))
        raise ValueError(
            f"checkpoint spec mismatch — refusing to resume ({lines})")


def load_scheduler_state(sched, s: dict) -> None:
    meta = s["meta"]
    for key, got in (("mode", sched.mode),
                     ("strategy", sched.strategy.name),
                     ("n_clients", int(sched.sim.fed.n_clients)),
                     ("clients_per_round",
                      int(sched.sim.fed.clients_per_round)),
                     ("seed", int(sched.sim.fed.seed))):
        _check(meta, key, got)
    # PR-8 meta keys — guarded so pre-hierarchy checkpoints still load
    if "lazy" in meta:
        _check(meta, "lazy", bool(sched.sim.lazy))
    if "pad_policy" in meta:
        _check(meta, "pad_policy", sched.pad_policy)
    if "n_silos" in meta:
        _check(meta, "n_silos", int(sched.topology.n_silos)
               if sched.topology is not None else 1)
    _check_spec(sched, s)
    plans = [plan_from_state(d) for d in s["plans"]]
    buckets = s["buckets"]
    sc = s["sched"]
    if sched.sim.lazy:
        # the pool cursor must restore *before* in-flight entries rehydrate:
        # peek() re-synthesizes each pending client at the visit its
        # pre-crash dispatch already advanced to
        _load_sim_state(sched.sim, s["sim"])
        pool = sched.sim.pool

        class _LazyClients:
            def __getitem__(self, cid):
                return pool.peek(cid)
        clients = _LazyClients()
    else:
        clients = {c.cid: c for c in sched.sim.clients}
    sched.clock = float(sc["clock"])
    sched.version = int(sc["version"])
    sched._seq = int(sc["seq"])
    sched.committed_updates = int(sc["committed_updates"])
    sched.fault_dropouts = int(sc["fault_dropouts"])
    sched.trace_dropouts = int(sc["trace_dropouts"])
    sched.silo_dropouts = int(sc.get("silo_dropouts", 0))
    sched.events = int(sc.get("events", 0))
    sched.tier_bytes = {k: int(v)
                        for k, v in sc.get("tier_bytes",
                                           {"edge": 0, "silo": 0}).items()}
    if s.get("silo") is not None:
        if sched._silo is None:
            raise ValueError(
                "checkpoint carries cross-silo tier state but this run is "
                "configured flat — config mismatch")
        sched._silo.load_state_dict(s["silo"])
    sched.redispatches = int(sc["redispatches"])
    sched.backoff_retries = int(sc["backoff_retries"])
    sched._round = int(sc["round"])
    sched._done = int(sc["done"])
    sched._started = bool(sc["started"])
    sched._async_seeded = bool(sc["async_seeded"])
    sched._lat_window = deque(sc["lat_window"],
                              maxlen=sched._lat_window.maxlen)
    # the serialized heap is a valid heapq list verbatim — restoring its
    # order reproduces the exact pop sequence
    sched._heap = [_pending_from_state(d, plans, buckets, clients)
                   for d in s["heap"]]
    sched._buffered = [_pending_from_state(d, plans, buckets, clients)
                       for d in s["buffered"]]
    sched._carried = [_pending_from_state(d, plans, buckets, clients)
                      for d in s["carried"]]
    sched._history = [RoundMetrics(**d) for d in s["history"]]
    sched.strategy.load_state_dict(s["strategy"])
    if not sched.sim.lazy:   # lazy restored first (pool cursor before peek)
        _load_sim_state(sched.sim, s["sim"])


# ------------------------------------------------------------------- files
def save_run(sched, path):
    """Atomic (write-tmp-then-rename) full-run checkpoint."""
    return save_state(path, scheduler_state(sched))


def restore_run(sched, path) -> None:
    load_scheduler_state(sched, load_state(path))
