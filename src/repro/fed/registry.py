"""Unified strategy registry + high-level experiment entry point.

Every federated method — the 9 baselines and CHAINFED — registers itself
under a name; benchmarks, examples and the launcher construct strategies
exclusively through ``make_strategy`` (FedML-style config-driven dispatch).
Adding a new method is a ~50-line class plus one decorator; a plan is enough
for most — pick a loss hook, a gradient program (autodiff, SPSA
perturbation, K-seed zeroth-order — see ``GRAD_PROGRAMS``) and optionally a
trainable transform, and the batched cohort engine does the rest:

    from repro.fed.registry import register_strategy
    from repro.fed.strategies import Strategy

    @register_strategy("my_method")
    class MyMethod(Strategy):
        memory_method = "full_adapters"
        def plan(self, client, round_idx):
            ...

``run_experiment`` is the one-call path from (arch, dataset, strategy name)
to a trained strategy + round metrics.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

_REGISTRY: Dict[str, Tuple[type, dict]] = {}
_BUILTINS_LOADED = False


def register_strategy(name: str, **defaults) -> Callable[[type], type]:
    """Class decorator: register a Strategy under ``name``.  ``defaults``
    are keyword arguments merged (overridably) into every construction —
    used e.g. for registered ablation variants of one class."""

    def deco(cls):
        if name in _REGISTRY and _REGISTRY[name][0] is not cls:
            raise ValueError(f"strategy {name!r} already registered "
                             f"to {_REGISTRY[name][0].__name__}")
        if getattr(cls, "name", "base") == "base":
            cls.name = name     # aliases keep the class's primary name
        _REGISTRY[name] = (cls, dict(defaults))
        return cls

    return deco


def _ensure_builtins():
    """Built-in strategies register on import; load them lazily so the
    registry module itself stays import-cycle-free."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    from . import baselines  # noqa: F401  (registers the 9 baselines)
    from . import chainfed   # noqa: F401  (registers chainfed + ablations)


def available_strategies() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)


# the strategy constructor's positional contract — everything else is a
# spec knob the introspection surfaces and make_strategy validates
_CTOR_ARGS = ("self", "cfg", "chain", "key")


def _strategy_options(cls) -> dict:
    """``{knob: default}`` accepted by ``cls``'s constructor beyond the
    positional (cfg, chain, key) contract.  Empty dict when the constructor
    takes **kwargs (options cannot be enumerated)."""
    import inspect
    sig = inspect.signature(cls.__init__)
    opts = {}
    for p in sig.parameters.values():
        if p.name in _CTOR_ARGS or p.kind in (p.VAR_POSITIONAL,
                                              p.VAR_KEYWORD):
            continue
        opts[p.name] = (None if p.default is inspect.Parameter.empty
                        else p.default)
    return opts


def _accepts_var_kwargs(cls) -> bool:
    import inspect
    return any(p.kind is p.VAR_KEYWORD
               for p in inspect.signature(cls.__init__).parameters.values())


def describe_strategy(name: str) -> dict:
    """Introspect one registered strategy: its spec knobs (constructor
    options + registered-variant defaults), the gradient programs it can
    run, and its memory/aggregation posture."""
    _ensure_builtins()
    if name not in _REGISTRY:
        raise KeyError(_unknown_strategy_msg(name))
    cls, defaults = _REGISTRY[name]
    doc = (cls.__doc__ or "").strip().splitlines()
    return {
        "name": name,
        "class": cls.__name__,
        "summary": doc[0] if doc else "",
        "memory_method": cls.memory_method,
        "grad_programs": tuple(getattr(cls, "grad_programs", ("ad",))),
        "aggregator": cls.aggregator,
        "secure_compatible": bool(cls.secure_compatible),
        "options": _strategy_options(cls),
        "defaults": dict(defaults),
    }


def list_strategies() -> List[dict]:
    """``describe_strategy`` for every registered name — the registry's
    introspection surface (``launch.train --list-strategies`` renders it)."""
    return [describe_strategy(n) for n in available_strategies()]


def _unknown_strategy_msg(name: str) -> str:
    import difflib
    msg = (f"unknown strategy {name!r}; available: "
           f"{', '.join(sorted(_REGISTRY))}")
    close = difflib.get_close_matches(name, list(_REGISTRY), n=2)
    if close:
        msg += f" — did you mean {' or '.join(map(repr, close))}?"
    return msg


def make_strategy(name: str, cfg, chain, key, **opts):
    """Construct a registered strategy.  ``opts`` override the registered
    defaults and are passed to the class constructor; unknown option names
    are rejected with a did-you-mean suggestion instead of silently
    swallowed (or exploding inside the constructor)."""
    _ensure_builtins()
    if name not in _REGISTRY:
        raise KeyError(_unknown_strategy_msg(name))
    cls, defaults = _REGISTRY[name]
    merged = {**defaults, **opts}
    if merged and not _accepts_var_kwargs(cls):
        import difflib
        valid = _strategy_options(cls)
        unknown = sorted(set(merged) - set(valid))
        if unknown:
            hints = []
            for u in unknown:
                close = difflib.get_close_matches(u, list(valid), n=1)
                hints.append(f"{u!r}" + (f" (did you mean {close[0]!r}?)"
                                         if close else ""))
            raise TypeError(
                f"strategy {name!r} got unknown option(s): "
                f"{', '.join(hints)}; accepted: "
                f"{', '.join(sorted(valid)) or '(none)'}")
    return cls(cfg, chain, key, **merged)


# ============================================================== experiments
@dataclasses.dataclass
class ExperimentResult:
    strategy: object
    sim: object
    history: list           # List[RoundMetrics]
    scheduler: object = None  # the FedScheduler (None on the legacy path)

    @property
    def best_acc(self) -> float:
        return max((h.acc for h in self.history), default=0.0)

    @property
    def final_acc(self) -> float:
        return self.history[-1].acc if self.history else 0.0


def run_experiment(strategy: Optional[str] = None, *, spec=None,
                   cfg=None, arch: str = "bert_tiny",
                   chain=None, fed=None, task: str = "classification",
                   dataset: str = "agnews", batch_size: int = 8,
                   rounds: int = 20, eval_every: int = 5, seed: int = 0,
                   memory_constrained: bool = True, pretrain_steps: int = 0,
                   params=None, sim=None, verbose: bool = False,
                   strategy_opts: Optional[dict] = None,
                   mode: str = "sync",
                   scheduler_opts: Optional[dict] = None,
                   dp=None, secure_agg=None, compress=None,
                   aggregator: Optional[str] = None,
                   aggregator_opts: Optional[dict] = None,
                   faults=None, trace=None,
                   lazy: bool = False, shard_size: Optional[int] = None,
                   checkpoint_every: Optional[int] = None,
                   checkpoint_path=None, resume=None,
                   halt_after: Optional[int] = None) -> ExperimentResult:
    """High-level entry point: build (or accept) the federated testbed, make
    the named strategy, optionally swap in a pretrained base, run rounds.

    **Preferred calling convention (ISSUE 8):** pass a declarative
    ``spec=ExperimentSpec(...)`` (``repro.fed.spec``) instead of the loose
    config kwargs — the spec serializes, embeds in checkpoints (``resume``
    then validates the *whole* configuration) and reproduces the exact
    results of the equivalent kwargs/flag invocation.  With ``spec=`` the
    only other accepted arguments are the live-object overrides
    (``cfg``/``chain``/``fed``/``params``/``sim``) and the invocation-level
    knobs (``verbose``, ``checkpoint_every``/``checkpoint_path``/
    ``resume``/``halt_after``); loose config kwargs still work without a
    spec but are deprecated and warn.

    ``sim``/``params`` short-circuit testbed construction so benchmarks can
    share one pretrained base across methods; ``pretrain_steps`` > 0 LM-
    pretrains a base on the task corpus when ``params`` is not given.

    ``mode`` selects the event-driven runtime's aggregation mode
    (``"sync"`` — the legacy lockstep protocol — ``"semisync"`` or
    ``"async"``; see ``repro.fed.runtime.FedScheduler``), and
    ``scheduler_opts`` forwards its knobs (``buffer_size``, ``concurrency``,
    ``deadline_quantile``, ``straggler``, ``bucket_pad``, ...).  In async
    mode ``rounds`` counts server commits.

    Privacy & robustness (``repro.fed.privacy`` / ``repro.fed.faults``):

    * ``dp`` — a ``DPConfig`` (or its kwargs as a dict) enables client-level
      DP-FedAvg; per-round ε lands in ``RoundMetrics.dp_epsilon``.
    * ``secure_agg`` — ``True``, a ``SecureAggConfig``, or its kwargs:
      pairwise-masked aggregation (sync/semisync only).
    * ``aggregator`` (+ ``aggregator_opts``) — a registered robust
      aggregation (``trimmed_mean``, ``median``, ``norm_clip``) replacing
      weighted FedAvg for strategies without a bespoke one.
    * ``faults`` — a ``ClientBehavior`` (or its kwargs): dropout/byzantine/
      straggler injection; needs ``mode`` semisync or async.
    * ``trace`` — an ``AvailabilityTrace`` or a ``{"kind": "diurnal"|
      "flaky", ...}`` dict (``repro.data.partition.make_trace`` kwargs):
      replayable client availability replacing Bernoulli dropout.

    Crash tolerance (``repro.fed.checkpoint``): ``checkpoint_every`` +
    ``checkpoint_path`` persist the full run state every N rounds/commits;
    ``resume`` restores such a checkpoint into the freshly built run before
    driving it (pass the *same* ``rounds``); ``halt_after`` stops the loop
    after that unit — the crash-simulation hook the resume-equality tests
    use.  Any of these forces the event-driven scheduler even in sync mode.
    """
    import warnings

    import jax
    import numpy as np

    from ..configs import get_config
    from ..data.synthetic import (DATASETS, classification_batch, lm_batch,
                                  make_classification, make_instruction)
    from ..models.config import ChainConfig, FedConfig
    from .engine import FedSim
    from . import spec as spec_mod

    topology = (scheduler_opts or {}).get("topology")
    if spec is not None:
        if strategy is not None:
            raise TypeError(
                "pass either spec= or the legacy strategy/config kwargs, "
                "not both")
        r = spec.run
        strategy, task, dataset = r.strategy, r.task, r.dataset
        batch_size, rounds, eval_every = r.batch_size, r.rounds, r.eval_every
        seed, memory_constrained = r.seed, r.memory_constrained
        pretrain_steps = r.pretrain_steps
        strategy_opts = spec_mod.thaw_opts(r.strategy_opts) or None
        lazy, shard_size = r.lazy, r.shard_size
        s_cfg, s_chain, s_fed = spec_mod.build_configs(spec)
        cfg = cfg if cfg is not None else s_cfg
        chain = chain if chain is not None else s_chain
        fed = fed if fed is not None else s_fed
        mode = spec.schedule.mode
        scheduler_opts = spec_mod.build_scheduler_opts(spec)
        dp = spec_mod.build_dp(spec)
        secure_agg = spec.privacy.secure_agg or None
        aggregator = spec.faults.aggregator
        aggregator_opts = (spec_mod.thaw_opts(spec.faults.aggregator_opts)
                           or None)
        faults = spec_mod.build_faults(spec)
        trace = spec_mod.build_trace(spec)
        topology = spec_mod.build_topology(spec)
        compress = spec_mod.build_compression(spec)
    else:
        if strategy is None:
            raise TypeError("run_experiment needs a strategy name or spec=")
        warnings.warn(
            "kwargs-style run_experiment is deprecated: build a declarative "
            "repro.fed.spec.ExperimentSpec and call "
            "run_experiment(spec=...) — loose config kwargs will be removed "
            "next release", DeprecationWarning, stacklevel=2)
        # best-effort spec for checkpoint embedding (None when the kwargs
        # carry live objects a spec cannot represent)
        spec = (None if (cfg is not None or sim is not None
                         or params is not None)
                else spec_mod.spec_from_kwargs(
                    strategy, arch=arch, task=task, dataset=dataset,
                    batch_size=batch_size, rounds=rounds,
                    eval_every=eval_every, seed=seed,
                    memory_constrained=memory_constrained,
                    pretrain_steps=pretrain_steps,
                    strategy_opts=strategy_opts, mode=mode,
                    scheduler_opts=scheduler_opts, dp=dp,
                    secure_agg=secure_agg, compress=compress,
                    aggregator=aggregator,
                    aggregator_opts=aggregator_opts, faults=faults,
                    trace=trace, chain=chain, fed=fed, lazy=lazy,
                    shard_size=shard_size))

    cfg = cfg if cfg is not None else get_config(arch)
    chain = chain if chain is not None else ChainConfig()
    fed = fed if fed is not None else FedConfig()

    if sim is None:
        if task == "classification":
            dspec = dataclasses.replace(DATASETS[dataset],
                                        vocab=cfg.vocab_size)
            tokens, labels = make_classification(dspec)
            # host arrays: jit converts on call; cohort_batches stacks
            # host-side with one device transfer per leaf
            batch_fn = lambda idx: classification_batch(dspec, tokens,
                                                        labels, idx)
        elif task == "instruction":
            tokens, labels2d = make_instruction(vocab=cfg.vocab_size)
            labels = np.zeros(len(tokens), np.int64)
            batch_fn = lambda idx: lm_batch(tokens, labels2d, idx)
        else:
            raise ValueError(f"unknown task {task!r}")
        sim = FedSim(cfg, fed, tokens, labels, batch_fn,
                     batch_size=batch_size,
                     memory_constrained=memory_constrained,
                     lazy=lazy, shard_size=shard_size)

    strat = make_strategy(strategy, cfg, chain, jax.random.PRNGKey(seed),
                          **(strategy_opts or {}))
    if params is None and pretrain_steps > 0:
        from ..train.pretrain import pretrained_base
        params = pretrained_base(cfg, sim.tokens, steps=pretrain_steps)
    if params is not None:
        strat.params = params

    if aggregator is not None:
        from .strategies import make_aggregator
        make_aggregator(aggregator, **(aggregator_opts or {}))  # validate
        strat.aggregator = aggregator
        strat.aggregator_opts = dict(aggregator_opts or {})
    if dp is not None:
        from .privacy import DPConfig, enable_dp
        enable_dp(strat, DPConfig(**dp) if isinstance(dp, dict) else dp)
    if secure_agg:
        from .privacy import SecureAggConfig, enable_secure_agg
        sa = (SecureAggConfig() if secure_agg is True
              else SecureAggConfig(**secure_agg)
              if isinstance(secure_agg, dict) else secure_agg)
        if not sa.cohort:
            sa = dataclasses.replace(sa, cohort=sim.fed.clients_per_round)
        enable_secure_agg(strat, sa)
    if compress is not None:
        from .compress import CompressionConfig, enable_compression
        enable_compression(strat, CompressionConfig(**compress)
                           if isinstance(compress, dict) else compress)
    if faults is not None:
        from .faults import ClientBehavior
        fb = (ClientBehavior(**faults) if isinstance(faults, dict)
              else faults)
        scheduler_opts = {**(scheduler_opts or {}), "faults": fb}
    if trace is not None:
        if isinstance(trace, dict):
            from ..data.partition import make_trace
            tkw = dict(trace)
            trace = make_trace(tkw.pop("kind"), fed.n_clients, **tkw)
        scheduler_opts = {**(scheduler_opts or {}), "trace": trace}

    # one driver code path (ISSUE 8): every run — including plain sync —
    # goes through the event-driven scheduler, whose sync mode reproduces
    # the legacy run_rounds protocol bit-identically
    from .runtime import FedScheduler
    so = dict(scheduler_opts or {})
    if topology is not None:
        so["topology"] = topology
    sched = FedScheduler(sim, strat, mode=mode, **so)
    sched.spec = spec
    if resume is not None:
        sched.restore(resume)
    history = sched.run(rounds, eval_every=eval_every, verbose=verbose,
                        checkpoint_every=checkpoint_every,
                        checkpoint_path=checkpoint_path,
                        halt_after=halt_after)
    return ExperimentResult(strat, sim, history, sched)
