"""Fault injection and byzantine-robust aggregation.

`ClientBehavior` describes how a population misbehaves; the event-driven
``FedScheduler`` consults a `FaultModel` at dispatch time:

* **dropout** — the client fails mid-round; its completion event is replaced
  by a timeout event on the same heap (the server learns of the failure at
  ``timeout_factor ×`` the expected round time).  Async mode re-dispatches a
  replacement client on the same heap; semisync excludes the entry from the
  wave commit (exercising secure-agg dropout recovery when masking is on).
* **byzantine** — a fixed subset of clients (``byzantine_frac`` of the
  population, chosen once from the behavior seed) scales its genuine update
  by ``byzantine_scale`` (negative = sign flip) before upload.  Applied as
  one jitted per-bucket scale-vector multiply — shape-stable, so the
  no-recompile guarantee of the event loop holds.
* **straggler** — intermittent slowdown: with ``straggler_prob`` a round
  takes ``straggler_factor ×`` its oracle latency.

All draws are deterministic per ``(seed, cid, dispatch seq)`` — replaying a
run replays its faults.

The robust aggregators (trimmed mean, coordinate median, norm-clip) register
in the strategy-level ``AGGREGATORS`` registry and drop into the same fused
aggregation seam as weighted FedAvg (``Strategy.aggregator = "trimmed_mean"``
or ``run_experiment(aggregator=...)``).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..utils.tree import tree_map
from .strategies import (cohort_fedavg, cohort_norms, register_aggregator,
                         scale_cohort)


# ============================================================ client faults
@dataclasses.dataclass(frozen=True)
class ClientBehavior:
    """Population misbehavior knobs (all probabilities per dispatch)."""
    dropout_prob: float = 0.0
    byzantine_frac: float = 0.0
    byzantine_scale: float = -10.0
    straggler_prob: float = 0.0
    straggler_factor: float = 4.0
    timeout_factor: float = 1.0   # failure detected at this × round time
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class FaultDraw:
    dropped: bool
    slowdown: float


class FaultModel:
    """Deterministic realization of a `ClientBehavior` over a population:
    the byzantine set is fixed once per run; dropout/straggler draws key off
    ``(seed, cid, seq)`` so every dispatch is independently — and
    reproducibly — faulty."""

    def __init__(self, behavior: ClientBehavior, n_clients: int):
        self.behavior = behavior
        n_byz = int(round(behavior.byzantine_frac * n_clients))
        if n_byz > 0:
            rng = np.random.default_rng((behavior.seed, 0xB52))
            self.byzantine = frozenset(
                int(c) for c in rng.choice(n_clients, n_byz, replace=False))
        else:
            self.byzantine = frozenset()

    def is_byzantine(self, cid: int) -> bool:
        return cid in self.byzantine

    def draw(self, cid: int, seq: int) -> FaultDraw:
        b = self.behavior
        rng = np.random.default_rng((b.seed, cid, seq))
        dropped = bool(rng.random() < b.dropout_prob)
        slow = b.straggler_factor if rng.random() < b.straggler_prob else 1.0
        return FaultDraw(dropped=dropped, slowdown=float(slow))

    def update_scales(self, cids) -> np.ndarray:
        """(C,) multiplier vector for a dispatch bucket — byzantine members
        get ``byzantine_scale``, honest ones 1.  Fed to one jitted
        ``scale_cohort`` so corruption costs no recompile."""
        s = self.behavior.byzantine_scale
        return np.asarray([s if self.is_byzantine(c) else 1.0 for c in cids],
                          np.float32)


# ======================================================= robust aggregators
def _trim_counts(cohort: int, trim: float) -> int:
    """Per-side trim count: ⌊trim·C⌋, capped so at least one row survives."""
    k = int(np.floor(trim * cohort))
    return min(k, (cohort - 1) // 2)


@register_aggregator("trimmed_mean")
def trimmed_mean(trim: float = 0.2):
    """Coordinate-wise trimmed mean: sort the cohort axis, drop the top and
    bottom ``⌊trim·C⌋`` values per coordinate, average the rest.  Ignores
    sample weights (robustness and weighting pull opposite ways — a
    byzantine client should not buy influence with a large dataset)."""
    def agg(trainable0, deltas, weights, masks):
        cohort = weights.shape[0]
        k = _trim_counts(cohort, trim)
        def red(t0, d):
            s = jnp.sort(d.astype(jnp.float32), axis=0)
            m = jnp.mean(s[k:cohort - k], axis=0)
            return (t0 + m).astype(t0.dtype)
        return tree_map(red, trainable0, deltas)
    return agg


@register_aggregator("median")
def coordinate_median():
    """Coordinate-wise median over the cohort axis."""
    def agg(trainable0, deltas, weights, masks):
        return tree_map(
            lambda t0, d: (t0 + jnp.median(d.astype(jnp.float32), axis=0)
                           ).astype(t0.dtype),
            trainable0, deltas)
    return agg


@register_aggregator("norm_clip")
def norm_clip(clip: float = 0.0):
    """Clip every client's update norm to ``clip`` (or, when 0, to the cohort's
    median norm — a scale-free default) and take the weighted FedAvg.
    Neutralizes magnitude attacks while keeping sample weighting."""
    def agg(trainable0, deltas, weights, masks):
        norms = cohort_norms(deltas)
        ref = jnp.float32(clip) if clip > 0 else jnp.median(norms)
        clipped = scale_cohort(deltas, jnp.minimum(1.0, ref / (norms + 1e-12)))
        return cohort_fedavg(trainable0, clipped, weights, masks)
    return agg
