"""Fault injection and byzantine-robust aggregation.

`ClientBehavior` describes how a population misbehaves; the event-driven
``FedScheduler`` consults a `FaultModel` at dispatch time:

* **dropout** — the client fails mid-round; its completion event is replaced
  by a timeout event on the same heap (the server learns of the failure at
  ``timeout_factor ×`` the expected round time).  Async mode re-dispatches a
  replacement client on the same heap; semisync excludes the entry from the
  wave commit (exercising secure-agg dropout recovery when masking is on).
* **byzantine** — a fixed subset of clients (``byzantine_frac`` of the
  population, chosen once from the behavior seed) scales its genuine update
  by ``byzantine_scale`` (negative = sign flip) before upload.  Applied as
  one jitted per-bucket scale-vector multiply — shape-stable, so the
  no-recompile guarantee of the event loop holds.
* **straggler** — intermittent slowdown: with ``straggler_prob`` a round
  takes ``straggler_factor ×`` its oracle latency.
* **model replacement** (ISSUE 7) — ``attack="replacement"``: instead of
  scaling its genuine update, a byzantine client uploads
  ``boost · (target − trainable₀)``, the classic targeted backdoor that
  steers the *aggregate* toward an attacker-chosen model in one round.
  Applied as one jitted shape-stable per-bucket blend, like scaling.
* **availability traces** (ISSUE 7) — when the model carries an
  `AvailabilityTrace` (``data.partition``), churn stops being a Bernoulli
  coin-flip: dispatch consults each client's online window at the virtual
  clock, a window closing mid-round fails the round at the cut time, and
  the scheduler retries with capped exponential backoff on the event heap.

All draws are deterministic per ``(seed, cid, dispatch seq)`` — replaying a
run replays its faults.

The robust aggregators (trimmed mean, coordinate median, norm-clip, and the
distance-based Krum / multi-Krum selectors) register in the strategy-level
``AGGREGATORS`` registry and drop into the same fused aggregation seam as
weighted FedAvg (``Strategy.aggregator = "trimmed_mean"`` or
``run_experiment(aggregator=...)``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..data.partition import AvailabilityTrace
from ..utils.tree import tree_map
from .strategies import (cohort_fedavg, cohort_norms, register_aggregator,
                         scale_cohort)


# ============================================================ client faults
@dataclasses.dataclass(frozen=True)
class ClientBehavior:
    """Population misbehavior knobs (all probabilities per dispatch)."""
    dropout_prob: float = 0.0
    byzantine_frac: float = 0.0
    byzantine_scale: float = -10.0
    straggler_prob: float = 0.0
    straggler_factor: float = 4.0
    timeout_factor: float = 1.0   # failure detected at this × round time
    attack: str = "scaling"       # "scaling" | "replacement"
    replace_boost: float = 4.0    # replacement attack: Δ = boost·(target−θ₀)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class FaultDraw:
    dropped: bool
    slowdown: float


class FaultModel:
    """Deterministic realization of a `ClientBehavior` over a population:
    the byzantine set is fixed once per run; dropout/straggler draws key off
    ``(seed, cid, seq)`` so every dispatch is independently — and
    reproducibly — faulty."""

    def __init__(self, behavior: ClientBehavior, n_clients: int,
                 trace: Optional[AvailabilityTrace] = None):
        if behavior.attack not in ("scaling", "replacement"):
            raise ValueError(f"unknown attack {behavior.attack!r}; "
                             "expected 'scaling' or 'replacement'")
        self.behavior = behavior
        self.trace = trace
        self._targets = {}           # replacement targets, cached per shape
        n_byz = int(round(behavior.byzantine_frac * n_clients))
        if n_byz > 0:
            rng = np.random.default_rng((behavior.seed, 0xB52))
            self.byzantine = frozenset(
                int(c) for c in rng.choice(n_clients, n_byz, replace=False))
        else:
            self.byzantine = frozenset()

    def is_byzantine(self, cid: int) -> bool:
        return cid in self.byzantine

    def draw(self, cid: int, seq: int) -> FaultDraw:
        b = self.behavior
        rng = np.random.default_rng((b.seed, cid, seq))
        dropped = bool(rng.random() < b.dropout_prob)
        slow = b.straggler_factor if rng.random() < b.straggler_prob else 1.0
        return FaultDraw(dropped=dropped, slowdown=float(slow))

    # ------------------------------------------------------- availability
    def available(self, cid: int, t: float) -> bool:
        """Is this client reachable at virtual time ``t``?  Always true
        without a trace (legacy Bernoulli churn handles failures)."""
        return self.trace is None or self.trace.available(cid, t)

    def offline_cut(self, cid: int, t0: float, t1: float):
        """First moment in ``[t0, t1)`` the client's connectivity drops, or
        ``None`` when it stays online for the whole round."""
        if self.trace is None:
            return None
        return self.trace.offline_cut(cid, t0, t1)

    # ---------------------------------------------------------- corruption
    def update_scales(self, cids) -> np.ndarray:
        """(C,) multiplier vector for a dispatch bucket — byzantine members
        get ``byzantine_scale``, honest ones 1.  Fed to one jitted
        ``scale_cohort`` so corruption costs no recompile."""
        s = self.behavior.byzantine_scale
        return np.asarray([s if self.is_byzantine(c) else 1.0 for c in cids],
                          np.float32)

    def byzantine_marks(self, cids) -> np.ndarray:
        """(C,) 0/1 vector marking byzantine rows of a dispatch bucket."""
        return np.asarray([1.0 if self.is_byzantine(c) else 0.0
                           for c in cids], np.float32)

    def replacement_target(self, like):
        """The attacker's goal model for the replacement attack: a fixed
        random tree drawn once per trainable structure from the behavior
        seed — deterministic across dispatches, runs, and resume."""
        flat, treedef = jax.tree_util.tree_flatten(like)
        sig = (treedef, tuple((l.shape, str(l.dtype)) for l in flat))
        if sig not in self._targets:
            key = jax.random.PRNGKey(np.uint32(self.behavior.seed)
                                     ^ np.uint32(0x7A9E))
            keys = jax.random.split(key, max(1, len(flat)))
            leaves = [
                (0.5 * jax.random.normal(k, l.shape, jnp.float32)
                 ).astype(l.dtype)
                for k, l in zip(keys, flat)]
            self._targets[sig] = jax.tree_util.tree_unflatten(treedef, leaves)
        return self._targets[sig]


def replace_rows(deltas, marks, trainable0, target, boost):
    """Blend a (C, ...) update stack with the model-replacement payload on
    the marked rows: honest rows pass through, byzantine rows become
    ``boost · (target − trainable0)``.  Shape-stable → one jit, no
    recompiles inside the event loop."""
    def blend(d, t0, tg):
        mal = (boost * (tg.astype(jnp.float32) - t0.astype(jnp.float32)))
        m = marks.reshape((-1,) + (1,) * (d.ndim - 1))
        out = d.astype(jnp.float32) * (1.0 - m) + m * mal[None]
        return out.astype(d.dtype)
    return tree_map(blend, deltas, trainable0, target)


# ======================================================= robust aggregators
def _trim_counts(cohort: int, trim: float) -> int:
    """Per-side trim count: ⌊trim·C⌋, capped so at least one row survives."""
    k = int(np.floor(trim * cohort))
    return min(k, (cohort - 1) // 2)


@register_aggregator("trimmed_mean")
def trimmed_mean(trim: float = 0.2):
    """Coordinate-wise trimmed mean: sort the cohort axis, drop the top and
    bottom ``⌊trim·C⌋`` values per coordinate, average the rest.  Ignores
    sample weights (robustness and weighting pull opposite ways — a
    byzantine client should not buy influence with a large dataset)."""
    def agg(trainable0, deltas, weights, masks):
        cohort = weights.shape[0]
        k = _trim_counts(cohort, trim)
        def red(t0, d):
            s = jnp.sort(d.astype(jnp.float32), axis=0)
            m = jnp.mean(s[k:cohort - k], axis=0)
            return (t0 + m).astype(t0.dtype)
        return tree_map(red, trainable0, deltas)
    return agg


@register_aggregator("median")
def coordinate_median():
    """Coordinate-wise median over the cohort axis."""
    def agg(trainable0, deltas, weights, masks):
        return tree_map(
            lambda t0, d: (t0 + jnp.median(d.astype(jnp.float32), axis=0)
                           ).astype(t0.dtype),
            trainable0, deltas)
    return agg


def _krum_select(f: int, m: int):
    """Krum / multi-Krum (Blanchard et al., NeurIPS'17) selection over a
    (C, ...) update stack.

    Each row's score is the sum of its ``k = C − f − 2`` smallest squared
    distances to other rows; the ``m`` lowest-scoring rows are averaged
    (``m = 1`` → Krum, ``m = k`` → the usual multi-Krum choice).  ``f`` is
    the byzantine budget; ``f ≤ 0`` auto-sizes it to ``(C − 3) // 2``, the
    largest value the C ≥ 2f + 3 guarantee admits.  Distance-based selection
    ignores sample weights, like the other robust rules."""
    def agg(trainable0, deltas, weights, masks):
        cohort = int(weights.shape[0])
        if cohort <= 2:
            return cohort_fedavg(trainable0, deltas,
                                 jnp.ones_like(weights), masks)
        ff = f if f > 0 else max(0, (cohort - 3) // 2)
        k = max(1, min(cohort - ff - 2, cohort - 1))
        leaves = jax.tree_util.tree_leaves(deltas)
        flat = jnp.concatenate(
            [l.reshape(cohort, -1).astype(jnp.float32) for l in leaves],
            axis=1)
        sq = jnp.sum(flat * flat, axis=1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * (flat @ flat.T)
        d2 = jnp.maximum(d2, 0.0) + jnp.float32(1e30) * jnp.eye(cohort)
        scores = jnp.sum(jnp.sort(d2, axis=1)[:, :k], axis=1)
        mm = max(1, min(m if m > 0 else k, cohort))
        sel = jnp.argsort(scores)[:mm]
        pick = jnp.zeros((cohort,), jnp.float32).at[sel].set(1.0 / mm)
        return tree_map(
            lambda t0, d: (t0 + jnp.tensordot(
                pick, d.astype(jnp.float32), axes=1)).astype(t0.dtype),
            trainable0, deltas)
    return agg


@register_aggregator("krum")
def krum(f: int = 0):
    """Krum: keep the single update closest (in summed squared distance) to
    its ``C − f − 2`` nearest peers."""
    return _krum_select(f, m=1)


@register_aggregator("multi_krum")
def multi_krum(f: int = 0, m: int = 0):
    """Multi-Krum: average the ``m`` lowest-scoring updates (``m = 0`` →
    ``C − f − 2``, the paper's default)."""
    return _krum_select(f, m)


@register_aggregator("norm_clip")
def norm_clip(clip: float = 0.0):
    """Clip every client's update norm to ``clip`` (or, when 0, to the cohort's
    median norm — a scale-free default) and take the weighted FedAvg.
    Neutralizes magnitude attacks while keeping sample weighting."""
    def agg(trainable0, deltas, weights, masks):
        norms = cohort_norms(deltas)
        ref = jnp.float32(clip) if clip > 0 else jnp.median(norms)
        clipped = scale_cohort(deltas, jnp.minimum(1.0, ref / (norms + 1e-12)))
        return cohort_fedavg(trainable0, clipped, weights, masks)
    return agg
