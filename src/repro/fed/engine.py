"""Federated simulation engine (single-host; the pjit pod-scale variant lives
in repro/train/steps.py).

Reproduces the paper's experimental protocol: heterogeneous client memory
budgets, memory-aware participation (the "memory wall" — methods whose local
footprint exceeds a client's budget cannot recruit it), Dirichlet non-IID
partitions, per-round client sampling, weighted FedAvg.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from ..core.memory import peak_memory
from ..data.partition import ClientSampler, dirichlet_partition, iid_partition
from ..models.config import FedConfig, ModelConfig


@dataclasses.dataclass
class Client:
    cid: int
    sampler: ClientSampler
    n_samples: int
    mem_budget: int      # bytes


class FedSim:
    """Builds the client population and drives rounds for a Strategy."""

    def __init__(self, cfg: ModelConfig, fed: FedConfig, tokens, labels,
                 batch_fn: Callable, batch_size: int = 8,
                 budget_range=(0.10, 1.30), memory_constrained: bool = True):
        self.cfg, self.fed = cfg, fed
        self.tokens, self.labels, self.batch_fn = tokens, labels, batch_fn
        self.rng = np.random.default_rng(fed.seed)
        n = len(tokens)
        if fed.iid:
            shards = iid_partition(n, fed.n_clients, fed.seed)
        else:
            shards = dirichlet_partition(labels, fed.n_clients,
                                         fed.dirichlet_alpha, fed.seed)
        # memory budgets span [lo, hi] × the full-adapter footprint — mirrors
        # the paper's 4–12 GB devices vs ~27 GB LLaMA2-7B requirement
        ref = peak_memory(cfg, "full_adapters", batch_size,
                          tokens.shape[1])["total"]
        lo, hi = budget_range
        budgets = (self.rng.uniform(lo, hi, fed.n_clients) * ref).astype(np.int64)
        self.clients: List[Client] = [
            Client(i, ClientSampler(shards[i], batch_size, fed.seed + i),
                   len(shards[i]), int(budgets[i]))
            for i in range(fed.n_clients)]
        self.memory_constrained = memory_constrained
        self.batch_size = batch_size
        self.seq_len = tokens.shape[1]

    # ---------------------------------------------------------- participation
    def eligible(self, mem_method: str, **mem_kw) -> List[Client]:
        if not self.memory_constrained:
            return self.clients
        need = peak_memory(self.cfg, mem_method, self.batch_size,
                           self.seq_len, **mem_kw)["total"]
        return [c for c in self.clients if c.mem_budget >= need]

    def sample_clients(self, mem_method: str, **mem_kw) -> List[Client]:
        pool = self.eligible(mem_method, **mem_kw)
        if not pool:
            return []
        k = min(self.fed.clients_per_round, len(pool))
        idx = self.rng.choice(len(pool), k, replace=False)
        return [pool[i] for i in idx]

    def client_batches(self, client: Client, n_batches: int):
        return [self.batch_fn(client.sampler.next_indices())
                for _ in range(n_batches)]

    def eval_batch(self, n: int = 256, seed: int = 1234):
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(self.tokens), min(n, len(self.tokens)), replace=False)
        return self.batch_fn(idx)


@dataclasses.dataclass
class RoundMetrics:
    round: int
    loss: float
    acc: float
    n_participants: int
    comm_bytes: int = 0


def run_rounds(sim: FedSim, strategy, rounds: int, eval_every: int = 5,
               verbose: bool = False) -> List[RoundMetrics]:
    """Generic driver: sample → local updates → aggregate → (eval)."""
    history = []
    eval_b = sim.eval_batch()
    for r in range(rounds):
        clients = sim.sample_clients(strategy.memory_method,
                                     **strategy.memory_kwargs(r))
        if clients:
            strategy.round(sim, clients, r)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            loss, acc = strategy.evaluate(eval_b)
            m = RoundMetrics(r, loss, acc, len(clients),
                             strategy.comm_bytes_per_round())
            history.append(m)
            if verbose:
                print(f"  round {r:3d} n={len(clients):2d} "
                      f"loss={loss:.4f} acc={acc:.4f}")
    return history
