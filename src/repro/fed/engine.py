"""Federated simulation engine (single-host; the pjit pod-scale variant lives
in repro/train/steps.py).

Reproduces the paper's experimental protocol: heterogeneous client memory
budgets, memory-aware participation (the "memory wall" — methods whose local
footprint exceeds a client's budget cannot recruit it), Dirichlet non-IID
partitions, per-round client sampling, weighted FedAvg.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from ..core.memory import peak_memory
from ..data.partition import (ClientPool, ClientSampler, DeviceProfile,
                              dirichlet_partition, iid_partition,
                              profile_tier, sample_profiles)
from ..models.config import FedConfig, ModelConfig


# batch leaves whose leading axis is the per-client batch dimension — the
# only leaves cohort_batches pads for short clients (a leading dim that
# merely *coincides* with the batch size, e.g. class_tokens with
# n_classes == b, must not be padded)
BATCH_AXIS_KEYS = ("tokens", "labels", "embeds", "enc_tokens", "enc_embeds")


@dataclasses.dataclass
class Client:
    cid: int
    sampler: ClientSampler
    n_samples: int
    mem_budget: int      # bytes
    profile: Optional[DeviceProfile] = None   # device clock / link / tier


class FedSim:
    """Builds the client population and drives rounds for a Strategy.

    ``lazy=True`` (ISSUE 8) switches the population to a ``ClientPool``:
    no per-client state exists until a client is dispatched — its memory
    budget, ``DeviceProfile`` and data shard are synthesized
    deterministically from ``(seed, cid)`` on ``pool.acquire`` and torn
    down on release, so resident state is O(active cohort) and
    ``fed.n_clients`` can be 10⁶.  The eager path is unchanged (same rng
    draws, bit-identical histories); lazy shards subsample the corpus
    per-cid (``shard_size`` examples each) instead of partitioning it,
    because a partition is itself an O(population) object."""

    def __init__(self, cfg: ModelConfig, fed: FedConfig, tokens, labels,
                 batch_fn: Callable, batch_size: int = 8,
                 budget_range=(0.10, 1.30), memory_constrained: bool = True,
                 lazy: bool = False, shard_size: Optional[int] = None):
        self.cfg, self.fed = cfg, fed
        self.tokens, self.labels, self.batch_fn = tokens, labels, batch_fn
        self.rng = np.random.default_rng(fed.seed)
        self.memory_constrained = memory_constrained
        self.batch_size = batch_size
        self.seq_len = tokens.shape[1]
        self.lazy = bool(lazy)
        # memory budgets span [lo, hi] × the full-adapter footprint — mirrors
        # the paper's 4–12 GB devices vs ~27 GB LLaMA2-7B requirement
        self._ref = peak_memory(cfg, "full_adapters", batch_size,
                                tokens.shape[1])["total"]
        self._budget_range = budget_range
        if self.lazy:
            self.shard_size = int(shard_size or min(len(tokens),
                                                    max(2 * batch_size, 8)))
            self.clients = None
            self.pool = ClientPool(
                fed.n_clients, self._synth_client,
                nbytes=lambda c: int(c.sampler.shard.nbytes
                                     + c.sampler._order.nbytes))
            return
        self.pool = None
        self.shard_size = None
        n = len(tokens)
        if fed.iid:
            shards = iid_partition(n, fed.n_clients, fed.seed)
        else:
            shards = dirichlet_partition(labels, fed.n_clients,
                                         fed.dirichlet_alpha, fed.seed)
        ref = self._ref
        lo, hi = budget_range
        budgets = (self.rng.uniform(lo, hi, fed.n_clients) * ref).astype(np.int64)
        # device profiles are deterministic in (budget, seed) and drawn from
        # a *separate* rng stream — self.rng's draws (and hence client
        # sampling) are identical with or without profiles
        profiles = sample_profiles(budgets, ref, seed=fed.seed)
        self.clients: List[Client] = [
            Client(i, ClientSampler(shards[i], batch_size, fed.seed + i),
                   len(shards[i]), int(budgets[i]), profiles[i])
            for i in range(fed.n_clients)]

    @property
    def n_clients(self) -> int:
        """Population size without touching (or requiring) a client list."""
        return self.fed.n_clients

    # ------------------------------------------------------- lazy synthesis
    def lazy_budget(self, cid: int) -> int:
        """A cid's memory budget from ``(seed, cid)`` alone — the cheap
        eligibility predicate rejection sampling tests before paying for a
        full materialization.  Must draw exactly like ``_synth_client``."""
        lo, hi = self._budget_range
        crng = np.random.default_rng((self.fed.seed, cid, 0xC11E27))
        return int(crng.uniform(lo, hi) * self._ref)

    def _synth_client(self, cid: int, visit: int) -> Client:
        """Deterministic client synthesis: budget, profile and shard depend
        only on ``(seed, cid)``; the minibatch sampler is seeded with
        ``(seed, cid, visit)`` so the k-th dispatch of a cid draws the same
        batches regardless of dispatch order across the population."""
        lo, hi = self._budget_range
        crng = np.random.default_rng((self.fed.seed, cid, 0xC11E27))
        budget = int(crng.uniform(lo, hi) * self._ref)
        name, flops, bw = profile_tier(budget / max(1, self._ref))
        jf, jb = 1.0 + 0.2 * crng.uniform(-1, 1, 2)
        profile = DeviceProfile(tier=name, flops=flops * float(jf),
                                bandwidth=bw * float(jb), memory=budget)
        size = min(self.shard_size, len(self.tokens))
        shard = np.sort(crng.choice(len(self.tokens), size, replace=False))
        sampler = ClientSampler(shard, self.batch_size,
                                seed=(self.fed.seed, cid, visit, 0x5A11))
        return Client(cid, sampler, len(shard), budget, profile)

    def pool_sample(self, k: int, mem_method: str, mem_kw: dict,
                    busy=frozenset(), avail=None) -> List[Client]:
        """Lazy-path sampling: rejection-sample eligible cids from the pool
        (memory wall + caller availability predicate) and materialize only
        the accepted ones."""
        need = (peak_memory(self.cfg, mem_method, self.batch_size,
                            self.seq_len, **mem_kw)["total"]
                if self.memory_constrained else 0)

        def ok(cid):
            if need and self.lazy_budget(cid) < need:
                return False
            return avail is None or avail(cid)

        return self.pool.sample(k, self.rng, busy=busy, eligible=ok)

    def release_clients(self, clients) -> None:
        """Return dispatched clients to the pool (no-op on the eager path)."""
        if self.lazy and clients:
            for c in clients:
                self.pool.release(c.cid)

    def probe_clients(self, k: int) -> List[Client]:
        """The first ``k`` cids, for one-off population probes (chainfed's
        FOAT boundary scan).  Lazy probes must be handed back via
        ``release_clients`` when done."""
        k = min(k, self.n_clients)
        if not self.lazy:
            return self.clients[:k]
        return [self.pool.acquire(cid) for cid in range(k)]

    # ---------------------------------------------------------- participation
    def eligible(self, mem_method: str, **mem_kw) -> List[Client]:
        if self.lazy:
            raise RuntimeError(
                "eligible() enumerates the population — the lazy ClientPool "
                "path samples by rejection instead (pool_sample)")
        if not self.memory_constrained:
            return self.clients
        need = peak_memory(self.cfg, mem_method, self.batch_size,
                           self.seq_len, **mem_kw)["total"]
        return [c for c in self.clients if c.mem_budget >= need]

    def sample_clients(self, mem_method: str, **mem_kw) -> List[Client]:
        if self.lazy:
            return self.pool_sample(self.fed.clients_per_round, mem_method,
                                    mem_kw)
        pool = self.eligible(mem_method, **mem_kw)
        if not pool:
            return []
        k = min(self.fed.clients_per_round, len(pool))
        idx = self.rng.choice(len(pool), k, replace=False)
        return [pool[i] for i in idx]

    def client_batches(self, client: Client, n_batches: int):
        return [self.batch_fn(client.sampler.next_indices())
                for _ in range(n_batches)]

    def cohort_batches(self, clients: List[Client], n_batches: int):
        """Stacked local batches for a whole cohort: every leaf becomes
        ``(C, n_batches, b, ...)`` — the layout one jitted ``cohort_step``
        (vmap over C, scan over n_batches) consumes, and the same layout the
        pjit pod path shards on its cohort axis.

        The stack is assembled host-side in numpy and crosses to the device
        in ONE transfer per leaf, instead of ``C × n_batches`` separate
        transfers on the per-client path (``batch_fn`` should return host
        arrays — the in-repo batch builders do).  Clients whose shard
        supports only a smaller batch are padded to the cohort's max batch
        size by repeating their last row with ``labels = IGNORE`` — exact
        under the masked mean of ``cross_entropy`` (padding rows carry zero
        loss weight; MoE router penalties see the padded tokens, a no-op for
        the dense reproduction configs).  The known batch-leading leaves
        (``BATCH_AXIS_KEYS``) pad along axis 0 and M-RoPE ``positions``
        (3, b, S) along their batch axis; any other leaf (``class_tokens``)
        must be batch-size-invariant and stacks as-is."""
        import jax.numpy as jnp

        from ..train.losses import IGNORE
        raw = [[{k: np.asarray(v) for k, v in
                 self.batch_fn(c.sampler.next_indices()).items()}
                for _ in range(n_batches)] for c in clients]
        bmax = max(b["tokens"].shape[0] for cb in raw for b in cb
                   if "tokens" in b) if raw and "tokens" in raw[0][0] else None

        def pad(batch):
            if bmax is None or batch["tokens"].shape[0] == bmax:
                return batch
            b = batch["tokens"].shape[0]
            out = {}
            for k, v in batch.items():
                if k in BATCH_AXIS_KEYS and v.ndim and v.shape[0] == b:
                    v = np.concatenate(
                        [v, np.repeat(v[-1:], bmax - b, axis=0)], axis=0)
                    if k == "labels":
                        v[b:] = IGNORE
                elif k == "positions" and v.ndim >= 3 and v.shape[-2] == b:
                    # (3, b, S): padded rows carry IGNORE labels, so their
                    # position values never reach the loss
                    v = np.concatenate(
                        [v, np.repeat(v[..., -1:, :], bmax - b, axis=-2)],
                        axis=-2)
                out[k] = v
            return out

        raw = [[pad(b) for b in cb] for cb in raw]
        keys = raw[0][0].keys()
        return {k: jnp.asarray(np.stack(
            [np.stack([b[k] for b in cb]) for cb in raw]))
            for k in keys}

    def eval_batch(self, n: int = 256, seed: int = 1234):
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(self.tokens), min(n, len(self.tokens)), replace=False)
        return self.batch_fn(idx)


@dataclasses.dataclass
class RoundMetrics:
    round: int
    loss: float
    acc: float
    n_participants: int
    comm_bytes: int = 0
    wallclock: float = 0.0      # virtual seconds since experiment start
    stale_updates: int = 0      # aggregated updates computed at an older
                                # model version (semisync carry / async)
    dp_epsilon: float = 0.0     # cumulative privacy spend (ε at the DP
                                # config's δ) — 0 when DP is off
    silo_comm_bytes: int = 0    # cumulative cross-silo→server tier bytes
                                # (hierarchical topology only; 0 when flat)


def run_rounds(sim: FedSim, strategy, rounds: int, eval_every: int = 5,
               verbose: bool = False) -> List[RoundMetrics]:
    """Deprecated alias for ``FedScheduler(mode="sync").run`` — the single
    driver code path since ISSUE 8.  It reproduces the historical sample →
    local updates → aggregate → (eval) loop bit-identically while also
    tracking each round's virtual wall-clock; call the scheduler (or
    ``run_experiment``) directly in new code."""
    import warnings

    from .runtime import FedScheduler
    warnings.warn(
        "run_rounds is deprecated: construct FedScheduler(sim, strategy, "
        "mode='sync') (or call run_experiment) directly — run_rounds is a "
        "thin alias and will be removed next release",
        DeprecationWarning, stacklevel=2)
    return FedScheduler(sim, strategy, mode="sync").run(
        rounds, eval_every=eval_every, verbose=verbose)
