"""Federated simulation engine (single-host; the pjit pod-scale variant lives
in repro/train/steps.py).

Reproduces the paper's experimental protocol: heterogeneous client memory
budgets, memory-aware participation (the "memory wall" — methods whose local
footprint exceeds a client's budget cannot recruit it), Dirichlet non-IID
partitions, per-round client sampling, weighted FedAvg.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from ..core.memory import peak_memory
from ..data.partition import (ClientSampler, DeviceProfile,
                              dirichlet_partition, iid_partition,
                              sample_profiles)
from ..models.config import FedConfig, ModelConfig


# batch leaves whose leading axis is the per-client batch dimension — the
# only leaves cohort_batches pads for short clients (a leading dim that
# merely *coincides* with the batch size, e.g. class_tokens with
# n_classes == b, must not be padded)
BATCH_AXIS_KEYS = ("tokens", "labels", "embeds", "enc_tokens", "enc_embeds")


@dataclasses.dataclass
class Client:
    cid: int
    sampler: ClientSampler
    n_samples: int
    mem_budget: int      # bytes
    profile: Optional[DeviceProfile] = None   # device clock / link / tier


class FedSim:
    """Builds the client population and drives rounds for a Strategy."""

    def __init__(self, cfg: ModelConfig, fed: FedConfig, tokens, labels,
                 batch_fn: Callable, batch_size: int = 8,
                 budget_range=(0.10, 1.30), memory_constrained: bool = True):
        self.cfg, self.fed = cfg, fed
        self.tokens, self.labels, self.batch_fn = tokens, labels, batch_fn
        self.rng = np.random.default_rng(fed.seed)
        n = len(tokens)
        if fed.iid:
            shards = iid_partition(n, fed.n_clients, fed.seed)
        else:
            shards = dirichlet_partition(labels, fed.n_clients,
                                         fed.dirichlet_alpha, fed.seed)
        # memory budgets span [lo, hi] × the full-adapter footprint — mirrors
        # the paper's 4–12 GB devices vs ~27 GB LLaMA2-7B requirement
        ref = peak_memory(cfg, "full_adapters", batch_size,
                          tokens.shape[1])["total"]
        lo, hi = budget_range
        budgets = (self.rng.uniform(lo, hi, fed.n_clients) * ref).astype(np.int64)
        # device profiles are deterministic in (budget, seed) and drawn from
        # a *separate* rng stream — self.rng's draws (and hence client
        # sampling) are identical with or without profiles
        profiles = sample_profiles(budgets, ref, seed=fed.seed)
        self.clients: List[Client] = [
            Client(i, ClientSampler(shards[i], batch_size, fed.seed + i),
                   len(shards[i]), int(budgets[i]), profiles[i])
            for i in range(fed.n_clients)]
        self.memory_constrained = memory_constrained
        self.batch_size = batch_size
        self.seq_len = tokens.shape[1]

    # ---------------------------------------------------------- participation
    def eligible(self, mem_method: str, **mem_kw) -> List[Client]:
        if not self.memory_constrained:
            return self.clients
        need = peak_memory(self.cfg, mem_method, self.batch_size,
                           self.seq_len, **mem_kw)["total"]
        return [c for c in self.clients if c.mem_budget >= need]

    def sample_clients(self, mem_method: str, **mem_kw) -> List[Client]:
        pool = self.eligible(mem_method, **mem_kw)
        if not pool:
            return []
        k = min(self.fed.clients_per_round, len(pool))
        idx = self.rng.choice(len(pool), k, replace=False)
        return [pool[i] for i in idx]

    def client_batches(self, client: Client, n_batches: int):
        return [self.batch_fn(client.sampler.next_indices())
                for _ in range(n_batches)]

    def cohort_batches(self, clients: List[Client], n_batches: int):
        """Stacked local batches for a whole cohort: every leaf becomes
        ``(C, n_batches, b, ...)`` — the layout one jitted ``cohort_step``
        (vmap over C, scan over n_batches) consumes, and the same layout the
        pjit pod path shards on its cohort axis.

        The stack is assembled host-side in numpy and crosses to the device
        in ONE transfer per leaf, instead of ``C × n_batches`` separate
        transfers on the per-client path (``batch_fn`` should return host
        arrays — the in-repo batch builders do).  Clients whose shard
        supports only a smaller batch are padded to the cohort's max batch
        size by repeating their last row with ``labels = IGNORE`` — exact
        under the masked mean of ``cross_entropy`` (padding rows carry zero
        loss weight; MoE router penalties see the padded tokens, a no-op for
        the dense reproduction configs).  The known batch-leading leaves
        (``BATCH_AXIS_KEYS``) pad along axis 0 and M-RoPE ``positions``
        (3, b, S) along their batch axis; any other leaf (``class_tokens``)
        must be batch-size-invariant and stacks as-is."""
        import jax.numpy as jnp

        from ..train.losses import IGNORE
        raw = [[{k: np.asarray(v) for k, v in
                 self.batch_fn(c.sampler.next_indices()).items()}
                for _ in range(n_batches)] for c in clients]
        bmax = max(b["tokens"].shape[0] for cb in raw for b in cb
                   if "tokens" in b) if raw and "tokens" in raw[0][0] else None

        def pad(batch):
            if bmax is None or batch["tokens"].shape[0] == bmax:
                return batch
            b = batch["tokens"].shape[0]
            out = {}
            for k, v in batch.items():
                if k in BATCH_AXIS_KEYS and v.ndim and v.shape[0] == b:
                    v = np.concatenate(
                        [v, np.repeat(v[-1:], bmax - b, axis=0)], axis=0)
                    if k == "labels":
                        v[b:] = IGNORE
                elif k == "positions" and v.ndim >= 3 and v.shape[-2] == b:
                    # (3, b, S): padded rows carry IGNORE labels, so their
                    # position values never reach the loss
                    v = np.concatenate(
                        [v, np.repeat(v[..., -1:, :], bmax - b, axis=-2)],
                        axis=-2)
                out[k] = v
            return out

        raw = [[pad(b) for b in cb] for cb in raw]
        keys = raw[0][0].keys()
        return {k: jnp.asarray(np.stack(
            [np.stack([b[k] for b in cb]) for cb in raw]))
            for k in keys}

    def eval_batch(self, n: int = 256, seed: int = 1234):
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(self.tokens), min(n, len(self.tokens)), replace=False)
        return self.batch_fn(idx)


@dataclasses.dataclass
class RoundMetrics:
    round: int
    loss: float
    acc: float
    n_participants: int
    comm_bytes: int = 0
    wallclock: float = 0.0      # virtual seconds since experiment start
    stale_updates: int = 0      # aggregated updates computed at an older
                                # model version (semisync carry / async)
    dp_epsilon: float = 0.0     # cumulative privacy spend (ε at the DP
                                # config's δ) — 0 when DP is off


def run_rounds(sim: FedSim, strategy, rounds: int, eval_every: int = 5,
               verbose: bool = False) -> List[RoundMetrics]:
    """Legacy lockstep driver — now a thin wrapper over the event-driven
    ``FedScheduler`` in ``sync`` mode, which reproduces the historical
    sample → local updates → aggregate → (eval) loop bit-identically while
    also tracking each round's virtual wall-clock (the slowest sampled
    device's compute + uplink time)."""
    from .runtime import FedScheduler
    return FedScheduler(sim, strategy, mode="sync").run(
        rounds, eval_every=eval_every, verbose=verbose)
