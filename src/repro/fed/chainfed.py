"""CHAINFED as a registered Strategy (paper §4, Algorithm 1): FOAT boundary
setup → DLCT-scheduled window plans → GPO dual loss, all executed by the
shared ``PlanEngine`` — no second trainer class.  The per-round plan carries
an ``ActiveAdapters.window`` spec; since plans key the engine's jit cache,
the DLCT cyclic window reuses ≤ L compilations (per-offset stage cache).

Ablation switches (paper Table 4), also registered as named variants:
  use_dlct=False → window size 1, no co-tuning overlap   (chainfed_wo_dlct)
  use_gpo=False  → λ = 0 (pure local objective)          (chainfed_wo_gpo)
  use_foat=False → L_start = 0 (full chain)              (chainfed_wo_foat)
"""
from __future__ import annotations

from ..core.adapters import ActiveAdapters
from ..core.dlct import ChainSchedule, make_schedule
from ..core.memory import comm_bytes_per_round
from ..models.config import ChainConfig, ModelConfig
from .registry import register_strategy
from .strategies import Strategy, TrainablePlan


@register_strategy("chainfed")
class ChainFed(Strategy):
    name = "chainfed"
    memory_method = "chainfed"

    def __init__(self, cfg: ModelConfig, chain: ChainConfig, key,
                 use_dlct=True, use_gpo=True, use_foat=True):
        if not use_dlct:
            chain = chain.replace(window=1)
        if not use_gpo:
            chain = chain.replace(lam=0.0)
        self.use_foat = use_foat
        super().__init__(cfg, chain, key)
        self.l_start = 0
        self.schedule: ChainSchedule = make_schedule(cfg, 0, chain.window)
        self._foat_done = False

    # ---- Phase 1: FOAT runs once, before federated rounds (Algorithm 1) ----
    def maybe_setup_foat(self, sim):
        if self._foat_done:
            return
        self._foat_done = True
        if not self.use_foat:
            return
        clients = sim.clients[:min(8, len(sim.clients))]
        # one stacked (C, b, ...) evaluation instead of C host-side batches —
        # cohort_batches assembles the stack in numpy (one transfer per leaf)
        # and pads short clients to the cohort batch size (padding repeats a
        # row, a sample-duplication in that client's CKA statistic)
        stacked = sim.cohort_batches(clients, 1)   # (C, 1, b, ...) leaves
        batches = {k: v[:, 0] for k, v in stacked.items()}
        weights = [c.n_samples for c in clients]
        self.setup_foat(batches, weights)

    def setup_foat(self, client_batches, weights=None):
        from ..core.foat import run_foat
        self.l_start, scores = run_foat(self._params, self.adapters,
                                        client_batches, self.cfg,
                                        self.chain.foat_threshold, weights)
        self.schedule = make_schedule(self.cfg, self.l_start,
                                      self.chain.window)
        return self.l_start, scores

    # ---- Phase 2: staged rounds as window plans --------------------------
    def plan(self, client, round_idx) -> TrainablePlan:
        seg = self.schedule.segments(round_idx, self.chain.advance_every)
        spec = ActiveAdapters.window(self.cfg.total_chain_layers, seg.prefix,
                                     seg.window)
        # remat=True keeps the window scan checkpointed (forward_chain's
        # long-standing default for the GPO staged forward)
        return TrainablePlan(adapters=spec, train_head=self.head is not None,
                             loss="gpo", lam=self.chain.lam, remat=True)

    def round(self, sim, clients, round_idx):
        self.maybe_setup_foat(sim)
        super().round(sim, clients, round_idx)

    # ---- accounting ------------------------------------------------------
    def memory_kwargs(self, round_idx):
        return {"window": self.chain.window, "l_start": self.l_start}

    def comm_bytes_per_round(self) -> int:
        return comm_bytes_per_round(self.cfg, "chainfed",
                                    window=self.chain.window,
                                    l_start=self.l_start)


register_strategy("chainfed_wo_dlct", use_dlct=False)(ChainFed)
register_strategy("chainfed_wo_gpo", use_gpo=False)(ChainFed)
register_strategy("chainfed_wo_foat", use_foat=False)(ChainFed)
