"""CHAINFED as a Strategy for the federated engine — wraps the chain core
(FOAT setup → DLCT-scheduled staged rounds with GPO dual loss) so benchmarks
drive it exactly like the baselines.

Ablation switches (paper Table 4):
  use_dlct=False → window size 1, no co-tuning overlap
  use_gpo=False  → λ = 0 (pure local objective)
  use_foat=False → L_start = 0 (full chain)
"""
from __future__ import annotations

import jax

from ..core.chain import ChainFedTrainer
from ..core.memory import comm_bytes_per_round
from ..models.config import ChainConfig, ModelConfig
from ..models.transformer import init_adapters, init_lm


class ChainFed:
    name = "chainfed"
    memory_method = "chainfed"

    def __init__(self, cfg: ModelConfig, chain: ChainConfig, key,
                 use_dlct=True, use_gpo=True, use_foat=True):
        if not use_dlct:
            chain = chain.replace(window=1)
        if not use_gpo:
            chain = chain.replace(lam=0.0)
        self.use_foat = use_foat
        self.cfg, self.chain = cfg, chain
        k1, k2 = jax.random.split(key)
        params = init_lm(k1, cfg)
        adapters = init_adapters(k2, cfg)
        self.trainer = ChainFedTrainer(cfg, chain, params, adapters)
        self._foat_done = False

    # FOAT runs once, before federated rounds (Algorithm 1 Phase 1)
    def maybe_setup_foat(self, sim):
        if self._foat_done:
            return
        self._foat_done = True
        if not self.use_foat:
            return
        clients = sim.clients[:min(8, len(sim.clients))]
        batches = [sim.client_batches(c, 1)[0] for c in clients]
        weights = [c.n_samples for c in clients]
        self.trainer.setup_foat(batches, weights)

    def round(self, sim, clients, round_idx):
        self.maybe_setup_foat(sim)
        deltas, weights = [], []
        for c in clients:
            batches = sim.client_batches(c, self.chain.local_steps)
            delta, loss, parts = self.trainer.client_update(round_idx, batches)
            deltas.append(delta)
            weights.append(c.n_samples)
        if deltas:
            self.trainer.aggregate(round_idx, deltas, weights)

    def evaluate(self, batch):
        return self.trainer.evaluate(batch)

    def memory_kwargs(self, round_idx):
        return {"window": self.chain.window,
                "l_start": self.trainer.l_start}

    def comm_bytes_per_round(self) -> int:
        return comm_bytes_per_round(self.cfg, "chainfed",
                                    window=self.chain.window,
                                    l_start=self.trainer.l_start)

    @property
    def params(self):
        return self.trainer.params

    @property
    def adapters(self):
        return self.trainer.adapters
