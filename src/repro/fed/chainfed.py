"""CHAINFED as a registered Strategy (paper §4, Algorithm 1): FOAT boundary
setup → DLCT-scheduled window plans → GPO dual loss, all executed by the
shared ``PlanEngine`` — no second trainer class.  The per-round plan carries
an ``ActiveAdapters.window`` spec; since plans key the engine's jit cache,
the DLCT cyclic window reuses ≤ L compilations (per-offset stage cache).

**Stage advance is event-driven** (ISSUE 5): the DLCT window no longer
follows the caller's round index but the strategy's own *commit* counter —
every server commit (a lockstep round, a semisync deadline cut, or an async
buffer flush on the virtual clock) is one stage event.  With the default
``advance="commits"`` policy the window advances every ``advance_every``
commits, which on the sync path is bit-identical to the old
round-counting schedule; ``advance="plateau"`` instead advances as soon as
the committed window's loss stops improving (patience/tol below), so fast
stages release their slot early — convergence events, not clock ticks.

Ablation switches (paper Table 4), also registered as named variants:
  use_dlct=False → window size 1, no co-tuning overlap   (chainfed_wo_dlct)
  use_gpo=False  → λ = 0 (pure local objective)          (chainfed_wo_gpo)
  use_foat=False → L_start = 0 (full chain)              (chainfed_wo_foat)
"""
from __future__ import annotations

from ..core.adapters import ActiveAdapters
from ..core.dlct import ChainSchedule, make_schedule
from ..core.memory import comm_bytes_per_round
from ..models.config import ChainConfig, ModelConfig
from .registry import register_strategy
from .strategies import Strategy, TrainablePlan


@register_strategy("chainfed")
class ChainFed(Strategy):
    name = "chainfed"
    memory_method = "chainfed"

    def __init__(self, cfg: ModelConfig, chain: ChainConfig, key,
                 use_dlct=True, use_gpo=True, use_foat=True,
                 advance="commits", plateau_patience=3, plateau_tol=1e-3):
        if not use_dlct:
            chain = chain.replace(window=1)
        if not use_gpo:
            chain = chain.replace(lam=0.0)
        if advance not in ("commits", "plateau"):
            raise ValueError(f"advance policy {advance!r}: commits|plateau")
        self.use_foat = use_foat
        self.advance = advance
        self.plateau_patience = plateau_patience
        self.plateau_tol = plateau_tol
        super().__init__(cfg, chain, key)
        self.l_start = 0
        self.schedule: ChainSchedule = make_schedule(cfg, 0, chain.window)
        self._foat_done = False
        # event-driven stage state: commits since start, commits in the
        # current stage, the stage's best committed loss and its streak of
        # non-improving commits (plateau mode)
        self._commits = 0
        self._stage = 0
        self._stage_commits = 0
        self._stage_best = float("inf")
        self._stage_bad = 0

    # ---- Phase 1: FOAT runs once, before federated rounds (Algorithm 1) ----
    def begin(self, sim):
        """Scheduler entry hook: FOAT is a clock-0 event for the semisync /
        async modes (the sync path keeps the legacy inside-round ordering)."""
        self.maybe_setup_foat(sim)

    def maybe_setup_foat(self, sim):
        if self._foat_done:
            return
        self._foat_done = True
        if not self.use_foat:
            return
        clients = sim.probe_clients(8)
        # one stacked (C, b, ...) evaluation instead of C host-side batches —
        # cohort_batches assembles the stack in numpy (one transfer per leaf)
        # and pads short clients to the cohort batch size (padding repeats a
        # row, a sample-duplication in that client's CKA statistic)
        stacked = sim.cohort_batches(clients, 1)   # (C, 1, b, ...) leaves
        batches = {k: v[:, 0] for k, v in stacked.items()}
        weights = [c.n_samples for c in clients]
        sim.release_clients(clients)
        self.setup_foat(batches, weights)

    def setup_foat(self, client_batches, weights=None):
        from ..core.foat import run_foat
        self.l_start, scores = run_foat(self._params, self.adapters,
                                        client_batches, self.cfg,
                                        self.chain.foat_threshold, weights)
        self.schedule = make_schedule(self.cfg, self.l_start,
                                      self.chain.window)
        return self.l_start, scores

    # ---- Phase 2: staged windows advanced by commit events ---------------
    def plan(self, client, round_idx) -> TrainablePlan:
        seg = self.schedule.segments(self._stage)
        spec = ActiveAdapters.window(self.cfg.total_chain_layers, seg.prefix,
                                     seg.window)
        # remat=True keeps the window scan checkpointed (forward_chain's
        # long-standing default for the GPO staged forward)
        return TrainablePlan(adapters=spec, train_head=self.head is not None,
                             loss="gpo", lam=self.chain.lam, remat=True)

    def begin_commit(self):
        """One *server* commit may aggregate several plan groups (async
        buffers mixing dispatch stages, semisync carry-over): debounce the
        per-``commit_trainable`` stage bookkeeping to a single event."""
        self._in_commit = True
        self._commit_pending = False

    def end_commit(self):
        self._in_commit = False
        if self._commit_pending:
            self._commit_pending = False
            self._note_commit()

    def commit_trainable(self, plan: TrainablePlan, new):
        """Every committed aggregation — lockstep round, semisync deadline
        cut, or async buffer flush — is one stage event; the DLCT window
        advances on these, not on the caller's round numbering."""
        super().commit_trainable(plan, new)
        if getattr(self, "_in_commit", False):
            self._commit_pending = True
            return
        self._note_commit()

    def _note_commit(self):
        self._commits += 1
        self._stage_commits += 1
        if self.advance == "plateau":
            loss = self._last_round_loss
            loss = float(loss) if loss is not None else float("inf")
            # federated per-commit losses are noisy: a plateau is a *streak*
            # of `patience` consecutive commits without improvement — one
            # bad commit on a healthy downtrend resets nothing away
            if loss < self._stage_best - self.plateau_tol:
                self._stage_bad = 0
            else:
                self._stage_bad += 1
            if loss < self._stage_best:
                self._stage_best = loss
            if self._stage_bad >= max(1, self.plateau_patience):
                self._next_stage()
        elif self._stage_commits >= max(1, self.chain.advance_every):
            self._next_stage()

    def _next_stage(self):
        self._stage += 1
        self._stage_commits = 0
        self._stage_best = float("inf")
        self._stage_bad = 0

    # ---- durable state ---------------------------------------------------
    def extra_state(self) -> dict:
        """The stage machine: FOAT's boundary (the schedule re-derives from
        it), commit counters, and the plateau tracker — everything the next
        ``plan()`` / ``_note_commit()`` reads."""
        return {"l_start": int(self.l_start),
                "foat_done": bool(self._foat_done),
                "commits": int(self._commits),
                "stage": int(self._stage),
                "stage_commits": int(self._stage_commits),
                "stage_best": float(self._stage_best),
                "stage_bad": int(self._stage_bad)}

    def load_extra_state(self, state: dict) -> None:
        self.l_start = int(state["l_start"])
        self.schedule = make_schedule(self.cfg, self.l_start,
                                      self.chain.window)
        self._foat_done = bool(state["foat_done"])
        self._commits = int(state["commits"])
        self._stage = int(state["stage"])
        self._stage_commits = int(state["stage_commits"])
        self._stage_best = float(state["stage_best"])
        self._stage_bad = int(state["stage_bad"])

    def round(self, sim, clients, round_idx):
        self.maybe_setup_foat(sim)
        super().round(sim, clients, round_idx)

    # ---- accounting ------------------------------------------------------
    def memory_kwargs(self, round_idx):
        return {"window": self.chain.window, "l_start": self.l_start}

    def base_comm_bytes(self) -> int:
        return comm_bytes_per_round(self.cfg, "chainfed",
                                    window=self.chain.window,
                                    l_start=self.l_start)


register_strategy("chainfed_wo_dlct", use_dlct=False)(ChainFed)
register_strategy("chainfed_wo_gpo", use_gpo=False)(ChainFed)
register_strategy("chainfed_wo_foat", use_foat=False)(ChainFed)
register_strategy("chainfed_plateau", advance="plateau")(ChainFed)
