"""Event-driven heterogeneous federation runtime (ISSUE 5 tentpole).

The lockstep ``run_rounds`` loop treated every eligible client as
interchangeable; real edge cohorts are not — the paper's whole premise is
the memory (and speed) disparity across devices.  ``FedScheduler`` replaces
the round loop with a **virtual clock**: every ``Client`` carries a
``DeviceProfile`` (compute FLOP/s, uplink bytes/s, memory — sampled in
``repro.data.partition``), each dispatched client's round cost is derived
from the analytic cost model (``core.memory.round_flops`` for compute,
``Strategy.comm_bytes_per_round`` over the link for upload), and the
scheduler pops client-*completion* events off a heap instead of iterating
rounds.

Three aggregation modes, all through the same ``PlanEngine`` machinery:

* ``sync``     — bit-identical to the legacy ``run_rounds`` (which is now a
  thin wrapper over this mode): sample a cohort, run one fused
  ``cohort_step`` per plan group, advance the clock by the slowest sampled
  device's compute + uplink time.
* ``semisync`` — deadline cutoff: the server waits only until the
  ``deadline_quantile``-fastest sampled device has finished; stragglers are
  ``"drop"``-ed (their work is wasted — the realistic accounting) or
  ``"carry"``-ed, committing in the round they actually finish with a
  staleness-discounted weight.
* ``async``    — FedBuff-style buffered aggregation: a fixed ``concurrency``
  of clients works continuously, completions accumulate in a buffer, and
  every ``buffer_size`` arrivals the server commits them with
  ``Strategy.staleness_weight``-discounted weights folded into the fused
  FedAvg tensordot, bumps the model version, and dispatches replacements.

**Bucketed dispatch** keeps the event loop jit-friendly: when a wave of
clients starts, they are grouped by their (hashable) ``TrainablePlan`` —
which carries ``grad_cfg``, so per-tier heterogeneous SPSA ``n_samples`` /
FedKSeed ``K`` form separate buckets — and each bucket runs ONE jitted
``PlanEngine.cohort_updates`` (vmap over the bucket axis) at the model
version current at dispatch.  Buckets are padded to a fixed ``bucket_pad``
(default: the concurrency), so the set of compilations is exactly
{(plan, bucket_pad)} — nothing recompiles inside the event loop, however
completions interleave.  The per-client updates park on the heap until
their completion events fire; committing a buffer is a cheap
staleness-weighted tensordot onto the *current* state — updates computed at
version v and applied at version v' > v are exactly what the staleness
discount prices.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.memory import round_flops
from ..utils.tree import tree_map
from . import privacy
from .engine import FedSim, RoundMetrics
from .faults import ClientBehavior, FaultModel
from .strategies import scale_cohort, stack_masks

MODES = ("sync", "semisync", "async")


def client_round_time(sim: FedSim, strategy, client, plan=None) -> float:
    """Virtual seconds for one client's local round: analytic compute FLOPs
    over the device's effective throughput, plus the strategy's per-round
    uplink over the device's link.  ``plan`` (when given) supplies the
    gradient-program knobs — per-tier ``n_samples``/``seeds`` budgets make
    slow devices cheaper per round, which is the whole point of
    memory-stratified perturbation budgets."""
    kw = dict(strategy.memory_kwargs(0))
    opts = dict(plan.grad_options) if plan is not None else {}
    if "n_samples" in opts:
        kw["n_samples"] = opts["n_samples"]
    if "seeds" in opts:
        kw["kseeds"] = len(opts["seeds"])
    if plan is not None and plan.is_window:
        # the executed prefix walks with the DLCT stage — charge the plan's
        # actual window position, not the round-0 FOAT boundary
        seg = plan.window_segments
        kw["l_start"], kw["window"] = seg.prefix, seg.window
    flops = round_flops(sim.cfg, strategy.memory_method, sim.batch_size,
                        sim.seq_len,
                        local_steps=strategy.chain.local_steps, **kw)
    prof = client.profile
    if prof is None:
        return 1.0
    return flops / prof.flops + strategy.comm_bytes_per_round() / prof.bandwidth


@dataclasses.dataclass
class _Pending:
    """One dispatched client parked on the virtual clock: its update was
    computed at dispatch (model version ``version``) and lives as row
    ``bi`` of its bucket's stacked ``(C, ...)`` update tree — kept stacked
    so a commit of a whole contiguous bucket (the common case) is a single
    prefix slice per leaf instead of C gathers + a restack.  It commits
    when its completion event fires."""
    finish: float
    client: object
    plan: object
    bucket: object          # the dispatch bucket's stacked (C, ...) updates
    bi: int                 # this client's row in the bucket
    masks: dict
    weight: float           # sample count (staleness discount applied later)
    version: int            # model version the update was computed at
    seq: int = 0            # dispatch order — deterministic heap tie-break
    loss: object = None     # device scalar: this client's mean local loss
    start: float = 0.0      # dispatch clock — observed latency = finish-start
    failed: bool = False    # fault-injected dropout: `finish` is the server's
                            # timeout event, the update never arrives
    session: object = None  # secure-agg masking session of this entry's
                            # dispatch bucket (None when masking is off)

    def __lt__(self, other):
        return (self.finish, self.seq) < (other.finish, other.seq)


def _stack_updates(entries: List["_Pending"]):
    """Cohort-axis update stack for a commit group (already sorted back
    into dispatch order): a whole contiguous bucket reuses its
    already-stacked tree — at most one prefix slice per leaf — while mixed
    groups (straggler carry-over, partial buffers) fall back to per-entry
    gathers."""
    first = entries[0]
    if (all(e.bucket is first.bucket for e in entries)
            and [e.bi for e in entries] == list(range(len(entries)))):
        n = len(entries)
        rows = jax.tree_util.tree_leaves(first.bucket)[0].shape[0]
        if n == rows:
            return first.bucket
        return tree_map(lambda u: u[:n], first.bucket)
    return tree_map(lambda *us: jnp.stack(us),
                    *[tree_map(lambda u: u[e.bi], e.bucket)
                      for e in entries])


class FedScheduler:
    """Event-driven federation driver over a heterogeneous device population.

    Parameters
    ----------
    mode : ``"sync"`` | ``"semisync"`` | ``"async"``
    concurrency : clients working in parallel (async; default
        ``fed.clients_per_round``).
    buffer_size : completions per server commit (async; default
        = concurrency — with uniform device profiles this makes ``async``
        coincide with ``sync``).
    deadline_quantile : fraction of the sampled cohort the server waits for
        (semisync; default 0.75 — the slowest quarter are stragglers).
    straggler : ``"drop"`` (aborted at the deadline: work wasted, device
        freed) or ``"carry"`` (stragglers keep computing — excluded from
        resampling — and commit late with a staleness-discounted weight) —
        semisync only.
    bucket_pad : fixed bucket size dispatch waves are padded to (default:
        concurrency).  Keys the jit cache as (plan, bucket_pad): a fixed pad
        means no recompiles inside the event loop even when heterogeneous
        per-tier plans split a wave into uneven buckets.
    staleness_cap : drop (instead of discount) updates staler than this many
        versions (async; default: keep all).
    faults : ``ClientBehavior`` (or a prebuilt ``FaultModel``) — inject
        dropouts (timeout event + async re-dispatch on the same heap),
        byzantine update corruption, and intermittent stragglers.  Requires
        an event-driven mode: the lockstep sync path has no timeout
        machinery to detect a failure with.
    """

    def __init__(self, sim: FedSim, strategy, mode: str = "sync", *,
                 concurrency: Optional[int] = None,
                 buffer_size: Optional[int] = None,
                 deadline_quantile: float = 0.75,
                 straggler: str = "drop",
                 bucket_pad: Optional[int] = None,
                 staleness_cap: Optional[int] = None,
                 faults=None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; one of {MODES}")
        if straggler not in ("drop", "carry"):
            raise ValueError(f"straggler policy {straggler!r}: drop|carry")
        if faults is not None and mode == "sync":
            raise ValueError(
                "fault injection needs the event-driven runtime (semisync/"
                "async): the lockstep sync path has no timeout events")
        if strategy.secure is not None:
            if mode == "async":
                raise ValueError(
                    "secure aggregation needs round-scoped masking sessions; "
                    "async FedBuff commits mix arbitrary dispatch waves — "
                    "use sync or semisync")
            if mode == "semisync" and straggler == "carry":
                raise ValueError(
                    "secure aggregation with straggler='carry' would commit "
                    "one session across several rounds; use straggler='drop'")
        self.sim, self.strategy, self.mode = sim, strategy, mode
        self.concurrency = concurrency or sim.fed.clients_per_round
        self.buffer_size = buffer_size or self.concurrency
        if self.buffer_size > self.concurrency:
            raise ValueError(
                f"buffer_size {self.buffer_size} > concurrency "
                f"{self.concurrency}: at most `concurrency` completions can "
                f"ever be outstanding, so a larger buffer would never fill")
        self.deadline_quantile = deadline_quantile
        self.straggler = straggler
        self.bucket_pad = bucket_pad or self.concurrency
        self.staleness_cap = staleness_cap
        if isinstance(faults, ClientBehavior):
            faults = FaultModel(faults, sim.fed.n_clients)
        self.faults: Optional[FaultModel] = faults
        self.clock = 0.0            # virtual seconds
        self.version = 0            # server model version (commits so far)
        self._times = {}            # (cid, plan) -> cached round time
        self._seq = 0               # dispatch counter (heap tie-break)
        self._agg_jit = {}          # plan -> jitted commit aggregation
        self._corrupt_jit = None    # jitted byzantine per-bucket scaling
        self.committed_updates = 0  # client updates aggregated so far
        self.fault_dropouts = 0     # dispatches lost to injected dropouts
        self.redispatches = 0       # replacement dispatches (async recovery)
        # observed round latencies (on-time actuals; stragglers enter
        # censored at the deadline) — the adaptive semisync deadline
        self._lat_window = deque(maxlen=512)

    # ------------------------------------------------------------------ run
    def run(self, rounds: int, eval_every: int = 5,
            verbose: bool = False) -> List[RoundMetrics]:
        """Drive ``rounds`` server commits and return the metric history.
        In sync/semisync a commit is a round; in async it is a buffer flush
        — histories are comparable via ``RoundMetrics.wallclock``."""
        if self.mode == "sync":
            # sync preserves the legacy ordering exactly: one-off setup
            # (chainfed FOAT) runs *inside* the first Strategy.round, after
            # that round's eligibility sampling — bit-identical histories
            return self._run_sync(rounds, eval_every, verbose)
        self.strategy.begin(self.sim)
        if self.mode == "semisync":
            return self._run_semisync(rounds, eval_every, verbose)
        return self._run_async(rounds, eval_every, verbose)

    # ------------------------------------------------------------- plumbing
    def _round_time(self, client, plan) -> float:
        key = (client.cid, plan)
        if key not in self._times:
            self._times[key] = client_round_time(self.sim, self.strategy,
                                                 client, plan)
        return self._times[key]

    def _metric(self, r, eval_b, n, stale, verbose) -> RoundMetrics:
        loss, acc = self.strategy.evaluate(eval_b)
        eps = 0.0
        if self.strategy.dp is not None:
            eps, _ = self.strategy.dp_accountant.epsilon(
                self.strategy.dp.delta)
        m = RoundMetrics(r, loss, acc, n,
                         self.strategy.comm_bytes_per_round(),
                         wallclock=self.clock, stale_updates=stale,
                         dp_epsilon=eps)
        if verbose:
            dp = f" ε={eps:.2f}" if self.strategy.dp is not None else ""
            print(f"  round {r:3d} n={n:2d} loss={loss:.4f} acc={acc:.4f} "
                  f"t={self.clock:.1f}s stale={stale}{dp}")
        return m

    def _sample(self, n: int, round_idx: int, busy=frozenset()):
        """Sample ``n`` clients from the eligible pool, never re-dispatching
        a client that is still in flight (``busy``: cids parked on the
        event heap — a device cannot compute two overlapping local rounds).
        When ``n`` equals the configured cohort size and nothing is busy
        this is exactly ``sim.sample_clients`` — the same rng draws in the
        same order as the sync path, which is what makes
        async-with-uniform-latencies coincide with sync."""
        sim, strat = self.sim, self.strategy
        if not busy and n == sim.fed.clients_per_round:
            return sim.sample_clients(strat.memory_method,
                                      **strat.memory_kwargs(round_idx))
        pool = [c for c in sim.eligible(strat.memory_method,
                                        **strat.memory_kwargs(round_idx))
                if c.cid not in busy]
        if not pool or n <= 0:
            return []
        k = min(n, len(pool))
        idx = sim.rng.choice(len(pool), k, replace=False)
        return [pool[i] for i in idx]

    # ------------------------------------------------------- dispatch waves
    def _dispatch(self, clients, round_idx: int) -> List[_Pending]:
        """Start a wave of clients at the current model version: bucket by
        plan, pad each bucket to ``bucket_pad``, run one jitted
        ``cohort_updates`` per bucket, and return the per-client pending
        completions (absolute finish times on the virtual clock)."""
        strat, sim = self.strategy, self.sim
        groups = {}
        for c in clients:
            groups.setdefault(strat.plan(c, round_idx), []).append(c)
        pending = []
        for plan, bucket in groups.items():
            n = len(bucket)
            batches = sim.cohort_batches(bucket, strat.chain.local_steps)
            mask_list = [strat.plan_masks(sim, c, round_idx) for c in bucket]
            masks = stack_masks(mask_list)
            pad = max(0, self.bucket_pad - n)
            if pad:
                # pad with *copies of already-drawn rows* — no extra sampler
                # draws, so padding never perturbs the data stream; padded
                # rows are computed and discarded (weightless)
                rep = lambda x: jnp.concatenate(
                    [x, jnp.repeat(x[-1:], pad, axis=0)], axis=0)
                batches = tree_map(rep, batches)
                masks = {k: rep(v) for k, v in masks.items()}
            tr0 = strat.init_trainable(plan)
            step = strat.engine.cohort_updates(plan)
            updates, losses = step(tr0, strat.params, strat.adapters,
                                   batches, masks)
            if self.faults is not None and self.faults.byzantine:
                # corruption is one shape-stable jitted multiply over the
                # padded bucket — the event loop's no-recompile guarantee
                # holds with byzantine clients in play
                scales = np.ones(n + pad, np.float32)
                scales[:n] = self.faults.update_scales(
                    [c.cid for c in bucket])
                if self._corrupt_jit is None:
                    self._corrupt_jit = jax.jit(scale_cohort)
                updates = self._corrupt_jit(updates,
                                            jnp.asarray(scales))
            session = (privacy.new_session(strat,
                                           [c.cid for c in bucket])
                       if strat.secure is not None else None)
            for i, c in enumerate(bucket):
                self._seq += 1
                t = self._round_time(c, plan)
                failed = False
                if self.faults is not None:
                    draw = self.faults.draw(c.cid, self._seq)
                    t *= draw.slowdown
                    if draw.dropped:
                        failed = True
                        t *= self.faults.behavior.timeout_factor
                        self.fault_dropouts += 1
                pending.append(_Pending(
                    finish=self.clock + t,
                    client=c, plan=plan, bucket=updates, bi=i,
                    masks=mask_list[i], weight=float(c.n_samples),
                    version=self.version, seq=self._seq, loss=losses[i],
                    start=self.clock, failed=failed, session=session))
        return pending

    # --------------------------------------------------------------- commit
    def _commit(self, entries: List[_Pending]):
        """Fold a batch of completed updates into the current model: group
        by plan, stack each group's updates/masks along the cohort axis, and
        run the strategy's in-graph aggregation (default fused FedAvg) with
        weights = sample count × staleness discount.  Returns ``(kept,
        stale)`` — updates committed (post ``staleness_cap`` filter; 0 means
        the model did not move and the caller must not count a commit) and
        how many of them were stale."""
        strat = self.strategy
        if self.staleness_cap is not None:
            entries = [e for e in entries
                       if self.version - e.version <= self.staleness_cap]
        if not entries:
            return 0, 0
        groups = {}
        for e in entries:
            groups.setdefault(e.plan, []).append(e)
        stale = 0
        # convergence-driven schedules (chainfed plateau advance) read the
        # committed mean local loss lazily — one value for the *whole*
        # server commit, not whichever plan group happened to run last
        strat._last_round_loss = jnp.mean(
            jnp.stack([e.loss for e in entries]))
        dp_rng = (jax.random.fold_in(strat._dp_key, self.version)
                  if strat.dp is not None else None)
        strat.begin_commit()
        for gi, (plan, es) in enumerate(groups.items()):
            # completion events interleave arbitrarily; restoring dispatch
            # order makes the cohort axis deterministic (and identical to
            # the sync cohort order), and re-enables the whole-bucket
            # zero-copy fast path in _stack_updates
            es.sort(key=lambda e: e.seq)
            stale += sum(1 for e in es if e.version < self.version)
            tr0 = strat.init_trainable(plan)
            rng = (jax.random.fold_in(dp_rng, gi)
                   if dp_rng is not None else jax.random.PRNGKey(0))
            if strat.secure is not None:
                # per-session unmasking: each dispatch bucket agreed its
                # own pairwise masks — survivors unmask per session,
                # dropped roster members' masks are reconstructed
                sgroups = {}
                for e in es:
                    sgroups.setdefault(id(e.session),
                                       (e.session, []))[1].append(
                        (e.client.cid,
                         tree_map(lambda u: u[e.bi], e.bucket),
                         e.weight * strat.staleness_weight(
                             self.version - e.version)))
                new = privacy.secure_commit(strat, plan, tr0,
                                            list(sgroups.values()), rng=rng)
            else:
                ups = _stack_updates(es)
                masks = stack_masks([e.masks for e in es])
                w = jnp.asarray(
                    [e.weight
                     * strat.staleness_weight(self.version - e.version)
                     for e in es], jnp.float32)
                if plan not in self._agg_jit:
                    self._agg_jit[plan] = jax.jit(
                        strat.resolve_aggregate(plan))
                new = self._agg_jit[plan](tr0, ups, w, masks, rng)
            strat.commit_trainable(plan, new)
        strat.end_commit()
        self.version += 1
        self.committed_updates += len(entries)
        if strat.dp is not None:
            strat.dp_accountant.step(
                strat.dp.noise_multiplier,
                q=len(entries) / max(1, len(self.sim.clients)))
        return len(entries), stale

    # ------------------------------------------------------------ sync mode
    def _run_sync(self, rounds, eval_every, verbose):
        """The legacy lockstep protocol, verbatim — same rng draws, same
        ``Strategy.round`` dispatch (fused cohort step, donation), same eval
        cadence — plus the virtual clock: each round costs the slowest
        sampled device's compute + uplink time."""
        sim, strat = self.sim, self.strategy
        history = []
        eval_b = sim.eval_batch()
        for r in range(rounds):
            clients = sim.sample_clients(strat.memory_method,
                                         **strat.memory_kwargs(r))
            if clients:
                # cost reads the plan *before* the commit — stage-advance
                # strategies (chainfed) move to the next plan on commit
                dt = max(self._round_time(c, strat.plan(c, r))
                         for c in clients)
                strat.round(sim, clients, r)
                self.clock += dt
                self.version += 1
                self.committed_updates += len(clients)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                history.append(self._metric(r, eval_b, len(clients), 0,
                                            verbose))
        return history

    # -------------------------------------------------------- semisync mode
    def _run_semisync(self, rounds, eval_every, verbose):
        """Deadline-cutoff rounds: a full cohort is dispatched, but the
        server commits when the ``deadline_quantile``-fastest device is done.
        Stragglers are dropped — the server *aborts* them at the deadline,
        so their work is wasted but the device is freed for the next round —
        or carried: a carried update was computed at dispatch and is still
        cooking, so the device stays busy (excluded from resampling) and its
        update commits in a later round at exactly the staleness its
        lateness earned it.

        The deadline is **online-adaptive**: the server keeps a rolling
        window of observed client latencies (on-time rounds contribute
        their actual latency; aborted stragglers contribute the deadline —
        a censored observation, all the server ever measures for them) and
        sets each round's cutoff at the running ``deadline_quantile`` of
        that window.  The first rounds bootstrap from the current wave's
        oracle latencies (the cold-start estimate PR 5 used every round);
        ``deadline_quantile >= 1.0`` means wait-for-everyone and bypasses
        estimation entirely.  A progress guard keeps the deadline at or
        above the wave's fastest finisher so every round commits someone.

        Fault-injected dropouts never commit: a failed entry's event is the
        server's timeout, the entry is excluded from the wave (and from
        the carry set), and — when secure aggregation is on — its pairwise
        masks are reconstructed from the surviving roster (the dropout-
        recovery path)."""
        sim = self.sim
        history = []
        eval_b = sim.eval_batch()
        carried: List[_Pending] = []
        for r in range(rounds):
            # a carried straggler is still computing — never resample it
            # into the new cohort mid-flight
            clients = self._sample(sim.fed.clients_per_round, r,
                                   busy=frozenset(p.client.cid
                                                  for p in carried))
            wave = self._dispatch(clients, r) if clients else []
            if not wave:
                deadline = self.clock
            elif self.deadline_quantile >= 1.0:
                deadline = max(p.finish for p in wave)
            elif len(self._lat_window) >= 8:
                est = float(np.quantile(np.asarray(self._lat_window),
                                        self.deadline_quantile))
                # progress guard: however wrong the estimate, at least the
                # wave's fastest device commits this round
                deadline = max(self.clock + est,
                               min(p.finish for p in wave))
            else:
                # cold start: bootstrap from this wave's oracle latencies
                lat = sorted(p.finish - self.clock for p in wave)
                q = min(len(lat) - 1,
                        max(0, int(np.ceil(self.deadline_quantile * len(lat)))
                            - 1))
                deadline = self.clock + lat[q]
            failed = [p for p in wave if p.failed]
            live = [p for p in wave if not p.failed]
            on_time = [p for p in live if p.finish <= deadline]
            stragglers = [p for p in live if p.finish > deadline]
            arrivals = [p for p in carried if p.finish <= deadline]
            carried = [p for p in carried if p.finish > deadline]
            if self.straggler == "carry":
                carried += stragglers
            for p in on_time:
                self._lat_window.append(p.finish - p.start)
            for p in stragglers + failed:
                # censored: the server only knows they hadn't finished
                self._lat_window.append(max(deadline - p.start, 0.0))
            self.clock = deadline
            kept, stale = self._commit(on_time + arrivals)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                history.append(self._metric(r, eval_b, kept, stale, verbose))
        return history

    # ----------------------------------------------------------- async mode
    def _run_async(self, commits, eval_every, verbose):
        """FedBuff-style buffered async: ``concurrency`` clients in flight,
        completion events popped off the heap, a commit (and replacement
        dispatch wave) every ``buffer_size`` arrivals.

        A fault-injected dropout surfaces as a *timeout event* on the same
        heap: when it fires, the update is discarded (it never arrived) and
        the server immediately dispatches a replacement client — the
        re-dispatch rides the identical bucketed path (padded to
        ``bucket_pad``), so recovery costs no recompilation."""
        history = []
        eval_b = self.sim.eval_batch()
        heap: List[_Pending] = []
        for p in self._dispatch(self._sample(self.concurrency, 0), 0):
            heapq.heappush(heap, p)
        buffered: List[_Pending] = []
        done = 0
        while done < commits and (heap or buffered):
            if heap:
                p = heapq.heappop(heap)
                self.clock = p.finish
                if p.failed:
                    # timeout event: the client died mid-round — re-dispatch
                    # a replacement on the same heap and keep draining
                    busy = frozenset(q.client.cid for q in heap)
                    for q in self._dispatch(self._sample(1, done, busy),
                                            done):
                        heapq.heappush(heap, q)
                        self.redispatches += 1
                    continue
                buffered.append(p)
            if len(buffered) >= self.buffer_size or not heap:
                if not buffered:
                    break
                kept, stale = self._commit(buffered)
                buffered = []
                if kept:        # a staleness_cap can void a whole buffer —
                    done += 1   # the model didn't move, don't count a commit
                    if done % eval_every == 0 or done == commits:
                        history.append(self._metric(done - 1, eval_b, kept,
                                                    stale, verbose))
                if done < commits:
                    busy = frozenset(p.client.cid for p in heap)
                    refill = self.concurrency - len(heap)
                    for q in self._dispatch(
                            self._sample(refill, done, busy), done):
                        heapq.heappush(heap, q)
        return history
