"""Event-driven heterogeneous federation runtime (ISSUE 5 tentpole;
crash tolerance, trace-driven churn and adversarial hardening — ISSUE 7).

The lockstep ``run_rounds`` loop treated every eligible client as
interchangeable; real edge cohorts are not — the paper's whole premise is
the memory (and speed) disparity across devices.  ``FedScheduler`` replaces
the round loop with a **virtual clock**: every ``Client`` carries a
``DeviceProfile`` (compute FLOP/s, uplink bytes/s, memory — sampled in
``repro.data.partition``), each dispatched client's round cost is derived
from the analytic cost model (``core.memory.round_flops`` for compute,
``Strategy.comm_bytes_per_round`` over the link for upload), and the
scheduler pops client-*completion* events off a heap instead of iterating
rounds.

Three aggregation modes, all through the same ``PlanEngine`` machinery:

* ``sync``     — bit-identical to the legacy ``run_rounds`` (which is now a
  thin wrapper over this mode): sample a cohort, run one fused
  ``cohort_step`` per plan group, advance the clock by the slowest sampled
  device's compute + uplink time.
* ``semisync`` — deadline cutoff: the server waits only until the
  ``deadline_quantile``-fastest sampled device has finished; stragglers are
  ``"drop"``-ed (their work is wasted — the realistic accounting) or
  ``"carry"``-ed, committing in the round they actually finish with a
  staleness-discounted weight.
* ``async``    — FedBuff-style buffered aggregation: a fixed ``concurrency``
  of clients works continuously, completions accumulate in a buffer, and
  every ``buffer_size`` arrivals the server commits them with
  ``Strategy.staleness_weight``-discounted weights folded into the fused
  FedAvg tensordot, bumps the model version, and dispatches replacements.

**Bucketed dispatch** keeps the event loop jit-friendly: when a wave of
clients starts, they are grouped by their (hashable) ``TrainablePlan`` —
which carries ``grad_cfg``, so per-tier heterogeneous SPSA ``n_samples`` /
FedKSeed ``K`` form separate buckets — and each bucket runs ONE jitted
``PlanEngine.cohort_updates`` (vmap over the bucket axis) at the model
version current at dispatch.  Buckets are padded to a fixed ``bucket_pad``
(default: the concurrency), so the set of compilations is exactly
{(plan, bucket_pad)} — nothing recompiles inside the event loop, however
completions interleave.  The per-client updates park on the heap until
their completion events fire; committing a buffer is a cheap
staleness-weighted tensordot onto the *current* state — updates computed at
version v and applied at version v' > v are exactly what the staleness
discount prices.

**Crash tolerance** (ISSUE 7): the scheduler's entire run state — virtual
clock, pending heap (the stacked update buckets included), buffered /
carried entries, strategy trainable + stage machine + DP accountant, and
every host RNG the run consumes — round-trips through
``state_dict``/``load_state_dict`` (``repro.fed.checkpoint``).  ``run``
takes ``checkpoint_every``/``checkpoint_path`` for periodic atomic saves at
commit boundaries; a fresh process that rebuilds the same config, calls
``restore`` and re-runs finishes **bit-identically** to the uninterrupted
run — same trainable leaves, same ε, same RoundMetrics — with zero extra
jit compilations (plans rehydrate hash-equal).

**Trace-driven churn**: an ``AvailabilityTrace`` (``repro.data.partition``)
replaces i.i.d. Bernoulli dropout with replayable per-client availability
windows.  Sampling skips offline clients; a client whose window closes
mid-round becomes a timeout event at the moment it went offline; and when
*no* client is available the server parks a capped-exponential-backoff
retry event (``backoff_base``·2^k, capped at ``backoff_cap``) on the same
heap and re-dispatches when it fires.

**Planet-scale population runtime** (ISSUE 8): three additions make the
scheduler credible at millions of clients.

* *Lazy client state* — with ``FedSim(lazy=True)`` the population is a
  ``ClientPool``: a client's shard, rng stream and ``DeviceProfile`` are
  synthesized deterministically from ``(seed, cid)`` when it is dispatched
  and released after its update commits, so resident client state is
  O(active cohort) — a 10⁶-client run holds a few dozen clients.  Sampling
  is rejection-based (budget synthesized per candidate cid), never an
  O(population) enumeration.
* *Hierarchical aggregation* — a ``Topology`` routes each client to one of
  ``n_silos`` cross-silo aggregators (edge → silo → server): every server
  commit first reduces each silo's member updates into one silo-level
  update (weighted partial mean; a robust ``AGGREGATORS`` entry per silo
  via ``Topology.aggregator``; the DP clip applied at the silo tier and
  the noise at the server, composing per-tier), then commits the
  silo-level updates with silo weights.  ``n_silos=1`` routes through the
  flat path unchanged — bit-identical by construction; N-silo weighted
  means match the flat commit to float-associativity (≤1e-5, tested).
  Per-silo availability traces (``Topology.trace``) model a whole silo
  going dark.
* *Per-completion FedBuff* — ``pad_policy="pow2"`` pads dispatch buckets to
  the next power of two (capped at ``bucket_pad``) instead of always
  ``bucket_pad``, so ``buffer_size=1`` commits dispatch true size-1
  replacement buckets; the geometric pad is the dispatch-batching
  heuristic that keeps the compile set bounded ({(plan, 2^k)}) while
  coalescing compatible completions into shared bucket shapes.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.memory import round_flops
from ..utils.tree import tree_map
from . import privacy
from .engine import FedSim, RoundMetrics
from .faults import ClientBehavior, FaultModel, replace_rows
from .strategies import (cohort_fedavg, cohort_norms, make_aggregator,
                         scale_cohort, stack_masks)

MODES = ("sync", "semisync", "async")
PAD_POLICIES = ("fixed", "pow2")


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


# ==================================================== hierarchical topology
@dataclasses.dataclass(frozen=True)
class Topology:
    """Edge → cross-silo tier → server aggregation topology (ISSUE 8).

    ``n_silos=1`` is the flat cohort — the scheduler routes it through the
    unmodified single-tier commit, so the flat path is literally the 1-silo
    special case.  With ``n_silos>1`` every commit pre-aggregates each
    silo's member updates into one silo-level update (``SiloAggregator``)
    and the server commits those.

    assign            ``"block"`` (contiguous cid ranges — geographic silos)
                      or ``"mod"`` (round-robin cid striping).
    aggregator        silo-tier ``AGGREGATORS`` entry (``"fedavg"`` keeps
                      the weighted partial mean; robust entries like
                      ``"trimmed_mean"`` filter byzantine members *inside*
                      their silo, before the server ever sees them).
    aggregator_opts   frozen ``(key, value)`` pairs for the factory.
    trace             per-*silo* ``AvailabilityTrace`` (``n_silos`` rows):
                      a silo going dark takes its members offline — its
                      clients are not sampled and a window closing
                      mid-round times their dispatches out.
    """
    n_silos: int = 1
    assign: str = "block"
    aggregator: str = "fedavg"
    aggregator_opts: tuple = ()
    trace: object = None

    def __post_init__(self):
        if self.n_silos < 1:
            raise ValueError(f"n_silos must be >= 1, got {self.n_silos}")
        if self.assign not in ("block", "mod"):
            raise ValueError(f"assign policy {self.assign!r}: block|mod")
        if self.trace is not None and self.trace.n_clients < self.n_silos:
            raise ValueError(
                f"silo trace has {self.trace.n_clients} rows for "
                f"{self.n_silos} silos")

    def silo_of(self, cid: int, n_clients: int) -> int:
        if self.n_silos <= 1:
            return 0
        if self.assign == "mod":
            return int(cid) % self.n_silos
        return min(self.n_silos - 1,
                   int(cid) * self.n_silos // max(1, n_clients))


class SiloAggregator:
    """The cross-silo tier: reduces one commit's member updates, silo by
    silo, into ``(silo_delta, silo_weight)`` pairs and combines them at the
    server.

    Numerics contract: the silo delta is the *weighted partial mean* of its
    members (staleness-discounted sample weights) and the server takes the
    silo-weight-ed mean of silo deltas — algebraically identical to the
    flat sample-weighted mean, differing only in float summation order
    (≤1e-5; the 1-silo case never reaches this class).  Under DP the clip
    is applied to members at the silo tier and the Gaussian noise (scaled
    by the *total* member count, exactly as in the flat
    ``make_private_aggregate``) at the server — the per-tier composition.

    Compile discipline: when both tiers run fedavg (the common case) the
    whole commit — member gather, silo reduce vmapped over the silo axis,
    server combine — is ONE jitted call, the same per-commit dispatch
    cost as the flat path.  Every silo is padded to the commit's pow2 max
    member count with zero-weight rows (exact: a zero weight contributes
    ``0·u``), the member axis to pow2 with never-gathered zero rows, and
    the silo axis is the FULL topology (absent silos duplicate a present
    row with zero server weight) — the fused fn is keyed ``(plan, dp)``
    and re-traces only per pow2 ``(members, max-per-silo)`` pair, a
    handful of entries no matter how the cohort churns.  Robust
    aggregators are
    weight-blind, so they see exact sizes at their tier via the staged
    two-call path.  The event loop runs recompile-free after the first
    few commits."""

    def __init__(self, topology: Topology, strategy, n_clients: int):
        self.topology = topology
        self.strategy = strategy
        self.n_clients = int(n_clients)
        self._reduce_jit = {}     # (plan, padded_m, dp?) -> silo reduce
        self._server_jit = {}     # (plan, n_present, dp?) -> server combine
        self._fused_jit = {}      # (plan, dp?) -> whole two-tier commit
        # durable per-silo tallies (checkpointed): commits a silo
        # contributed to, member updates it forwarded
        self.silo_commits = np.zeros(topology.n_silos, np.int64)
        self.silo_updates = np.zeros(topology.n_silos, np.int64)

    def silo_of(self, cid: int) -> int:
        return self.topology.silo_of(cid, self.n_clients)

    def _cache_sizes(self) -> list:
        """Jit-cache entry counts (compile-stability assertions in tests)."""
        return [f._cache_size() for f in
                list(self._reduce_jit.values())
                + list(self._server_jit.values())
                + list(self._fused_jit.values())
                if hasattr(f, "_cache_size")]

    # ------------------------------------------------------------ silo tier
    def _reduce_fn(self, plan, m: int, dp: bool):
        """Batched silo reduce: ``(S, m, ...)`` member stacks for S
        same-padded-size silos → ``(S, ...)`` silo deltas in ONE vmapped
        jitted call — the silo tier costs O(1) device dispatches per
        commit, not O(n_silos)."""
        key = (plan, m, dp)
        if key not in self._reduce_jit:
            if not dp:
                def one(ups, w):
                    wn = w / jnp.sum(w)
                    return tree_map(
                        lambda u: jnp.tensordot(wn, u.astype(jnp.float32),
                                                axes=1), ups)
                fn = jax.jit(jax.vmap(one))
            else:
                def one(ups, w, clip):
                    # DP composes per-tier: members are clipped *here* (the
                    # edge→silo upload is the sensitive quantity) and the
                    # mean is uniform over live members — sample weights
                    # would make per-member sensitivity data-dependent,
                    # exactly as in the flat make_private_aggregate
                    ups = privacy.clip_cohort(ups, clip)
                    live = (w > 0).astype(jnp.float32)
                    wn = live / jnp.sum(live)
                    return tree_map(lambda u: jnp.tensordot(wn, u, axes=1),
                                    ups)
                fn = jax.jit(jax.vmap(one, in_axes=(0, 0, None)))
            self._reduce_jit[key] = fn
        return self._reduce_jit[key]

    def _robust_fn(self, plan, m: int, dp: bool):
        key = (plan, m, dp, "robust")
        if key not in self._reduce_jit:
            agg = make_aggregator(self.topology.aggregator,
                                  **dict(self.topology.aggregator_opts))

            def reduce(ups, w, clip=None):
                if clip is not None:
                    ups = privacy.clip_cohort(ups, clip)
                zeros = tree_map(
                    lambda u: jnp.zeros(u.shape[1:], jnp.float32), ups)
                return agg(zeros, ups, w, {})
            self._reduce_jit[key] = jax.jit(reduce)
        return self._reduce_jit[key]

    # ---------------------------------------------------------- server tier
    def _server_fn(self, plan, n_present: int, dp):
        key = (plan, n_present, dp is not None)
        if key not in self._server_jit:
            strat = self.strategy
            server_agg = cohort_fedavg
            if strat.aggregator != "fedavg":
                # the strategy's robust server aggregation treats silo
                # deltas as pseudo-clients
                server_agg = make_aggregator(
                    strat.aggregator, **dict(strat.aggregator_opts or {}))
            if dp is None:
                def combine(tr0, deltas, W):
                    return server_agg(tr0, deltas, W, {})
            else:
                sigma = float(dp.noise_multiplier)

                def combine(tr0, deltas, W, rng, clip, members):
                    new = server_agg(tr0, deltas, W, {})
                    # same mechanism as the flat DP commit: N(0,(σ·clip/C)²)
                    # per coordinate with C = total member count
                    std = sigma * clip / members
                    noise = privacy.gaussian_noise_tree(
                        jax.random.fold_in(rng, 0x0D9), new, std)
                    return tree_map(
                        lambda x, n: (x.astype(jnp.float32) + n
                                      ).astype(x.dtype), new, noise)
            self._server_jit[key] = jax.jit(combine)
        return self._server_jit[key]

    # ----------------------------------------------------------- fused path
    def _fused_fn(self, plan, dp):
        """The whole two-tier commit — member gather, vmapped silo reduce,
        server combine (+ DP noise) — as ONE jitted call, matching the flat
        path's one-dispatch-per-commit cost.  Only valid when both tiers
        run fedavg (robust aggregators are weight-blind and need exact
        sizes → the staged path).  Keyed ``(plan, dp)``; jit re-traces per
        pow2-padded member-count/max-per-silo shape pair (the silo axis is
        churn-independent), so the trace set stays a handful."""
        key = (plan, dp is not None)
        if key not in self._fused_jit:
            if dp is None:
                def fused(tr0, ups, gather, mask, weights, W):
                    sub = tree_map(lambda u: u[gather], ups)
                    w_mat = weights[gather] * mask

                    def one(u, w):
                        wn = w / jnp.sum(w)
                        return tree_map(
                            lambda x: jnp.tensordot(
                                wn, x.astype(jnp.float32), axes=1), u)
                    deltas = jax.vmap(one)(sub, w_mat)
                    return cohort_fedavg(tr0, deltas, W, {})
            else:
                sigma = float(dp.noise_multiplier)

                def fused(tr0, ups, gather, mask, weights, W, rng, clip,
                          members):
                    sub = tree_map(lambda u: u[gather], ups)
                    w_mat = weights[gather] * mask

                    def one(u, w, clip):
                        # DP composes per-tier: members clipped at the silo
                        # (the edge→silo upload is the sensitive quantity),
                        # uniform live-member mean — as in the flat
                        # make_private_aggregate
                        u = privacy.clip_cohort(u, clip)
                        live = (w > 0).astype(jnp.float32)
                        wn = live / jnp.sum(live)
                        return tree_map(
                            lambda x: jnp.tensordot(wn, x, axes=1), u)
                    deltas = jax.vmap(one, in_axes=(0, 0, None))(
                        sub, w_mat, clip)
                    new = cohort_fedavg(tr0, deltas, W, {})
                    std = sigma * clip / members
                    noise = privacy.gaussian_noise_tree(
                        jax.random.fold_in(rng, 0x0D9), new, std)
                    return tree_map(
                        lambda x, n: (x.astype(jnp.float32) + n
                                      ).astype(x.dtype), new, noise)
            self._fused_jit[key] = jax.jit(fused)
        return self._fused_jit[key]

    # --------------------------------------------------------------- commit
    def commit(self, plan, tr0, es, ups, weights, rng, clip):
        """Two-tier aggregation of one plan group: ``es`` are the commit's
        entries (dispatch order), ``ups`` their stacked ``(E, ...)`` update
        tree, ``weights`` the (E,) staleness-discounted sample weights as a
        HOST array — the silo-weight sums must never force a device sync
        (a per-commit sync stalls the async dispatch pipeline and halves
        events/s).  Returns ``(new_trainable, silos_present)``."""
        strat = self.strategy
        dp = strat.dp
        by_silo = {}
        for i, e in enumerate(es):
            by_silo.setdefault(self.silo_of(e.client.cid), []).append(i)
        order = sorted(by_silo)
        # silo weights + tallies in one host pass — no device syncs
        w_host = np.asarray(weights, np.float32)
        W = []
        for s in order:
            idx = by_silo[s]
            # silo weight: total member weight (DP: live member count — the
            # uniform-mean composition)
            W.append(float(len(idx)) if dp is not None
                     else float(w_host[idx].sum()))
            self.silo_commits[s] += 1
            self.silo_updates[s] += len(idx)
        S = len(order)
        robust_silo = self.topology.aggregator != "fedavg"
        robust_server = strat.aggregator != "fedavg"
        if not robust_silo and not robust_server:
            # the common fedavg/fedavg commit: gather → silo reduce →
            # server combine run as ONE jitted call whose every input
            # shape is churn-independent, so the trace set saturates in
            # the first few commits.  Member slots pad to the commit's
            # pow2 max member count (zero-weight: exact under the
            # weighted mean, excluded from the DP live-mask), absent/pad
            # silo rows duplicate the first present row — mask included,
            # so no 0/0 — with zero server weight; the member axis pads
            # to pow2 with zero rows that are never gathered.  The silo
            # axis is the FULL topology when small (no silos-present in
            # the key at all), pow2-compacted beyond that.
            ns = self.topology.n_silos
            tgt = _pow2_at_least(max(len(by_silo[s]) for s in order))
            if ns <= 64:
                R = ns
                rows = order                     # row = absolute silo id
            else:
                R = _pow2_at_least(S)
                rows = range(S)                  # row = compacted position
            idx_mat = np.zeros((R, tgt), np.int64)
            mask = np.zeros((R, tgt), np.float32)
            Wv = np.zeros(R, np.float32)
            present = np.zeros(R, bool)
            for pos, (r, s) in enumerate(zip(rows, order)):
                idx = by_silo[s]
                m = len(idx)
                idx_mat[r, :m] = idx
                idx_mat[r, m:] = idx[-1]
                mask[r, :m] = 1.0
                Wv[r] = W[pos]
                present[r] = True
            idx_mat[~present] = idx_mat[rows[0]]
            mask[~present] = mask[rows[0]]
            E = len(es)
            Ep = _pow2_at_least(E)
            if Ep > E:
                ups = tree_map(lambda u: jnp.concatenate(
                    [u, jnp.zeros((Ep - E,) + u.shape[1:], u.dtype)]), ups)
            w_pad = np.zeros(Ep, np.float32)
            w_pad[:E] = w_host
            fn = self._fused_fn(plan, dp)
            if dp is None:
                new = fn(tr0, ups, idx_mat, mask, w_pad, Wv)
            else:
                new = fn(tr0, ups, idx_mat, mask, w_pad, Wv, rng,
                         clip, jnp.float32(E))
            return new, S
        weights = jnp.asarray(w_host)
        if robust_silo:
            deltas = []
            for s in order:
                idx = np.asarray(by_silo[s], np.int64)
                sub = tree_map(lambda u: u[idx], ups)
                w_s = weights[jnp.asarray(idx)]
                fn = self._robust_fn(plan, len(idx), dp is not None)
                deltas.append(fn(sub, w_s, clip) if dp is not None
                              else fn(sub, w_s))
            stacked = tree_map(lambda *ds: jnp.stack(ds), *deltas)
        else:
            # robust server over fedavg silos: batched vmapped reduce,
            # sliced to the S real silo deltas — robust aggregators are
            # weight-blind and must see exact sizes
            tgt = _pow2_at_least(max(len(by_silo[s]) for s in order))
            Sp = _pow2_at_least(S)
            idx_mat = np.zeros((Sp, tgt), np.int64)
            mask = np.zeros((Sp, tgt), np.float32)
            for r, s in enumerate(order):
                idx = by_silo[s]
                m = len(idx)
                idx_mat[r, :m] = idx
                idx_mat[r, m:] = idx[-1]
                mask[r, :m] = 1.0
            idx_mat[S:] = idx_mat[0]
            mask[S:] = mask[0]
            gather = jnp.asarray(idx_mat)
            sub = tree_map(lambda u: u[gather], ups)
            w_mat = weights[gather] * jnp.asarray(mask)
            fn = self._reduce_fn(plan, tgt, dp is not None)
            out = (fn(sub, w_mat, clip) if dp is not None
                   else fn(sub, w_mat))
            stacked = tree_map(lambda d: d[:S], out)
        Wv = jnp.asarray(W, jnp.float32)
        fn = self._server_fn(plan, S, dp)
        if dp is None:
            new = fn(tr0, stacked, Wv)
        else:
            new = fn(tr0, stacked, Wv, rng, clip,
                     jnp.float32(len(es)))
        return new, S

    # ------------------------------------------------------- durable state
    def state_dict(self) -> dict:
        return {"silo_commits": np.asarray(self.silo_commits),
                "silo_updates": np.asarray(self.silo_updates)}

    def load_state_dict(self, s: dict) -> None:
        self.silo_commits = np.asarray(s["silo_commits"], np.int64).copy()
        self.silo_updates = np.asarray(s["silo_updates"], np.int64).copy()


def run_sync_rounds(sim: FedSim, strategy, rounds: int, eval_every: int = 5,
                    verbose: bool = False):
    """The one-call lockstep driver — ``FedScheduler(mode="sync").run``.
    This is what the deprecated ``engine.run_rounds`` aliases; call this (or
    ``run_experiment``) in new code."""
    return FedScheduler(sim, strategy, mode="sync").run(
        rounds, eval_every=eval_every, verbose=verbose)


def client_round_time(sim: FedSim, strategy, client, plan=None) -> float:
    """Virtual seconds for one client's local round: analytic compute FLOPs
    over the device's effective throughput, plus the strategy's per-round
    uplink over the device's link.  ``plan`` (when given) supplies the
    gradient-program knobs — per-tier ``n_samples``/``seeds`` budgets make
    slow devices cheaper per round, which is the whole point of
    memory-stratified perturbation budgets."""
    kw = dict(strategy.memory_kwargs(0))
    opts = dict(plan.grad_options) if plan is not None else {}
    if "n_samples" in opts:
        kw["n_samples"] = opts["n_samples"]
    if "seeds" in opts:
        kw["kseeds"] = len(opts["seeds"])
    if plan is not None and plan.is_window:
        # the executed prefix walks with the DLCT stage — charge the plan's
        # actual window position, not the round-0 FOAT boundary
        seg = plan.window_segments
        kw["l_start"], kw["window"] = seg.prefix, seg.window
    flops = round_flops(sim.cfg, strategy.memory_method, sim.batch_size,
                        sim.seq_len,
                        local_steps=strategy.chain.local_steps, **kw)
    prof = client.profile
    if prof is None:
        return 1.0
    return flops / prof.flops + strategy.comm_bytes_per_round() / prof.bandwidth


@dataclasses.dataclass
class _Pending:
    """One dispatched client parked on the virtual clock: its update was
    computed at dispatch (model version ``version``) and lives as row
    ``bi`` of its bucket's stacked ``(C, ...)`` update tree — kept stacked
    so a commit of a whole contiguous bucket (the common case) is a single
    prefix slice per leaf instead of C gathers + a restack.  It commits
    when its completion event fires.

    ``retry >= 0`` marks a *backoff retry event* instead of a client: no
    update, no device — when it fires the scheduler attempts a dispatch and,
    failing again, parks the next retry at twice the delay."""
    finish: float
    client: object
    plan: object
    bucket: object          # the dispatch bucket's stacked (C, ...) updates
    bi: int                 # this client's row in the bucket
    masks: dict
    weight: float           # sample count (staleness discount applied later)
    version: int            # model version the update was computed at
    seq: int = 0            # dispatch order — deterministic heap tie-break
    loss: object = None     # device scalar: this client's mean local loss
    start: float = 0.0      # dispatch clock — observed latency = finish-start
    failed: bool = False    # fault-injected dropout: `finish` is the server's
                            # timeout event, the update never arrives
    session: object = None  # secure-agg masking session of this entry's
                            # dispatch bucket (None when masking is off)
    retry: int = -1         # >= 0: backoff retry event (client is None)

    def __lt__(self, other):
        return (self.finish, self.seq) < (other.finish, other.seq)


def _stack_updates(entries: List["_Pending"]):
    """Cohort-axis update stack for a commit group (already sorted back
    into dispatch order): a whole contiguous bucket reuses its
    already-stacked tree — at most one prefix slice per leaf — while mixed
    groups (straggler carry-over, partial buffers) fall back to per-entry
    gathers."""
    first = entries[0]
    if (all(e.bucket is first.bucket for e in entries)
            and [e.bi for e in entries] == list(range(len(entries)))):
        n = len(entries)
        rows = jax.tree_util.tree_leaves(first.bucket)[0].shape[0]
        if n == rows:
            return first.bucket
        return tree_map(lambda u: u[:n], first.bucket)
    return tree_map(lambda *us: jnp.stack(us),
                    *[tree_map(lambda u: u[e.bi], e.bucket)
                      for e in entries])


class FedScheduler:
    """Event-driven federation driver over a heterogeneous device population.

    Parameters
    ----------
    mode : ``"sync"`` | ``"semisync"`` | ``"async"``
    concurrency : clients working in parallel (async; default
        ``fed.clients_per_round``).
    buffer_size : completions per server commit (async; default
        = concurrency — with uniform device profiles this makes ``async``
        coincide with ``sync``).
    deadline_quantile : fraction of the sampled cohort the server waits for
        (semisync; default 0.75 — the slowest quarter are stragglers).
    straggler : ``"drop"`` (aborted at the deadline: work wasted, device
        freed) or ``"carry"`` (stragglers keep computing — excluded from
        resampling — and commit late with a staleness-discounted weight) —
        semisync only.
    bucket_pad : fixed bucket size dispatch waves are padded to (default:
        concurrency).  Keys the jit cache as (plan, bucket_pad): a fixed pad
        means no recompiles inside the event loop even when heterogeneous
        per-tier plans split a wave into uneven buckets.
    staleness_cap : drop (instead of discount) updates staler than this many
        versions (async; default: keep all).
    faults : ``ClientBehavior`` (or a prebuilt ``FaultModel``) — inject
        dropouts (timeout event + async re-dispatch on the same heap),
        byzantine update corruption (scaling or model replacement), and
        intermittent stragglers.  Requires an event-driven mode: the
        lockstep sync path has no timeout machinery to detect a failure
        with.
    trace : ``AvailabilityTrace`` — replayable per-client availability
        windows replacing Bernoulli dropout (may be combined with
        ``faults``; a bare trace builds a benign ``FaultModel`` around
        itself).  Offline clients are never sampled; a window closing
        mid-round drops the update at the closing time.
    backoff_base / backoff_cap / max_backoff_retries : capped exponential
        backoff for dispatch attempts that find no available client —
        delay = min(base · 2^k, cap), giving up after ``max_backoff_retries``
        consecutive failures.
    pad_policy : ``"fixed"`` (every bucket padded to ``bucket_pad`` — one
        compile per plan) or ``"pow2"`` (padded to the next power of two,
        capped at ``bucket_pad`` — the per-completion dispatch-batching
        heuristic: size-1 replacement buckets compile at (plan, 1) instead
        of paying a full-width padded wave, with the compile set still
        bounded at {(plan, 2^k)}).
    topology : a ``Topology`` — hierarchical edge → silo → server
        aggregation.  ``n_silos=1`` (or ``None``) is the flat cohort;
        ``Topology.trace`` adds per-silo availability on top of any
        client-level trace.
    """

    def __init__(self, sim: FedSim, strategy, mode: str = "sync", *,
                 concurrency: Optional[int] = None,
                 buffer_size: Optional[int] = None,
                 deadline_quantile: float = 0.75,
                 straggler: str = "drop",
                 bucket_pad: Optional[int] = None,
                 staleness_cap: Optional[int] = None,
                 faults=None, trace=None,
                 backoff_base: float = 1.0, backoff_cap: float = 60.0,
                 max_backoff_retries: int = 60,
                 pad_policy: str = "fixed",
                 topology: Optional[Topology] = None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; one of {MODES}")
        if straggler not in ("drop", "carry"):
            raise ValueError(f"straggler policy {straggler!r}: drop|carry")
        if pad_policy not in PAD_POLICIES:
            raise ValueError(f"unknown pad_policy {pad_policy!r}; "
                             f"one of {PAD_POLICIES}")
        if isinstance(faults, ClientBehavior):
            faults = FaultModel(faults, sim.fed.n_clients, trace=trace)
        elif faults is None and trace is not None:
            faults = FaultModel(ClientBehavior(), sim.fed.n_clients,
                                trace=trace)
        elif faults is not None and trace is not None:
            faults.trace = trace
        if faults is not None and mode == "sync":
            raise ValueError(
                "fault injection needs the event-driven runtime (semisync/"
                "async): the lockstep sync path has no timeout events")
        if strategy.secure is not None:
            if mode == "async":
                raise ValueError(
                    "secure aggregation needs round-scoped masking sessions; "
                    "async FedBuff commits mix arbitrary dispatch waves — "
                    "use sync or semisync")
            if mode == "semisync" and straggler == "carry":
                raise ValueError(
                    "secure aggregation with straggler='carry' would commit "
                    "one session across several rounds; use straggler='drop'")
            if strategy.aggregator != "fedavg":
                raise ValueError(
                    "secure aggregation only supports the linear fedavg "
                    f"mean; robust aggregator {strategy.aggregator!r} needs "
                    "plaintext per-client updates")
        self.topology = topology
        if topology is not None and topology.n_silos > 1:
            if strategy.secure is not None:
                raise ValueError(
                    "secure aggregation masks per dispatch bucket — pairwise "
                    "sessions cannot span the cross-silo tier; use n_silos=1")
            self._silo = SiloAggregator(topology, strategy,
                                        sim.fed.n_clients)
        else:
            self._silo = None
        self.sim, self.strategy, self.mode = sim, strategy, mode
        self.pad_policy = pad_policy
        self.spec = None            # ExperimentSpec embedded in checkpoints
        self.concurrency = concurrency or sim.fed.clients_per_round
        self.buffer_size = buffer_size or self.concurrency
        if self.buffer_size > self.concurrency:
            raise ValueError(
                f"buffer_size {self.buffer_size} > concurrency "
                f"{self.concurrency}: at most `concurrency` completions can "
                f"ever be outstanding, so a larger buffer would never fill")
        self.deadline_quantile = deadline_quantile
        self.straggler = straggler
        self.bucket_pad = bucket_pad or self.concurrency
        self.staleness_cap = staleness_cap
        self.faults: Optional[FaultModel] = faults
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.max_backoff_retries = int(max_backoff_retries)
        self.clock = 0.0            # virtual seconds
        self.version = 0            # server model version (commits so far)
        self._times = {}            # (cid, plan) -> cached round time
        self._seq = 0               # dispatch counter (heap tie-break)
        self._agg_jit = {}          # plan -> jitted commit aggregation
        self._corrupt_jit = None    # jitted byzantine per-bucket scaling
        self._replace_jit = None    # jitted model-replacement row blend
        self.committed_updates = 0  # client updates aggregated so far
        self.fault_dropouts = 0     # dispatches lost to injected dropouts
        self.trace_dropouts = 0     # dispatches lost to availability windows
        self.silo_dropouts = 0      # dispatches lost to silo-level windows
        self.redispatches = 0       # replacement dispatches (async recovery)
        self.backoff_retries = 0    # no-client-available backoff events
        self.events = 0             # scheduler events processed (dispatches
                                    # + commits + timeouts/retries) — the
                                    # bench_round --population events/s
        self.tier_bytes = {"edge": 0, "silo": 0}  # per-tier comm accounting
        # observed round latencies (on-time actuals; stragglers enter
        # censored at the deadline) — the adaptive semisync deadline
        self._lat_window = deque(maxlen=512)
        # durable loop state (checkpoint/resume): where the run is, plus the
        # in-flight entries a crash would otherwise lose
        self._round = 0                 # rounds completed (sync/semisync)
        self._done = 0                  # commits completed (async)
        self._history: List[RoundMetrics] = []
        self._heap: List[_Pending] = []       # async event heap
        self._buffered: List[_Pending] = []   # async partial buffer
        self._carried: List[_Pending] = []    # semisync carried stragglers
        self._started = False           # strategy.begin already ran
        self._async_seeded = False      # initial async dispatch done
        self._ckpt = None
        self._halt_after = None

    # ------------------------------------------------------------------ run
    def run(self, rounds: int, eval_every: int = 5, verbose: bool = False,
            *, checkpoint_every: Optional[int] = None,
            checkpoint_path=None,
            halt_after: Optional[int] = None) -> List[RoundMetrics]:
        """Drive ``rounds`` server commits and return the metric history.
        In sync/semisync a commit is a round; in async it is a buffer flush
        — histories are comparable via ``RoundMetrics.wallclock``.

        ``checkpoint_every``/``checkpoint_path`` save the full run state
        (``save``) every N completed rounds/commits; ``halt_after`` stops
        the loop after that unit — the crash-simulation hook the resume
        equality tests (and the CI smoke) kill the run with.  A resumed
        scheduler (``restore``) continues exactly where the checkpoint was
        taken; call ``run`` again with the *same* total ``rounds``."""
        self._ckpt = ((int(checkpoint_every), checkpoint_path)
                      if checkpoint_every and checkpoint_path is not None
                      else None)
        self._halt_after = halt_after
        if self.mode == "sync":
            # sync preserves the legacy ordering exactly: one-off setup
            # (chainfed FOAT) runs *inside* the first Strategy.round, after
            # that round's eligibility sampling — bit-identical histories.
            # The hierarchical sync wave bypasses Strategy.round, so begin
            # must run here instead.
            if self._silo is not None and not self._started:
                self._started = True
                self.strategy.begin(self.sim)
            return self._run_sync(rounds, eval_every, verbose)
        if not self._started:
            self._started = True
            self.strategy.begin(self.sim)
        if self.mode == "semisync":
            return self._run_semisync(rounds, eval_every, verbose)
        return self._run_async(rounds, eval_every, verbose)

    # ------------------------------------------------------------- plumbing
    def _round_time(self, client, plan) -> float:
        key = (client.cid, plan)
        if key not in self._times:
            self._times[key] = client_round_time(self.sim, self.strategy,
                                                 client, plan)
        return self._times[key]

    def _metric(self, r, eval_b, n, stale, verbose) -> RoundMetrics:
        loss, acc = self.strategy.evaluate(eval_b)
        eps = 0.0
        if self.strategy.dp is not None:
            eps, _ = self.strategy.dp_accountant.epsilon(
                self.strategy.dp.delta)
        m = RoundMetrics(r, loss, acc, n,
                         self.strategy.comm_bytes_per_round(),
                         wallclock=self.clock, stale_updates=stale,
                         dp_epsilon=eps,
                         silo_comm_bytes=int(self.tier_bytes["silo"]))
        if verbose:
            dp = f" ε={eps:.2f}" if self.strategy.dp is not None else ""
            print(f"  round {r:3d} n={n:2d} loss={loss:.4f} acc={acc:.4f} "
                  f"t={self.clock:.1f}s stale={stale}{dp}")
        return m

    def _has_trace(self) -> bool:
        return self.faults is not None and self.faults.trace is not None

    def _silo_trace(self) -> bool:
        return self.topology is not None and self.topology.trace is not None

    def _churny(self) -> bool:
        """Any availability machinery that can empty a sample (and so
        justifies a backoff retry instead of a wasted round)."""
        return self._has_trace() or self._silo_trace()

    def _silo_available(self, cid: int) -> bool:
        t = self.topology
        return t.trace.available(t.silo_of(cid, self.sim.n_clients),
                                 self.clock)

    def _silo_cut(self, cid: int, t0: float, t1: float):
        t = self.topology
        return t.trace.offline_cut(t.silo_of(cid, self.sim.n_clients),
                                   t0, t1)

    def _checkpoint_unit(self, unit: int) -> bool:
        """Persist the run after completing ``unit`` (a round / a commit)
        when it falls on the checkpoint cadence; returns True when the run
        should halt here (``halt_after`` crash simulation)."""
        if self._ckpt is not None and unit % self._ckpt[0] == 0:
            self.save(self._ckpt[1])
        return self._halt_after is not None and unit >= self._halt_after

    def _sample(self, n: int, round_idx: int, busy=frozenset()):
        """Sample ``n`` clients from the eligible pool, never re-dispatching
        a client that is still in flight (``busy``: cids parked on the
        event heap — a device cannot compute two overlapping local rounds)
        and — under an availability trace — never one that is offline at
        the current clock.  When ``n`` equals the configured cohort size
        and nothing constrains the pool this is exactly
        ``sim.sample_clients`` — the same rng draws in the same order as
        the sync path, which is what makes async-with-uniform-latencies
        coincide with sync."""
        sim, strat = self.sim, self.strategy
        if sim.lazy:
            # the lazy pool never enumerates the population: rejection-
            # sample cids, testing the cheap (seed, cid) budget synthesis
            # plus whatever availability applies at the current clock
            if n <= 0:
                return []
            has_t, has_s = self._has_trace(), self._silo_trace()
            avail = None
            if has_t or has_s:
                def avail(cid):
                    if has_t and not self.faults.available(cid, self.clock):
                        return False
                    return not has_s or self._silo_available(cid)
            return sim.pool_sample(n, strat.memory_method,
                                   dict(strat.memory_kwargs(round_idx)),
                                   busy=busy, avail=avail)
        if not busy and n == sim.fed.clients_per_round \
                and not self._churny():
            return sim.sample_clients(strat.memory_method,
                                      **strat.memory_kwargs(round_idx))
        pool = [c for c in sim.eligible(strat.memory_method,
                                        **strat.memory_kwargs(round_idx))
                if c.cid not in busy
                and (self.faults is None
                     or self.faults.available(c.cid, self.clock))
                and (not self._silo_trace()
                     or self._silo_available(c.cid))]
        if not pool or n <= 0:
            return []
        k = min(n, len(pool))
        idx = sim.rng.choice(len(pool), k, replace=False)
        return [pool[i] for i in idx]

    # ------------------------------------------------------- dispatch waves
    def _dispatch(self, clients, round_idx: int) -> List[_Pending]:
        """Start a wave of clients at the current model version: bucket by
        plan, pad each bucket to a shape-stable size, run one jitted
        ``cohort_updates`` per bucket, and return the per-client pending
        completions (absolute finish times on the virtual clock).

        Pad targets are the no-recompile contract.  ``pad_policy="fixed"``
        pads every bucket to ``bucket_pad`` (one compiled shape per plan);
        ``"pow2"`` pads to the next power of two capped at ``bucket_pad``
        (compile set {(plan, 2^k)}, k ≤ log₂ bucket_pad) — the dispatch-
        batching heuristic that makes per-completion FedBuff (size-1
        buckets) cheap while still coalescing larger waves without new
        shapes."""
        strat, sim = self.strategy, self.sim
        groups = {}
        for c in clients:
            groups.setdefault(strat.plan(c, round_idx), []).append(c)
        pending = []
        for plan, bucket in groups.items():
            n = len(bucket)
            batches = sim.cohort_batches(bucket, strat.chain.local_steps)
            mask_list = [strat.plan_masks(sim, c, round_idx) for c in bucket]
            masks = stack_masks(mask_list)
            if self.pad_policy == "pow2":
                tgt = max(min(_pow2_at_least(n), self.bucket_pad), n)
            else:
                tgt = max(self.bucket_pad, n)
            pad = max(0, tgt - n)
            if pad:
                # pad with *copies of already-drawn rows* — no extra sampler
                # draws, so padding never perturbs the data stream; padded
                # rows are computed and discarded (weightless)
                rep = lambda x: jnp.concatenate(
                    [x, jnp.repeat(x[-1:], pad, axis=0)], axis=0)
                batches = tree_map(rep, batches)
                masks = {k: rep(v) for k, v in masks.items()}
            tr0 = strat.init_trainable(plan)
            step = strat.engine.cohort_updates(plan)
            updates, losses = step(tr0, strat.params, strat.adapters,
                                   batches, masks)
            if self.faults is not None and self.faults.byzantine:
                # corruption is one shape-stable jitted op over the padded
                # bucket — the event loop's no-recompile guarantee holds
                # with byzantine clients in play
                if self.faults.behavior.attack == "replacement":
                    updates = self._apply_replacement(updates, tr0, bucket,
                                                      n, pad)
                else:
                    scales = np.ones(n + pad, np.float32)
                    scales[:n] = self.faults.update_scales(
                        [c.cid for c in bucket])
                    if self._corrupt_jit is None:
                        self._corrupt_jit = jax.jit(scale_cohort)
                    updates = self._corrupt_jit(updates,
                                                jnp.asarray(scales))
            session = (privacy.new_session(strat,
                                           [c.cid for c in bucket])
                       if strat.secure is not None else None)
            for i, c in enumerate(bucket):
                self._seq += 1
                t = self._round_time(c, plan)
                failed = False
                if self.faults is not None:
                    draw = self.faults.draw(c.cid, self._seq)
                    t *= draw.slowdown
                    if draw.dropped:
                        failed = True
                        t *= self.faults.behavior.timeout_factor
                        self.fault_dropouts += 1
                    else:
                        # availability window closing mid-round: the client
                        # goes dark at `cut` — the server's timeout event
                        cut = self.faults.offline_cut(c.cid, self.clock,
                                                      self.clock + t)
                        if cut is not None:
                            failed = True
                            t = max(cut - self.clock, 0.0)
                            self.trace_dropouts += 1
                if not failed and self._silo_trace():
                    # a silo going dark mid-round takes its members with it
                    cut = self._silo_cut(c.cid, self.clock, self.clock + t)
                    if cut is not None:
                        failed = True
                        t = max(cut - self.clock, 0.0)
                        self.silo_dropouts += 1
                pending.append(_Pending(
                    finish=self.clock + t,
                    client=c, plan=plan, bucket=updates, bi=i,
                    masks=mask_list[i], weight=float(c.n_samples),
                    version=self.version, seq=self._seq, loss=losses[i],
                    start=self.clock, failed=failed, session=session))
        self.events += len(pending)
        return pending

    def _apply_replacement(self, updates, tr0, bucket, n, pad):
        """Model-replacement poisoning (targeted backdoor-style attack):
        each byzantine row is overwritten with ``boost · (target − x₀)`` so
        a plain weighted mean lands the aggregate on the attacker's target
        model.  One shape-stable jitted blend over the padded bucket."""
        fm = self.faults
        row = tree_map(lambda u: u[0], updates)
        if (jax.tree_util.tree_structure(row)
                != jax.tree_util.tree_structure(tr0)):
            raise ValueError(
                "model-replacement attack needs trainable-shaped updates; "
                "this strategy ships a different update structure (e.g. "
                "FedKSeed's seed-space coefficients) — use attack='scaling'")
        marks = np.zeros(n + pad, np.float32)
        marks[:n] = fm.byzantine_marks([c.cid for c in bucket])
        target = fm.replacement_target(tr0)
        if self._replace_jit is None:
            self._replace_jit = jax.jit(replace_rows)
        return self._replace_jit(updates, jnp.asarray(marks), tr0, target,
                                 jnp.float32(fm.behavior.replace_boost))

    # --------------------------------------------------------------- commit
    def _commit(self, entries: List[_Pending]):
        """Fold a batch of completed updates into the current model: group
        by plan, stack each group's updates/masks along the cohort axis, and
        run the strategy's in-graph aggregation (default fused FedAvg) with
        weights = sample count × staleness discount.  Returns ``(kept,
        stale)`` — updates committed (post ``staleness_cap`` filter; 0 means
        the model did not move and the caller must not count a commit) and
        how many of them were stale."""
        strat = self.strategy
        consumed = entries        # every entry hands its client back to the
                                  # lazy pool, committed or stale-voided
        if self.staleness_cap is not None:
            entries = [e for e in entries
                       if self.version - e.version <= self.staleness_cap]
        if not entries:
            self.sim.release_clients([e.client for e in consumed])
            return 0, 0
        groups = {}
        for e in entries:
            groups.setdefault(e.plan, []).append(e)
        stale = 0
        # convergence-driven schedules (chainfed plateau advance) read the
        # committed mean local loss lazily — one value for the *whole*
        # server commit, not whichever plan group happened to run last
        strat._last_round_loss = jnp.mean(
            jnp.stack([jnp.asarray(e.loss) for e in entries]))
        dp_rng = (jax.random.fold_in(strat._dp_key, self.version)
                  if strat.dp is not None else None)
        adaptive = strat.dp is not None and strat.dp.adaptive_clip
        strat.begin_commit()
        for gi, (plan, es) in enumerate(groups.items()):
            # completion events interleave arbitrarily; restoring dispatch
            # order makes the cohort axis deterministic (and identical to
            # the sync cohort order), and re-enables the whole-bucket
            # zero-copy fast path in _stack_updates
            es.sort(key=lambda e: e.seq)
            stale += sum(1 for e in es if e.version < self.version)
            tr0 = strat.init_trainable(plan)
            rng = (jax.random.fold_in(dp_rng, gi)
                   if dp_rng is not None else jax.random.PRNGKey(0))
            if strat.secure is not None:
                # per-session unmasking: each dispatch bucket agreed its
                # own pairwise masks — survivors unmask per session,
                # dropped roster members' masks are reconstructed
                sgroups = {}
                for e in es:
                    sgroups.setdefault(id(e.session),
                                       (e.session, []))[1].append(
                        (e.client.cid,
                         tree_map(lambda u: u[e.bi], e.bucket),
                         e.weight * strat.staleness_weight(
                             self.version - e.version)))
                new = privacy.secure_commit(strat, plan, tr0,
                                            list(sgroups.values()), rng=rng)
            elif self._silo is not None:
                # hierarchical commit: silo partial reduces, then the
                # server combines silo deltas — per-tier comm accounted
                if strat.cohort_aggregate(plan) is not None:
                    raise ValueError(
                        f"strategy {type(strat).__name__} aggregates in a "
                        "custom update space (cohort_aggregate) — the "
                        "cross-silo tier only composes with trainable-"
                        "shaped updates; run it flat (n_silos=1)")
                ups = _stack_updates(es)
                # host-side weights: the silo tier sums them per silo
                # without ever syncing the device pipeline
                w = np.asarray(
                    [e.weight
                     * strat.staleness_weight(self.version - e.version)
                     for e in es], np.float32)
                clip = (jnp.float32(privacy.current_clip(strat))
                        if strat.dp is not None else None)
                new, n_silos_present = self._silo.commit(
                    plan, tr0, es, ups, w, rng, clip)
                if adaptive:
                    privacy.observe_update_norms(strat, cohort_norms(ups))
                payload = strat.comm_bytes_per_round() // max(
                    1, self.sim.fed.clients_per_round)
                self.tier_bytes["edge"] += payload * len(es)
                self.tier_bytes["silo"] += payload * n_silos_present
            else:
                ups = _stack_updates(es)
                masks = stack_masks([e.masks for e in es])
                if adaptive:
                    # the clip rides in as a traced (C,) mask row — its
                    # drift never recompiles the jitted aggregation
                    masks = {**masks, "dp_clip": jnp.full(
                        (len(es),), privacy.current_clip(strat),
                        jnp.float32)}
                w = jnp.asarray(
                    [e.weight
                     * strat.staleness_weight(self.version - e.version)
                     for e in es], jnp.float32)
                if plan not in self._agg_jit:
                    self._agg_jit[plan] = jax.jit(
                        strat.resolve_aggregate(plan))
                new = self._agg_jit[plan](tr0, ups, w, masks, rng)
                if adaptive:
                    privacy.observe_update_norms(strat, cohort_norms(ups))
            strat.commit_trainable(plan, new)
        strat.end_commit()
        self.version += 1
        self.committed_updates += len(entries)
        self.events += 1
        if strat.dp is not None:
            strat.dp_accountant.step(
                strat.dp.noise_multiplier,
                q=len(entries) / max(1, self.sim.n_clients))
        self.sim.release_clients([e.client for e in consumed])
        return len(entries), stale

    # ------------------------------------------------------------ sync mode
    def _run_sync(self, rounds, eval_every, verbose):
        """The legacy lockstep protocol, verbatim — same rng draws, same
        ``Strategy.round`` dispatch (fused cohort step, donation), same eval
        cadence — plus the virtual clock: each round costs the slowest
        sampled device's compute + uplink time."""
        sim, strat = self.sim, self.strategy
        eval_b = sim.eval_batch()
        for r in range(self._round, rounds):
            clients = self._sample(sim.fed.clients_per_round, r) \
                if (self._silo is not None or self._silo_trace()) \
                else sim.sample_clients(strat.memory_method,
                                        **strat.memory_kwargs(r))
            if clients and self._silo is not None:
                # hierarchical lockstep: the wave rides the scheduler's
                # dispatch/commit path so the silo tier sees every commit
                wave = self._dispatch(clients, r)
                self.clock = max((p.finish for p in wave),
                                 default=self.clock)
                self._commit([p for p in wave if not p.failed])
                sim.release_clients(
                    [p.client for p in wave if p.failed])
            elif clients:
                # cost reads the plan *before* the commit — stage-advance
                # strategies (chainfed) move to the next plan on commit
                dt = max(self._round_time(c, strat.plan(c, r))
                         for c in clients)
                strat.round(sim, clients, r)
                self.clock += dt
                self.version += 1
                self.committed_updates += len(clients)
                self.events += len(clients) + 1
                sim.release_clients(clients)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                self._history.append(self._metric(r, eval_b, len(clients),
                                                  0, verbose))
            self._round = r + 1
            if self._checkpoint_unit(r + 1):
                break
        return self._history

    # -------------------------------------------------------- semisync mode
    def _run_semisync(self, rounds, eval_every, verbose):
        """Deadline-cutoff rounds: a full cohort is dispatched, but the
        server commits when the ``deadline_quantile``-fastest device is done.
        Stragglers are dropped — the server *aborts* them at the deadline,
        so their work is wasted but the device is freed for the next round —
        or carried: a carried update was computed at dispatch and is still
        cooking, so the device stays busy (excluded from resampling) and its
        update commits in a later round at exactly the staleness its
        lateness earned it.

        The deadline is **online-adaptive**: the server keeps a rolling
        window of observed client latencies (on-time rounds contribute
        their actual latency; aborted stragglers contribute the deadline —
        a censored observation, all the server ever measures for them) and
        sets each round's cutoff at the running ``deadline_quantile`` of
        that window.  The first rounds bootstrap from the current wave's
        oracle latencies (the cold-start estimate PR 5 used every round);
        ``deadline_quantile >= 1.0`` means wait-for-everyone and bypasses
        estimation entirely.  A progress guard keeps the deadline at or
        above the wave's fastest finisher so every round commits someone.

        Fault-injected dropouts never commit: a failed entry's event is the
        server's timeout, the entry is excluded from the wave (and from
        the carry set), and — when secure aggregation is on — its pairwise
        masks are reconstructed from the surviving roster (the dropout-
        recovery path).

        Under an availability trace an empty sample (every eligible device
        offline) does not waste a round: the server backs off — clock
        advances by min(base·2^k, cap) — and retries until a window opens
        or ``max_backoff_retries`` attempts are spent."""
        sim = self.sim
        eval_b = sim.eval_batch()
        for r in range(self._round, rounds):
            # a carried straggler is still computing — never resample it
            # into the new cohort mid-flight
            busy = frozenset(p.client.cid for p in self._carried)
            clients = self._sample(sim.fed.clients_per_round, r, busy=busy)
            if not clients and self._churny():
                delay = self.backoff_base
                for _ in range(self.max_backoff_retries):
                    self.clock += delay
                    self.backoff_retries += 1
                    self.events += 1
                    delay = min(delay * 2.0, self.backoff_cap)
                    clients = self._sample(sim.fed.clients_per_round, r,
                                           busy=busy)
                    if clients:
                        break
            wave = self._dispatch(clients, r) if clients else []
            if not wave:
                deadline = self.clock
            elif self.deadline_quantile >= 1.0:
                deadline = max(p.finish for p in wave)
            elif len(self._lat_window) >= 8:
                est = float(np.quantile(np.asarray(self._lat_window),
                                        self.deadline_quantile))
                # progress guard: however wrong the estimate, at least the
                # wave's fastest device commits this round
                deadline = max(self.clock + est,
                               min(p.finish for p in wave))
            else:
                # cold start: bootstrap from this wave's oracle latencies
                lat = sorted(p.finish - self.clock for p in wave)
                q = min(len(lat) - 1,
                        max(0, int(np.ceil(self.deadline_quantile * len(lat)))
                            - 1))
                deadline = self.clock + lat[q]
            failed = [p for p in wave if p.failed]
            live = [p for p in wave if not p.failed]
            on_time = [p for p in live if p.finish <= deadline]
            stragglers = [p for p in live if p.finish > deadline]
            arrivals = [p for p in self._carried if p.finish <= deadline]
            self._carried = [p for p in self._carried
                             if p.finish > deadline]
            if self.straggler == "carry":
                self._carried += stragglers
            else:
                # aborted stragglers never reach a commit — hand their
                # clients straight back to the lazy pool
                sim.release_clients([p.client for p in stragglers])
            sim.release_clients([p.client for p in failed])
            for p in on_time:
                self._lat_window.append(p.finish - p.start)
            for p in stragglers + failed:
                # censored: the server only knows they hadn't finished
                self._lat_window.append(max(deadline - p.start, 0.0))
            self.clock = deadline
            kept, stale = self._commit(on_time + arrivals)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                self._history.append(self._metric(r, eval_b, kept, stale,
                                                  verbose))
            self._round = r + 1
            if self._checkpoint_unit(r + 1):
                break
        return self._history

    # ----------------------------------------------------------- async mode
    def _push_retry(self, retry: int):
        """Park a backoff retry event on the heap: when it fires the
        scheduler re-attempts a dispatch; another failure parks the next
        retry at twice the delay (capped), giving up after
        ``max_backoff_retries`` consecutive misses."""
        if retry >= self.max_backoff_retries:
            return
        delay = min(self.backoff_base * (2.0 ** retry), self.backoff_cap)
        self._seq += 1
        self.backoff_retries += 1
        self.events += 1
        heapq.heappush(self._heap, _Pending(
            finish=self.clock + delay, client=None, plan=None, bucket=None,
            bi=-1, masks={}, weight=0.0, version=self.version,
            seq=self._seq, retry=retry))

    def _async_refill(self, retry: int):
        """Top the in-flight pool back up to ``concurrency`` live workers;
        a shortfall under an availability trace parks a backoff retry
        (attempt number ``retry``) instead of silently shrinking the pool."""
        busy = frozenset(q.client.cid for q in self._heap
                         if q.client is not None)
        live = sum(1 for q in self._heap if q.retry < 0)
        want = self.concurrency - live
        got = (self._dispatch(self._sample(want, self._done, busy),
                              self._done) if want > 0 else [])
        for q in got:
            heapq.heappush(self._heap, q)
            if retry > 0:
                self.redispatches += 1
        if want > 0 and len(got) < want and self._churny():
            self._push_retry(retry)

    def _seed_async(self):
        # the initial dispatch is just a refill from an empty pool — a
        # partial fill under trace churn parks a retry for the rest
        if self._async_seeded:
            return
        self._async_seeded = True
        self._async_refill(0)

    def _run_async(self, commits, eval_every, verbose):
        """FedBuff-style buffered async: ``concurrency`` clients in flight,
        completion events popped off the heap, a commit (and replacement
        dispatch wave) every ``buffer_size`` arrivals.

        A fault-injected dropout (or an availability window closing
        mid-round) surfaces as a *timeout event* on the same heap: when it
        fires, the update is discarded (it never arrived) and the server
        immediately dispatches a replacement client — the re-dispatch rides
        the identical bucketed path (padded to ``bucket_pad``), so recovery
        costs no recompilation.  When no replacement is available (trace
        churn) a capped-exponential-backoff retry event takes its place."""
        eval_b = self.sim.eval_batch()
        self._seed_async()
        while self._done < commits and (self._heap or self._buffered):
            if self._heap:
                p = heapq.heappop(self._heap)
                self.clock = p.finish
                if p.retry >= 0:
                    # backoff wake-up: try the dispatch again; failure
                    # parks the next retry at twice the delay
                    self._async_refill(p.retry + 1)
                    continue
                if p.failed:
                    # timeout event: the client died mid-round — re-dispatch
                    # a replacement on the same heap and keep draining
                    self.sim.release_clients([p.client])
                    busy = frozenset(q.client.cid for q in self._heap
                                     if q.client is not None)
                    got = self._dispatch(self._sample(1, self._done, busy),
                                         self._done)
                    for q in got:
                        heapq.heappush(self._heap, q)
                        self.redispatches += 1
                    if not got and self._churny():
                        self._push_retry(0)
                    continue
                self._buffered.append(p)
            if len(self._buffered) >= self.buffer_size or not self._heap:
                if not self._buffered:
                    break
                kept, stale = self._commit(self._buffered)
                self._buffered = []
                if kept:        # a staleness_cap can void a whole buffer —
                    self._done += 1   # model didn't move: not a commit
                    if (self._done % eval_every == 0
                            or self._done == commits):
                        self._history.append(self._metric(
                            self._done - 1, eval_b, kept, stale, verbose))
                if self._done < commits:
                    self._async_refill(0)
                if kept and self._checkpoint_unit(self._done):
                    break
        return self._history

    # ------------------------------------------------- durable run state
    def state_dict(self) -> dict:
        """Everything a fresh, identically-configured scheduler needs to
        continue this run bit-identically — see ``repro.fed.checkpoint``."""
        from .checkpoint import scheduler_state
        return scheduler_state(self)

    def load_state_dict(self, state: dict) -> None:
        from .checkpoint import load_scheduler_state
        load_scheduler_state(self, state)

    def save(self, path) -> None:
        """Atomically persist the full run state (write-tmp-then-rename)."""
        from .checkpoint import save_run
        save_run(self, path)

    def restore(self, path) -> None:
        """Load a checkpoint into this (freshly constructed, identically
        configured) scheduler; the next ``run`` continues where the
        checkpoint was taken."""
        from .checkpoint import restore_run
        restore_run(self, path)
