"""Declarative experiment configuration (ISSUE 8 api_redesign).

One frozen, serializable ``ExperimentSpec`` replaces the 50+ loose kwargs /
CLI flags that ``run_experiment`` and ``launch.train`` had accreted.  The
spec is the *single source of truth* for a run's configuration:

* ``run_experiment(spec=ExperimentSpec(...))`` consumes it directly and
  reproduces the exact results of the equivalent flag invocation;
* ``launch.train`` builds it from flags (``--config spec.json`` round-trips
  it through :meth:`ExperimentSpec.to_json` / :meth:`from_json`);
* the scheduler embeds it in checkpoints, so ``--resume`` validates the
  *whole* configuration field-by-field (:meth:`ExperimentSpec.diff`), not
  just the mode/strategy/fleet/seed handful;
* live objects that cannot serialize (a prebuilt ``FedSim``, pretrained
  ``params``, a bespoke ``ModelConfig``) stay *outside* the spec as
  explicit overrides on ``run_experiment``.

Design rule: every field is a JSON scalar (or a tuple of ``(key, value)``
pairs standing in for a dict), defaults mirror the runtime objects they
configure, and ``None`` means "derive it" (e.g. ``PrivacySpec.seed=None``
inherits ``RunSpec.seed``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

Pairs = Tuple[Tuple[str, object], ...]


def freeze_opts(opts) -> Pairs:
    """Normalize a kwargs dict (or pair tuple) into sorted hashable pairs —
    the frozen-dataclass-safe stand-in for a dict field."""
    if opts is None:
        return ()
    if isinstance(opts, dict):
        items = opts.items()
    else:
        items = ((k, v) for k, v in opts)
    return tuple(sorted((str(k), _freeze_value(v)) for k, v in items))


def _freeze_value(v):
    if isinstance(v, dict):
        return freeze_opts(v)
    if isinstance(v, (list, tuple)):
        return tuple(_freeze_value(x) for x in v)
    return v


def thaw_opts(pairs: Pairs) -> dict:
    return {k: v for k, v in pairs}


# ================================================================ sections
@dataclasses.dataclass(frozen=True)
class RunSpec:
    """What trains, on what data, for how long — model/chain/population."""
    strategy: str = "chainfed"
    arch: str = "bert_tiny"
    smoke: bool = False                 # reduced smoke variant of the arch
    task: str = "classification"
    dataset: str = "agnews"
    batch_size: int = 8
    rounds: int = 20                    # async mode: server commits
    eval_every: int = 5
    seed: int = 0
    memory_constrained: bool = True
    pretrain_steps: int = 0
    strategy_opts: Pairs = ()           # constructor kwargs for the strategy
    # ---- chain schedule (ChainConfig) ----
    window: int = 3
    lam: float = 0.2
    foat_threshold: float = 0.8
    local_steps: int = 1
    lr: float = 1e-3
    optimizer: str = "adamw"
    opt_bits: int = 32                  # optimizer-state precision (32 | 8)
    fused_optim: Optional[bool] = None  # fused update: None backend-aware,
                                        # True force kernel, False legacy
    # ---- cohort update compression (fed.compress) ----
    compress: Optional[str] = None      # None | "topk" | "qsgd"
    compress_opts: Pairs = ()           # CompressionConfig kwargs
    # ---- population (FedConfig) ----
    n_clients: int = 16
    clients_per_round: int = 4
    dirichlet_alpha: float = 1.0
    iid: bool = False
    # ---- lazy ClientPool population (ISSUE 8) ----
    lazy: bool = False                  # O(active cohort) resident state
    shard_size: Optional[int] = None    # examples per lazy client shard


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """Event-driven runtime knobs (``FedScheduler``)."""
    mode: str = "sync"                  # sync | semisync | async
    concurrency: Optional[int] = None   # async clients in flight
    buffer_size: Optional[int] = None   # async completions per commit
    deadline_quantile: float = 0.75     # semisync cutoff
    straggler: str = "drop"             # semisync: drop | carry
    bucket_pad: Optional[int] = None    # dispatch-bucket pad target
    pad_policy: str = "fixed"           # fixed | pow2 (per-completion async)
    staleness_cap: Optional[int] = None
    backoff_base: float = 1.0
    backoff_cap: float = 60.0
    max_backoff_retries: int = 60


@dataclasses.dataclass(frozen=True)
class PrivacySpec:
    """Client-level DP + secure aggregation (``repro.fed.privacy``)."""
    clip: Optional[float] = None        # None → DP off
    noise_multiplier: float = 1.0
    delta: float = 1e-5
    adaptive_clip: bool = False
    target_quantile: float = 0.5
    clip_lr: float = 0.2
    seed: Optional[int] = None          # None → RunSpec.seed
    secure_agg: bool = False
    fixedpoint_bits: int = 16


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Fault injection, availability churn, robust server aggregation."""
    dropout_prob: float = 0.0
    byzantine_frac: float = 0.0
    byzantine_scale: float = -10.0
    attack: str = "scaling"             # scaling | replacement
    replace_boost: float = 4.0
    straggler_prob: float = 0.0
    straggler_factor: float = 4.0
    timeout_factor: float = 1.0
    seed: Optional[int] = None          # None → RunSpec.seed
    trace: Optional[str] = None         # diurnal | flaky | None
    trace_period: float = 1000.0
    trace_uptime: float = 0.45          # diurnal duty cycle
    aggregator: Optional[str] = None    # robust server aggregation override
    aggregator_opts: Pairs = ()

    @property
    def any_faults(self) -> bool:
        return bool(self.dropout_prob or self.byzantine_frac
                    or self.straggler_prob)


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Edge → cross-silo → server hierarchy (``repro.fed.runtime.Topology``).
    ``n_silos=1`` is the flat cohort."""
    n_silos: int = 1
    assign: str = "block"               # block | mod
    aggregator: str = "fedavg"          # silo-tier aggregation
    aggregator_opts: Pairs = ()
    trace: Optional[str] = None         # per-silo availability trace kind
    trace_period: float = 1000.0
    trace_uptime: float = 0.45
    trace_seed: Optional[int] = None    # None → RunSpec.seed


_SECTIONS = (("run", RunSpec), ("schedule", ScheduleSpec),
             ("privacy", PrivacySpec), ("faults", FaultSpec),
             ("topology", TopologySpec))


# ============================================================ the composite
@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    run: RunSpec = dataclasses.field(default_factory=RunSpec)
    schedule: ScheduleSpec = dataclasses.field(default_factory=ScheduleSpec)
    privacy: PrivacySpec = dataclasses.field(default_factory=PrivacySpec)
    faults: FaultSpec = dataclasses.field(default_factory=FaultSpec)
    topology: TopologySpec = dataclasses.field(default_factory=TopologySpec)

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        out = {}
        for name, _ in _SECTIONS:
            sec = dataclasses.asdict(getattr(self, name))
            out[name] = {k: (list(v) if isinstance(v, tuple) else v)
                         for k, v in sec.items()}
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        kw = {}
        for name, sec_cls in _SECTIONS:
            raw = dict(d.get(name, {}))
            fields = {f.name for f in dataclasses.fields(sec_cls)}
            unknown = set(raw) - fields
            if unknown:
                raise ValueError(
                    f"unknown {name} spec field(s): {sorted(unknown)}")
            for k in ("strategy_opts", "aggregator_opts", "compress_opts"):
                if k in raw and raw[k] is not None:
                    raw[k] = freeze_opts(
                        raw[k] if isinstance(raw[k], dict)
                        else [tuple(p) for p in raw[k]])
            kw[name] = sec_cls(**raw)
        unknown = set(d) - {n for n, _ in _SECTIONS}
        if unknown:
            raise ValueError(f"unknown spec section(s): {sorted(unknown)}")
        return cls(**kw)

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    # ---------------------------------------------------------- validation
    def diff(self, other: "ExperimentSpec") -> dict:
        """Field-level differences, ``{"section.field": (self, other)}`` —
        the resume validator refuses a checkpoint on *any* entry."""
        out = {}
        for name, sec_cls in _SECTIONS:
            a, b = getattr(self, name), getattr(other, name)
            for f in dataclasses.fields(sec_cls):
                va, vb = getattr(a, f.name), getattr(b, f.name)
                if _norm(va) != _norm(vb):
                    out[f"{name}.{f.name}"] = (va, vb)
        return out


def _norm(v):
    """JSON round-trip normalization: tuples and lists compare equal."""
    if isinstance(v, (list, tuple)):
        return tuple(_norm(x) for x in v)
    return v


# ==================================================== spec → runtime objects
def build_configs(spec: ExperimentSpec):
    """``(cfg, chain, fed)`` exactly as ``launch.train`` builds them from
    the equivalent flags."""
    from ..configs import get_config, get_smoke_config
    from ..models.config import ChainConfig, FedConfig
    r = spec.run
    cfg = get_smoke_config(r.arch) if r.smoke else get_config(r.arch)
    chain = ChainConfig(window=r.window, lam=r.lam,
                        foat_threshold=r.foat_threshold,
                        local_steps=r.local_steps, lr=r.lr,
                        optimizer=r.optimizer, opt_bits=r.opt_bits,
                        fused_optim=r.fused_optim)
    fed = FedConfig(n_clients=r.n_clients,
                    clients_per_round=r.clients_per_round,
                    rounds=r.rounds, iid=r.iid,
                    dirichlet_alpha=r.dirichlet_alpha, seed=r.seed)
    return cfg, chain, fed


def build_dp(spec: ExperimentSpec) -> Optional[dict]:
    p = spec.privacy
    if p.clip is None:
        return None
    return {"clip": p.clip, "noise_multiplier": p.noise_multiplier,
            "delta": p.delta,
            "seed": p.seed if p.seed is not None else spec.run.seed,
            "adaptive_clip": p.adaptive_clip,
            "target_quantile": p.target_quantile, "clip_lr": p.clip_lr}


def build_compression(spec: ExperimentSpec) -> Optional[dict]:
    """kwargs for ``fed.compress.CompressionConfig`` — or None when update
    compression is off."""
    r = spec.run
    if r.compress is None:
        return None
    return {"kind": r.compress, **thaw_opts(r.compress_opts)}


def build_faults(spec: ExperimentSpec) -> Optional[dict]:
    f = spec.faults
    if not f.any_faults:
        return None
    return {"dropout_prob": f.dropout_prob,
            "byzantine_frac": f.byzantine_frac,
            "byzantine_scale": f.byzantine_scale,
            "attack": f.attack, "replace_boost": f.replace_boost,
            "straggler_prob": f.straggler_prob,
            "straggler_factor": f.straggler_factor,
            "timeout_factor": f.timeout_factor,
            "seed": f.seed if f.seed is not None else spec.run.seed}


def build_trace(spec: ExperimentSpec) -> Optional[dict]:
    f = spec.faults
    if f.trace is None:
        return None
    t = {"kind": f.trace, "period": f.trace_period,
         "seed": f.seed if f.seed is not None else spec.run.seed}
    if f.trace == "diurnal":
        t["uptime"] = f.trace_uptime
    return t


def build_topology(spec: ExperimentSpec):
    """A ``repro.fed.runtime.Topology`` — or None for the flat cohort."""
    t = spec.topology
    if t.n_silos <= 1 and t.trace is None:
        return None
    from ..data.partition import make_trace
    from .runtime import Topology
    silo_trace = None
    if t.trace is not None:
        kw = {"period": t.trace_period,
              "seed": (t.trace_seed if t.trace_seed is not None
                       else spec.run.seed)}
        if t.trace == "diurnal":
            kw["uptime"] = t.trace_uptime
        silo_trace = make_trace(t.trace, t.n_silos, **kw)
    return Topology(n_silos=t.n_silos, assign=t.assign,
                    aggregator=t.aggregator,
                    aggregator_opts=freeze_opts(t.aggregator_opts),
                    trace=silo_trace)


def build_scheduler_opts(spec: ExperimentSpec) -> dict:
    """Constructor kwargs for ``FedScheduler`` (``faults``/``trace``/
    ``topology`` objects are attached by ``run_experiment``)."""
    s = spec.schedule
    so = {"deadline_quantile": s.deadline_quantile,
          "straggler": s.straggler, "pad_policy": s.pad_policy,
          "backoff_base": s.backoff_base, "backoff_cap": s.backoff_cap,
          "max_backoff_retries": s.max_backoff_retries}
    for k in ("concurrency", "buffer_size", "bucket_pad", "staleness_cap"):
        v = getattr(s, k)
        if v is not None:
            so[k] = v
    return so


# ======================================================== kwargs → spec shim
def spec_from_kwargs(strategy, *, arch="bert_tiny", task="classification",
                     dataset="agnews", batch_size=8, rounds=20, eval_every=5,
                     seed=0, memory_constrained=True, pretrain_steps=0,
                     strategy_opts=None, mode="sync", scheduler_opts=None,
                     dp=None, secure_agg=None, compress=None,
                     aggregator=None,
                     aggregator_opts=None, faults=None, trace=None,
                     chain=None, fed=None,
                     lazy=False, shard_size=None) -> Optional[ExperimentSpec]:
    """Best-effort spec for a legacy kwargs invocation — used to embed a
    validated configuration in checkpoints.  Returns None when the kwargs
    carry live objects a spec cannot faithfully represent (prebuilt traces
    or fault models, a ``Topology`` instance, custom callables); callers
    treat None as "no spec to embed", never an error."""
    try:
        run_kw = dict(strategy=str(strategy), arch=arch, task=task,
                      dataset=dataset, batch_size=int(batch_size),
                      rounds=int(rounds), eval_every=int(eval_every),
                      seed=int(seed),
                      memory_constrained=bool(memory_constrained),
                      pretrain_steps=int(pretrain_steps),
                      strategy_opts=freeze_opts(strategy_opts),
                      lazy=bool(lazy), shard_size=shard_size)
        if chain is not None:
            run_kw.update(window=chain.window, lam=chain.lam,
                          foat_threshold=chain.foat_threshold,
                          local_steps=chain.local_steps, lr=chain.lr,
                          optimizer=chain.optimizer,
                          opt_bits=getattr(chain, "opt_bits", 32),
                          fused_optim=getattr(chain, "fused_optim", None))
        if fed is not None:
            run_kw.update(n_clients=fed.n_clients,
                          clients_per_round=fed.clients_per_round,
                          dirichlet_alpha=fed.dirichlet_alpha, iid=fed.iid)
        if compress is not None:
            d = dataclasses.asdict(compress) \
                if dataclasses.is_dataclass(compress) else dict(compress)
            run_kw["compress"] = d.pop("kind")
            run_kw["compress_opts"] = freeze_opts(d)
        so = dict(scheduler_opts or {})
        topology = so.pop("topology", None)
        topo_kw = {}
        if topology is not None:
            if topology.trace is not None:
                return None      # a prebuilt trace object — not declarative
            topo_kw = dict(n_silos=topology.n_silos, assign=topology.assign,
                           aggregator=topology.aggregator,
                           aggregator_opts=freeze_opts(
                               topology.aggregator_opts))
        sched_fields = {f.name for f in dataclasses.fields(ScheduleSpec)}
        if not set(so) <= sched_fields:
            return None
        priv_kw = {}
        if dp is not None:
            d = dataclasses.asdict(dp) if dataclasses.is_dataclass(dp) \
                else dict(dp)
            priv_kw = {k: d[k] for k in
                       ("clip", "noise_multiplier", "delta", "seed",
                        "adaptive_clip", "target_quantile", "clip_lr")
                       if k in d}
        if secure_agg:
            priv_kw["secure_agg"] = True
            if dataclasses.is_dataclass(secure_agg):
                priv_kw["fixedpoint_bits"] = secure_agg.fixedpoint_bits
        fault_kw = {}
        if faults is not None:
            d = dataclasses.asdict(faults) \
                if dataclasses.is_dataclass(faults) else dict(faults)
            fault_kw.update(d)
        if trace is not None:
            if not isinstance(trace, dict):
                return None      # prebuilt AvailabilityTrace object
            t = dict(trace)
            fault_kw["trace"] = t.pop("kind")
            if "period" in t:
                fault_kw["trace_period"] = t.pop("period")
            if "uptime" in t:
                fault_kw["trace_uptime"] = t.pop("uptime")
            t.pop("seed", None)
            if t:                # trace kwargs the spec has no field for
                return None
        if aggregator is not None:
            fault_kw["aggregator"] = aggregator
            fault_kw["aggregator_opts"] = freeze_opts(aggregator_opts)
        return ExperimentSpec(
            run=RunSpec(**run_kw),
            schedule=ScheduleSpec(mode=mode, **so),
            privacy=PrivacySpec(**priv_kw),
            faults=FaultSpec(**fault_kw),
            topology=TopologySpec(**topo_kw))
    except (TypeError, ValueError, AttributeError, KeyError):
        return None
