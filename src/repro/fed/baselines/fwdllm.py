"""FwdLLM [arXiv:2308.13894]: backpropagation-free federated fine-tuning via
forward/zeroth-order gradients on the trainables — eliminates activation
storage at the cost of noisy gradient estimates (the paper's Table 1 shows
its accuracy penalty, incl. non-convergence on 20NEWS)."""
from __future__ import annotations

import jax

from ...models.transformer import forward_full
from ...optim.zeroth import spsa_grad
from ...train.losses import cross_entropy
from ...utils.tree import tree_map
from ..registry import register_strategy
from ..strategies import Strategy


@register_strategy("fwdllm")
class FwdLLM(Strategy):
    name = "fwdllm"
    memory_method = "fwdllm"
    N_PERTURB = 4

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain, key)
        cfg_ = cfg

        @jax.jit
        def zo_step(tr, opt_state, params, batch, key):
            def loss_of(t):
                p = {**params, "cls_head": t["head"]} if "head" in t else params
                logits, _ = forward_full(p, t["adapters"], batch, cfg_,
                                         remat=False)
                return cross_entropy(logits, batch["labels"])

            g, _ = spsa_grad(loss_of, tr, key, eps=1e-3,
                             n_samples=self.N_PERTURB)
            tr, opt_state = self.opt.step(tr, g, opt_state)
            return tr, opt_state

        self._zo_step = zo_step
        self._key = jax.random.fold_in(key, 1717)

    def round(self, sim, clients, round_idx):
        deltas, weights = [], []
        master = self.master_trainable()
        for c in clients:
            tr = master
            st = self.opt.init(tr)
            for batch in sim.client_batches(c, self.chain.local_steps):
                self._key, sub = jax.random.split(self._key)
                tr, st = self._zo_step(tr, st, self._params, batch, sub)
            deltas.append(tree_map(lambda a, b: a - b, tr, master))
            weights.append(c.n_samples)
        self._fedavg(deltas, weights)
