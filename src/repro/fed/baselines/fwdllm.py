"""FwdLLM [arXiv:2308.13894]: backpropagation-free federated fine-tuning via
forward/zeroth-order gradients on the trainables — eliminates activation
storage at the cost of noisy gradient estimates (the paper's Table 1 shows
its accuracy penalty, incl. non-convergence on 20NEWS).

The whole method is a plan: full adapter span + CE loss + the ``"spsa"``
gradient program, so the batched cohort path (vmap over clients, fused
FedAvg, donation) comes for free from ``PlanEngine.cohort_step``.  Per-client
RNG is derived as ``fold_in(fold_in(fold_in(key, round), client), step)`` —
stateless, so re-running a round reproduces bit-identical updates."""
from __future__ import annotations

import jax

from ...core.adapters import ActiveAdapters
from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan


@register_strategy("fwdllm")
class FwdLLM(Strategy):
    name = "fwdllm"
    memory_method = "fwdllm"
    N_PERTURB = 4
    EPS = 1e-3

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain, key)
        self._base_key = jax.random.fold_in(key, 1717)

    def plan(self, client, round_idx) -> TrainablePlan:
        return TrainablePlan(
            adapters=ActiveAdapters.full(self.cfg.total_chain_layers),
            train_head=self.head is not None,
            grad="spsa",
            grad_cfg=(("eps", self.EPS), ("n_samples", self.N_PERTURB)))

    def plan_masks(self, sim, client, round_idx):
        k = jax.random.fold_in(self._base_key, round_idx)
        return {"grad_key": jax.random.fold_in(k, client.cid)}
