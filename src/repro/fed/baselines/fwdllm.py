"""FwdLLM [arXiv:2308.13894]: backpropagation-free federated fine-tuning via
forward/zeroth-order gradients on the trainables — eliminates activation
storage at the cost of noisy gradient estimates (the paper's Table 1 shows
its accuracy penalty, incl. non-convergence on 20NEWS).

The whole method is a plan: full adapter span + CE loss + a perturbation
gradient program — ``"spsa"`` (antithetic central differences, the memory
profile of forward gradients) or the true forward-mode ``"jvp"`` program
(``jax.jvp`` per direction, FwdLLM's actual estimator; registered as the
``fwdllm_jvp`` variant) — so the batched cohort path (vmap over clients,
fused FedAvg) comes for free from ``PlanEngine``.  Per-client RNG is derived
as ``fold_in(fold_in(fold_in(key, round), client), step)`` — stateless, so
re-running a round reproduces bit-identical updates.

**Memory-stratified perturbation budgets** (ISSUE 5): ``samples_by_tier``
maps a client's ``DeviceProfile.tier`` to its ``n_samples`` — big devices
draw more perturbation directions per step, small ones fewer.  Since
``n_samples`` lives in the plan's frozen ``grad_cfg``, each tier is its own
(hashable) plan: the cohort/event runtimes bucket clients by plan and run
one compiled step per tier, with no recompiles as cohorts mix."""
from __future__ import annotations

import jax

from ...core.adapters import ActiveAdapters
from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan


@register_strategy("fwdllm")
class FwdLLM(Strategy):
    name = "fwdllm"
    memory_method = "fwdllm"
    grad_programs = ("spsa", "jvp")
    N_PERTURB = 4
    EPS = 1e-3

    def __init__(self, cfg, chain, key, grad_program="spsa",
                 n_samples=None, samples_by_tier=None):
        super().__init__(cfg, chain, key)
        self._base_key = jax.random.fold_in(key, 1717)
        self.grad_program = grad_program
        self.n_samples = n_samples or self.N_PERTURB
        self.samples_by_tier = dict(samples_by_tier) if samples_by_tier \
            else None

    def _n_samples(self, client) -> int:
        if self.samples_by_tier and getattr(client, "profile", None):
            return int(self.samples_by_tier.get(client.profile.tier,
                                                self.n_samples))
        return int(self.n_samples)

    def plan(self, client, round_idx) -> TrainablePlan:
        cfg = (("n_samples", self._n_samples(client)),)
        if self.grad_program == "spsa":    # jvp is exact — no eps knob
            cfg = (("eps", self.EPS),) + cfg
        return TrainablePlan(
            adapters=ActiveAdapters.full(self.cfg.total_chain_layers),
            train_head=self.head is not None,
            grad=self.grad_program,
            grad_cfg=cfg)

    def plan_masks(self, sim, client, round_idx):
        k = jax.random.fold_in(self._base_key, round_idx)
        return {"grad_key": jax.random.fold_in(k, client.cid)}


register_strategy("fwdllm_jvp", grad_program="jvp")(FwdLLM)
