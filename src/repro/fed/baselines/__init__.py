from .full_adapters import FullAdapters
from .linear_probing import LinearProbing
from .fedadapter import FedAdapter
from .c2a import C2A
from .fwdllm import FwdLLM
from .fedkseed import FedKSeed
from .flora import FLoRA
from .fedra import FedRA

BASELINES = {
    "full_adapters": FullAdapters,
    "linear_probing": LinearProbing,
    "fedadapter": FedAdapter,
    "c2a": C2A,
    "fwdllm": FwdLLM,
    "fedkseed": FedKSeed,
    "flora": FLoRA,
    "fedra": FedRA,
}
