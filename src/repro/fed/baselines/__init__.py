"""The baseline strategies (paper Table 1, plus the embedding-tuning
baseline).  Importing this package registers each under its name in
``repro.fed.registry``; ``BASELINES`` is kept as a plain-dict view for
direct class access."""
from .c2a import C2A
from .fedadapter import FedAdapter
from .fedembed import FedEmbed
from .fedkseed import FedKSeed
from .fedra import FedRA
from .flora import FLoRA
from .full_adapters import FullAdapters
from .fwdllm import FwdLLM
from .layerwise import LayerDropout, LayerPruning
from .linear_probing import LinearProbing

BASELINES = {
    "full_adapters": FullAdapters,
    "linear_probing": LinearProbing,
    "fedadapter": FedAdapter,
    "c2a": C2A,
    "fwdllm": FwdLLM,
    "fedkseed": FedKSeed,
    "flora": FLoRA,
    "fedra": FedRA,
    "fedembed": FedEmbed,
    "layer_pruning": LayerPruning,
    "layer_dropout": LayerDropout,
}
