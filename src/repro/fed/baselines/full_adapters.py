"""Full Adapters† — the paper's idealized, memory-unconstrained upper bound:
end-to-end training of every adapter (Table 1 'Upper Bound').  Exactly the
base Strategy's default plan (full ActiveAdapters spec, CE loss)."""
from ..registry import register_strategy
from ..strategies import Strategy


@register_strategy("full_adapters")
class FullAdapters(Strategy):
    name = "full_adapters"
    memory_method = "full_adapters"
