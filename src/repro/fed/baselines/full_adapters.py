"""Full Adapters† — the paper's idealized, memory-unconstrained upper bound:
end-to-end training of every adapter (Table 1 'Upper Bound')."""
from ..strategies import Strategy


class FullAdapters(Strategy):
    name = "full_adapters"
    memory_method = "full_adapters"
