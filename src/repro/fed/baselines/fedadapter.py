"""FedAdapter (Cai et al., 2022): dynamic adapter configuration — the set of
active adapter layers grows progressively over rounds to accelerate early
convergence (shallow first, then deeper).  The growth schedule is a runtime
layer mask over the full plan, so every round reuses one compiled step."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.adapters import ActiveAdapters
from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan


@register_strategy("fedadapter")
class FedAdapter(Strategy):
    name = "fedadapter"
    memory_method = "fedadapter"

    def plan(self, client, round_idx) -> TrainablePlan:
        return TrainablePlan(
            adapters=ActiveAdapters.full(self.cfg.total_chain_layers),
            train_head=self.head is not None, layer_masked=True)

    def client_mask(self, client, round_idx):
        L = self.cfg.total_chain_layers
        # start with the top quarter of layers, grow one layer every 2 rounds
        active = min(L, max(1, L // 4) + round_idx // 2)
        mask = jnp.zeros((L,), jnp.float32)
        return mask.at[L - active:].set(1.0)

    def plan_masks(self, sim, client, round_idx):
        return {"layer_mask": self.client_mask(client, round_idx)}
