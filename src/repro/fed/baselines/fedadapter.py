"""FedAdapter (Cai et al., 2022): dynamic adapter configuration — the set of
active adapter layers grows progressively over rounds to accelerate early
convergence (shallow first, then deeper)."""
from __future__ import annotations

import jax.numpy as jnp

from ..strategies import Strategy


class FedAdapter(Strategy):
    name = "fedadapter"
    memory_method = "fedadapter"

    def client_mask(self, client, round_idx):
        L = self.cfg.total_chain_layers
        # start with the top quarter of layers, grow one layer every 2 rounds
        active = min(L, max(1, L // 4) + round_idx // 2)
        mask = jnp.zeros((L,), jnp.float32)
        return mask.at[L - active:].set(1.0)
