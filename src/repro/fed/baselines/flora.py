"""FLoRA [arXiv:2409.05976]: heterogeneous low-rank adaptation — each client
trains only the leading r_c columns of the shared bottleneck (r_c set by its
memory budget); aggregation zero-pads to the full rank (stacking-style).
The rank restriction is the plan's runtime rank mask; the shared engine
applies it to both the forward pass and the gradients."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.adapters import ActiveAdapters
from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan


@register_strategy("flora")
class FLoRA(Strategy):
    name = "flora"
    memory_method = "flora"

    def plan(self, client, round_idx) -> TrainablePlan:
        return TrainablePlan(
            adapters=ActiveAdapters.full(self.cfg.total_chain_layers),
            train_head=self.head is not None, rank_masked=True)

    def _client_rank_mask(self, client):
        r = self.cfg.adapter.rank
        rc = max(1, int(r * min(1.0, 0.25 + 0.75 * (client.cid % 4) / 3)))
        return (jnp.arange(r) < rc).astype(jnp.float32)

    def plan_masks(self, sim, client, round_idx):
        return {"rank_mask": self._client_rank_mask(client)}
