"""FLoRA [arXiv:2409.05976]: heterogeneous low-rank adaptation — each client
trains only the leading r_c columns of the shared bottleneck (r_c set by its
memory budget); aggregation zero-pads to the full rank (stacking-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models.transformer import forward_full
from ...train.losses import cross_entropy
from ...utils.tree import tree_map
from ..strategies import Strategy


class FLoRA(Strategy):
    name = "flora"
    memory_method = "flora"

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain, key)
        cfg_ = cfg

        def loss_fn(tr, params, batch, rmask):
            ad = {"down": tr["adapters"]["down"] * rmask[None, None, :],
                  "up": tr["adapters"]["up"] * rmask[None, :, None]}
            p = {**params, "cls_head": tr["head"]} if "head" in tr else params
            logits, _ = forward_full(p, ad, batch, cfg_, remat=False)
            return cross_entropy(logits, batch["labels"])

        @jax.jit
        def step(tr, opt_state, params, batch, rmask):
            loss, g = jax.value_and_grad(loss_fn)(tr, params, batch, rmask)
            g["adapters"] = {"down": g["adapters"]["down"] * rmask[None, None, :],
                             "up": g["adapters"]["up"] * rmask[None, :, None]}
            tr, opt_state = self.opt.step(tr, g, opt_state)
            return tr, opt_state, loss

        self._rank_step = step

    def _client_rank_mask(self, client):
        r = self.cfg.adapter.rank
        rc = max(1, int(r * min(1.0, 0.25 + 0.75 * (client.cid % 4) / 3)))
        return (jnp.arange(r) < rc).astype(jnp.float32)

    def round(self, sim, clients, round_idx):
        deltas, weights = [], []
        master = self.master_trainable()
        for c in clients:
            rmask = self._client_rank_mask(c)
            tr = master
            st = self.opt.init(tr)
            for batch in sim.client_batches(c, self.chain.local_steps):
                tr, st, _ = self._rank_step(tr, st, self._params, batch, rmask)
            deltas.append(tree_map(lambda a, b: a - b, tr, master))
            weights.append(c.n_samples)
        self._fedavg(deltas, weights)
