"""C2A (Kim et al., 2023): client-customized adapters via a hypernetwork.
The hypernetwork consumes the client's label histogram and emits per-layer
FiLM (scale, shift) modulations of the shared adapter bottleneck — a compact
instantiation of 'hypernetwork generates personalized adapters'.

The modulation is a plan-level ``transform_trainable`` hook (``"film"``):
the hypernetwork is an extra trainable leaf, the histogram a runtime mask,
and the FiLM application happens inside the shared loss — so C2A trains on
the same batched cohort path (and autodiff grad program) as every other
strategy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...core.adapters import ActiveAdapters
from ...models.module import normal_init
from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan, register_transform


@register_transform("film")
def _film_transform(cfg, chain, plan):
    """FiLM-modulate the adapter bottleneck with hypernetwork output:
    ``trainable["hyper"]["w"]: (buckets, 2L)`` maps ``masks["hist"]:
    (buckets,)`` to per-layer (scale, shift); gradients flow into both the
    shared adapters and the hypernetwork."""
    L = cfg.total_chain_layers

    def tf(trainable, masks):
        film = masks["hist"] @ trainable["hyper"]["w"]
        scale = 1.0 + film[:L].reshape(L, 1, 1)
        shift = film[L:].reshape(L, 1, 1) * 0.01
        ad = trainable["adapters"]
        return {**trainable,
                "adapters": {"down": ad["down"] * scale + shift,
                             "up": ad["up"]}}

    return tf


@register_strategy("c2a")
class C2A(Strategy):
    name = "c2a"
    memory_method = "c2a"
    N_BUCKETS = 32

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain, key)
        L = cfg.total_chain_layers
        kh = jax.random.fold_in(key, 7)
        self.hyper = {"w": normal_init(kh, (self.N_BUCKETS, 2 * L),
                                       cfg.pdtype(), stddev=0.01)}

    def plan(self, client, round_idx) -> TrainablePlan:
        return TrainablePlan(
            adapters=ActiveAdapters.full(self.cfg.total_chain_layers),
            train_head=self.head is not None, transform="film")

    def init_trainable(self, plan):
        t = super().init_trainable(plan)
        t["hyper"] = self.hyper
        return t

    def commit_trainable(self, plan, new):
        self.hyper = new["hyper"]
        super().commit_trainable(plan, new)

    def extra_state(self):
        return {"hyper": self.hyper}

    def load_extra_state(self, state):
        self.hyper = state["hyper"]

    def _client_hist(self, sim, client):
        lab = (sim.labels[client.sampler.shard] if len(client.sampler.shard)
               else sim.labels[:1])
        h = np.bincount(np.asarray(lab) % self.N_BUCKETS,
                        minlength=self.N_BUCKETS)
        h = h / max(1, h.sum())
        return jnp.asarray(h, jnp.float32)

    def plan_masks(self, sim, client, round_idx):
        return {"hist": self._client_hist(sim, client)}
