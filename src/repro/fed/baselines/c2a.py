"""C2A (Kim et al., 2023): client-customized adapters via a hypernetwork.
The hypernetwork consumes the client's label histogram and emits per-layer
FiLM (scale, shift) modulations of the shared adapter bottleneck — a compact
instantiation of 'hypernetwork generates personalized adapters'."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...models.module import normal_init
from ...models.transformer import forward_full
from ...train.losses import cross_entropy
from ...utils.tree import tree_map
from ..registry import register_strategy
from ..strategies import Strategy


@register_strategy("c2a")
class C2A(Strategy):
    name = "c2a"
    memory_method = "c2a"
    N_BUCKETS = 32

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain, key)
        L = cfg.total_chain_layers
        kh = jax.random.fold_in(key, 7)
        self.hyper = {"w": normal_init(kh, (self.N_BUCKETS, 2 * L), cfg.pdtype(),
                                       stddev=0.01)}
        cfg_ = cfg

        def modulate(adapters, hyper, hist):
            film = hist @ hyper["w"]
            scale = 1.0 + film[:L].reshape(L, 1, 1)
            shift = film[L:].reshape(L, 1, 1) * 0.01
            return {"down": adapters["down"] * scale + shift,
                    "up": adapters["up"]}

        def loss_fn(tr, params, batch, hist):
            ad = modulate(tr["adapters"], tr["hyper"], hist)
            p = {**params, "cls_head": tr["head"]} if "head" in tr else params
            logits, _ = forward_full(p, ad, batch, cfg_, remat=False)
            return cross_entropy(logits, batch["labels"])

        @jax.jit
        def step(tr, opt_state, params, batch, hist):
            loss, g = jax.value_and_grad(loss_fn)(tr, params, batch, hist)
            tr, opt_state = self.opt.step(tr, g, opt_state)
            return tr, opt_state, loss

        self._c2a_step = step

    def master_trainable(self):
        t = super().master_trainable()
        t["hyper"] = self.hyper
        return t

    def _commit(self, tr):
        super()._commit(tr)
        self.hyper = tr["hyper"]

    def _client_hist(self, sim, client):
        lab = sim.labels[client.sampler.shard] if len(client.sampler.shard) else sim.labels[:1]
        h = np.bincount(np.asarray(lab) % self.N_BUCKETS, minlength=self.N_BUCKETS)
        h = h / max(1, h.sum())
        return jnp.asarray(h, jnp.float32)

    def round(self, sim, clients, round_idx):
        deltas, weights = [], []
        master = self.master_trainable()
        for c in clients:
            hist = self._client_hist(sim, c)
            tr = master
            st = self.opt.init(tr)
            for batch in sim.client_batches(c, self.chain.local_steps):
                tr, st, _ = self._c2a_step(tr, st, self._params, batch, hist)
            deltas.append(tree_map(lambda a, b: a - b, tr, master))
            weights.append(c.n_samples)
        self._fedavg(deltas, weights)
