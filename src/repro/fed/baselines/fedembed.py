"""Embedding tuning: the token-embedding table (and task head) train while
the backbone and every adapter stay frozen — the input-side counterpart of
linear probing (prompt/embedding-tuning family).  Exercises the engine's
``TrainablePlan.train_embedding`` path: the embedding rides the trainable as
the ``embed`` leaf and commits back into the base params."""
from __future__ import annotations

from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan


@register_strategy("fedembed")
class FedEmbed(Strategy):
    name = "fedembed"
    memory_method = "fedembed"

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain.replace(train_head=True), key)

    def plan(self, client, round_idx) -> TrainablePlan:
        return TrainablePlan(adapters=None, train_head=True,
                             train_embedding=True)
