"""FedKSeed [arXiv:2312.06353]: zeroth-order full-parameter tuning restricted
to K shared random seeds; each client round uploads only K scalars.

The method is a plan with the ``"kseed"`` whole-client gradient program: one
``PlanEngine.cohort_step`` estimates every client's ``(K,)`` coefficient
vector (the cohort output is ``(C, K)``), ``cohort_aggregate`` fuses the
sample-weighted mean in-graph, and ``commit_trainable`` materializes the
round once server-side with ``kseed_apply`` — the full-parameter update is
never formed per client."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.adapters import ActiveAdapters
from ...optim.zeroth import kseed_apply
from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan


@register_strategy("fedkseed")
class FedKSeed(Strategy):
    name = "fedkseed"
    memory_method = "fedkseed"
    grad_programs = ("kseed",)
    K = 8
    EPS = 1e-3

    def __init__(self, cfg, chain, key, k_by_tier=None):
        super().__init__(cfg, chain, key)
        self.seeds = tuple(range(1000, 1000 + self.K))
        # memory-stratified seed budgets (ISSUE 5): a client's tier selects
        # a *prefix* of the shared seed list, so small devices pay fewer
        # forward passes; each K is its own plan and the cohort/event
        # runtimes bucket by plan — per-bucket compiled steps, per-bucket
        # coefficient aggregation/materialization, no ragged cohorts
        self.k_by_tier = dict(k_by_tier) if k_by_tier else None

    def _seeds(self, client):
        if self.k_by_tier and getattr(client, "profile", None):
            k = int(self.k_by_tier.get(client.profile.tier, self.K))
            return self.seeds[:max(1, min(k, self.K))]
        return self.seeds

    def plan(self, client, round_idx) -> TrainablePlan:
        return TrainablePlan(
            adapters=ActiveAdapters.full(self.cfg.total_chain_layers),
            train_head=self.head is not None,
            grad="kseed",
            grad_cfg=(("seeds", self._seeds(client)), ("eps", self.EPS)))

    # The kseed program perturbs {"_base": params, **trainable}; the seed
    # reconstruction is tree-structure-dependent, so materialization must
    # rebuild the exact same structure.
    def _full_tree(self):
        t = {"_base": self._params, "adapters": self.adapters}
        if self.head is not None:
            t["head"] = self.head
        return t

    def cohort_aggregate(self, plan):
        def agg(trainable0, updates, weights, masks):
            w = weights / jnp.sum(weights)
            return {"kseed": jnp.tensordot(
                w, updates["kseed"].astype(jnp.float32), axes=1)}

        return agg

    def apply_update(self, plan, trainable0, mean_update):
        """Secure-aggregation finalization: the masked sum already *is* the
        weighted-mean coefficient vector — commit it as-is (coefficients are
        not deltas on the trainable)."""
        return {"kseed": mean_update["kseed"]}

    def commit_trainable(self, plan, new):
        seeds = plan.grad_options["seeds"]    # the plan's (possibly tiered) K
        full = kseed_apply(self._full_tree(), seeds,
                           [float(c) for c in new["kseed"]], self.chain.lr)
        self._params = full["_base"]
        self.adapters = full["adapters"]
        if self.head is not None:
            self.head = full["head"]

    def aggregate(self, round_idx, plans, deltas, weights, masks):
        """Sequential-path counterpart: weighted mean of the per-client
        coefficient uploads, then the same materialization."""
        if not deltas:
            return
        self.commit_trainable(plans[0], self.engine.fedavg(deltas, weights))

    def base_comm_bytes(self):
        return self.K * 8
