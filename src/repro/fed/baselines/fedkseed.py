"""FedKSeed [arXiv:2312.06353]: zeroth-order full-parameter tuning restricted
to K shared random seeds; each client round uploads only K scalars."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models.transformer import forward_full
from ...optim.zeroth import kseed_apply, kseed_coeffs
from ...train.losses import cross_entropy
from ..registry import register_strategy
from ..strategies import Strategy


@register_strategy("fedkseed")
class FedKSeed(Strategy):
    name = "fedkseed"
    memory_method = "fedkseed"
    K = 8

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain, key)
        self.seeds = list(range(1000, 1000 + self.K))
        cfg_ = cfg

        def loss_of(trainable, batch):
            p = trainable["params"]
            if "head" in trainable:
                p = {**p, "cls_head": trainable["head"]}
            logits, _ = forward_full(p, trainable["adapters"], batch, cfg_,
                                     remat=False)
            return cross_entropy(logits, batch["labels"])

        self._loss_of = jax.jit(loss_of)

    def _full_trainable(self):
        t = {"params": self._params, "adapters": self.adapters}
        if self.head is not None:
            t["head"] = self.head
        return t

    def round(self, sim, clients, round_idx):
        trainable = self._full_trainable()
        all_coeffs, weights = [], []
        for c in clients:
            batch = sim.client_batches(c, 1)[0]
            coeffs = kseed_coeffs(lambda t: self._loss_of(t, batch), trainable,
                                  self.seeds, eps=1e-3)
            all_coeffs.append(coeffs)
            weights.append(c.n_samples)
        if not all_coeffs:
            return
        w = jnp.asarray(weights, jnp.float32); w = w / w.sum()
        agg = sum(wi * cc for wi, cc in zip(w, all_coeffs))
        trainable = kseed_apply(trainable, self.seeds,
                                [float(a) for a in agg], self.chain.lr)
        self._params = trainable["params"]
        self.adapters = trainable["adapters"]
        if "head" in trainable:
            self.head = trainable["head"]

    def comm_bytes_per_round(self):
        return self.K * 8
