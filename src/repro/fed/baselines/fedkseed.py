"""FedKSeed [arXiv:2312.06353]: zeroth-order full-parameter tuning restricted
to K shared random seeds; each client round uploads only K scalars.

The method is a plan with the ``"kseed"`` whole-client gradient program: one
``PlanEngine.cohort_step`` estimates every client's ``(K,)`` coefficient
vector (the cohort output is ``(C, K)``), ``cohort_aggregate`` fuses the
sample-weighted mean in-graph, and ``commit_trainable`` materializes the
round once server-side with ``kseed_apply`` — the full-parameter update is
never formed per client."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...core.adapters import ActiveAdapters
from ...optim.zeroth import kseed_apply
from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan


@register_strategy("fedkseed")
class FedKSeed(Strategy):
    name = "fedkseed"
    memory_method = "fedkseed"
    grad_programs = ("kseed",)
    K = 8
    EPS = 1e-3

    def __init__(self, cfg, chain, key, k_by_tier=None):
        super().__init__(cfg, chain, key)
        self.seeds = tuple(range(1000, 1000 + self.K))
        # accumulated-coefficient seed history (paper §3 of arXiv:2312.06353,
        # the "18 KB total communication" mechanism): ``_hist[k]`` sums every
        # committed round-mean coefficient for seed k.  Because a seed's
        # perturbation depends only on the *tree structure* — never the
        # values — ``kseed_apply`` is linear in the coefficients, so
        # θ_T = kseed_apply(θ_0, seeds, Σ_t c_t): a joining client downloads
        # the K-scalar history instead of the full model and replays it
        # (:meth:`replay`).  fp64 accumulator — rounds of fp32 coefficient
        # sums must not drift the replayed model.
        self._hist = np.zeros(self.K, np.float64)
        # memory-stratified seed budgets (ISSUE 5): a client's tier selects
        # a *prefix* of the shared seed list, so small devices pay fewer
        # forward passes; each K is its own plan and the cohort/event
        # runtimes bucket by plan — per-bucket compiled steps, per-bucket
        # coefficient aggregation/materialization, no ragged cohorts
        self.k_by_tier = dict(k_by_tier) if k_by_tier else None

    def _seeds(self, client):
        if self.k_by_tier and getattr(client, "profile", None):
            k = int(self.k_by_tier.get(client.profile.tier, self.K))
            return self.seeds[:max(1, min(k, self.K))]
        return self.seeds

    def plan(self, client, round_idx) -> TrainablePlan:
        return TrainablePlan(
            adapters=ActiveAdapters.full(self.cfg.total_chain_layers),
            train_head=self.head is not None,
            grad="kseed",
            grad_cfg=(("seeds", self._seeds(client)), ("eps", self.EPS)))

    # The kseed program perturbs {"_base": params, **trainable}; the seed
    # reconstruction is tree-structure-dependent, so materialization must
    # rebuild the exact same structure.
    def _full_tree(self):
        t = {"_base": self._params, "adapters": self.adapters}
        if self.head is not None:
            t["head"] = self.head
        return t

    def cohort_aggregate(self, plan):
        def agg(trainable0, updates, weights, masks):
            w = weights / jnp.sum(weights)
            return {"kseed": jnp.tensordot(
                w, updates["kseed"].astype(jnp.float32), axes=1)}

        return agg

    def apply_update(self, plan, trainable0, mean_update):
        """Secure-aggregation finalization: the masked sum already *is* the
        weighted-mean coefficient vector — commit it as-is (coefficients are
        not deltas on the trainable)."""
        return {"kseed": mean_update["kseed"]}

    def commit_trainable(self, plan, new):
        seeds = plan.grad_options["seeds"]    # the plan's (possibly tiered) K
        coeffs = [float(c) for c in new["kseed"]]
        # tiered plans select a *prefix* of the shared seed list, so the
        # history accumulates positionally
        self._hist[:len(coeffs)] += np.asarray(coeffs, np.float64)
        full = kseed_apply(self._full_tree(), seeds, coeffs, self.chain.lr)
        self._params = full["_base"]
        self.adapters = full["adapters"]
        if self.head is not None:
            self.head = full["head"]

    def replay(self, tree0):
        """Materialize the *current* model from a round-0 full tree (the
        ``_full_tree`` structure) and the accumulated coefficient history —
        what a client joining at round T actually downloads: K scalars, not
        the model."""
        return kseed_apply(tree0, self.seeds,
                           [float(c) for c in self._hist], self.chain.lr)

    def extra_state(self):
        return {"kseed_hist": np.asarray(self._hist)}

    def load_extra_state(self, state):
        if "kseed_hist" in state:
            self._hist = np.asarray(state["kseed_hist"], np.float64).copy()

    def aggregate(self, round_idx, plans, deltas, weights, masks):
        """Sequential-path counterpart: weighted mean of the per-client
        coefficient uploads, then the same materialization."""
        if not deltas:
            return
        self.commit_trainable(plans[0], self.engine.fedavg(deltas, weights))

    def base_comm_bytes(self):
        return self.K * 8

    def downlink_bytes(self):
        """Per-round server→client payload: the round's K aggregated fp64
        coefficients (the history *delta*) — the model itself never moves."""
        return self.K * 8

    def total_comm_bytes(self):
        """Round-trip bytes per client per round, uplink + downlink — the
        paper's 18 KB figure is this at K=1152 (16·1152 = 18 KiB exactly);
        see ``core.memory.fedkseed_total_comm``."""
        return self.comm_bytes_per_round() + self.downlink_bytes()
