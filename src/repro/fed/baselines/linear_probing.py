"""Linear Probing (Kornblith et al., 2019b): only the output layer trains;
the backbone and all adapters stay frozen."""
from __future__ import annotations

import jax

from ...models.transformer import forward_full
from ...train.losses import cross_entropy
from ...utils.tree import tree_map
from ..strategies import Strategy


class LinearProbing(Strategy):
    name = "linear_probing"
    memory_method = "linear_probing"

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain.replace(train_head=True), key)
        cfg_ = cfg

        def loss_fn(trainable, params, adapters, batch):
            p = {**params, "cls_head": trainable["head"]}
            logits, _ = forward_full(p, adapters, batch, cfg_, remat=False)
            return cross_entropy(logits, batch["labels"])

        @jax.jit
        def step(trainable, opt_state, params, adapters, batch):
            loss, g = jax.value_and_grad(loss_fn)(trainable, params, adapters,
                                                  batch)
            trainable, opt_state = self.opt.step(trainable, g, opt_state)
            return trainable, opt_state, loss

        self._head_step = step

    def round(self, sim, clients, round_idx):
        deltas, weights = [], []
        master = {"head": self.head}
        for c in clients:
            tr = master
            st = self.opt.init(tr)
            for batch in sim.client_batches(c, self.chain.local_steps):
                tr, st, _ = self._head_step(tr, st, self._params, self.adapters,
                                            batch)
            deltas.append(tree_map(lambda a, b: a - b, tr, master))
            weights.append(c.n_samples)
        if deltas:
            import jax.numpy as jnp
            w = jnp.asarray(weights, jnp.float32); w = w / w.sum()
            agg = tree_map(lambda *ds: sum(wi * d for wi, d in zip(w, ds)), *deltas)
            self.head = tree_map(lambda a, d: (a + d).astype(a.dtype),
                                 master, agg)["head"]
