"""Linear Probing (Kornblith et al., 2019b): only the output layer trains;
the backbone and all adapters stay frozen — the plan declares no active
adapters, so the shared engine builds a head-only step."""
from __future__ import annotations

from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan


@register_strategy("linear_probing")
class LinearProbing(Strategy):
    name = "linear_probing"
    memory_method = "linear_probing"

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain.replace(train_head=True), key)

    def plan(self, client, round_idx) -> TrainablePlan:
        return TrainablePlan(adapters=None, train_head=True)
