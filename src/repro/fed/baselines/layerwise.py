"""Layerwise-sparsity baselines (PAPERS.md second axis).

* ``layer_pruning`` — federated layer pruning (Wu et al., arXiv:2508.17209):
  a **fixed** evenly-spaced subset of layers survives for the whole run;
  every client trains the same retained adapters, pruned layers' adapters
  are frozen at init.  Memory and compute scale with the retained count —
  the structural counterpart of CHAINFED's window without the chain
  schedule.
* ``layer_dropout`` — federated layer dropout (Wang et al.,
  arXiv:2503.10217): each client independently redraws a **random** retained
  subset every round.  Aggregation is per-layer holder-normalized (only the
  clients that trained a layer vote on it) — exactly FedRA's aggregation,
  which both inherit; what differs is the allocation policy (evenly-spaced
  static vs per-dispatch random) and the device-side memory story (pruning
  discards layers outright; dropout keeps the full stack resident since any
  layer can wake next round).

Both are pure ``TrainablePlan`` layer masks — no engine changes — and
register as ordinary registry strategies for ``benchmarks/table1_main.py``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..registry import register_strategy
from .fedra import FedRA


def evenly_spaced(total: int, keep: int) -> np.ndarray:
    """``keep`` layer indices spread uniformly over ``total`` (always
    includes layer 0; deterministic — the pruned architecture is a run-level
    constant)."""
    keep = max(1, min(keep, total))
    return np.unique(np.round(np.linspace(0, total - 1, keep)).astype(int))


@register_strategy("layer_pruning")
class LayerPruning(FedRA):
    """Fixed retained subset shared by every client — the holder
    normalization degenerates to plain FedAvg over the retained layers
    (every client holds them), but riding FedRA's aggregation keeps one
    code path for both allocation policies."""
    name = "layer_pruning"
    memory_method = "layer_pruning"
    keep_ratio = 0.5

    def __init__(self, cfg, chain, key, keep_ratio=None):
        super().__init__(cfg, chain, key)
        if keep_ratio is not None:
            self.keep_ratio = float(keep_ratio)
        L = cfg.total_chain_layers
        self.keep_layers = max(1, int(round(self.keep_ratio * L)))
        mask = np.zeros((L,), np.float32)
        mask[evenly_spaced(L, self.keep_layers)] = 1.0
        self._mask = jnp.asarray(mask)

    def client_mask(self, client, round_idx):
        return self._mask

    def memory_kwargs(self, round_idx):
        return {"keep_layers": self.keep_layers}


@register_strategy("layer_dropout")
class LayerDropout(FedRA):
    """Per-client per-round random retained subset.  Differs from FedRA
    only in framing (dropout regularization vs memory-budget allocation)
    and in the memory model: the full stack stays resident on device."""
    name = "layer_dropout"
    memory_method = "layer_dropout"
    keep_ratio = 0.5

    def __init__(self, cfg, chain, key, keep_ratio=None):
        super().__init__(cfg, chain, key)
        if keep_ratio is not None:
            self.keep_ratio = float(keep_ratio)
        L = cfg.total_chain_layers
        self.keep_layers = max(1, int(round(self.keep_ratio * L)))

    def client_mask(self, client, round_idx):
        L = self.cfg.total_chain_layers
        sel = self._rng.choice(L, self.keep_layers, replace=False)
        mask = np.zeros((L,), np.float32)
        mask[sel] = 1.0
        return jnp.asarray(mask)

    def memory_kwargs(self, round_idx):
        return {"keep_layers": self.keep_layers}
