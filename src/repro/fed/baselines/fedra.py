"""FedRA [arXiv:2311.11227]: random allocation — each client is assigned a
random subset of layers matching its memory budget and trains only those
adapters; the server aggregates per layer over the clients that held it.
The random allocation is the plan's runtime layer mask (one compiled step
for every client/round); only the per-layer holder-normalized aggregation
is method-specific."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...core.adapters import ActiveAdapters
from ...utils.tree import tree_map
from ..registry import register_strategy
from ..strategies import Strategy, TrainablePlan, cohort_fedavg


@register_strategy("fedra")
class FedRA(Strategy):
    name = "fedra"
    memory_method = "fedra"
    # holder-normalized aggregation needs each client's plaintext layer
    # mask against its update — not recoverable from a masked sum
    secure_compatible = False

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain, key)
        self._rng = np.random.default_rng(4242)

    def plan(self, client, round_idx) -> TrainablePlan:
        return TrainablePlan(
            adapters=ActiveAdapters.full(self.cfg.total_chain_layers),
            train_head=self.head is not None, layer_masked=True)

    def client_mask(self, client, round_idx):
        L = self.cfg.total_chain_layers
        keep = max(1, L // 2)
        sel = self._rng.choice(L, keep, replace=False)
        mask = np.zeros((L,), np.float32)
        mask[sel] = 1.0
        return jnp.asarray(mask)

    def plan_masks(self, sim, client, round_idx):
        return {"layer_mask": self.client_mask(client, round_idx)}

    def extra_state(self):
        # the per-round layer-mask stream must resume where it stopped
        # (PCG64 state carries 128-bit ints — save_state encodes them)
        return {"rng": self._rng.bit_generator.state}

    def load_extra_state(self, state):
        self._rng.bit_generator.state = state["rng"]

    def cohort_aggregate(self, plan):
        """The holder-normalized aggregation below, traced into the cohort
        step: stacked deltas (C, L, ...) and stacked layer masks (C, L)
        replace the host-side per-client loop."""

        def agg(trainable0, deltas, weights, masks):
            lm = masks["layer_mask"]                          # (C, L)
            denom = jnp.maximum(1e-9, (lm * weights[:, None]).sum(0))  # (L,)

            def agg_layers(t0, d):
                # zero unheld layers' deltas (AdamW decay leakage — see
                # aggregate()), weight, then per-layer holder normalization
                d = d * lm.reshape(lm.shape + (1,) * (d.ndim - 2))
                s = (d.astype(jnp.float32)
                     * weights.reshape((-1,) + (1,) * (d.ndim - 1))).sum(0)
                s = s / denom.reshape((-1,) + (1,) * (s.ndim - 1))
                return (t0 + s).astype(t0.dtype)

            new = {"adapters": tree_map(agg_layers, trainable0["adapters"],
                                        deltas["adapters"])}
            if "head" in trainable0:
                new["head"] = cohort_fedavg(trainable0["head"],
                                            deltas["head"], weights, masks)
            return new

        return agg

    def aggregate(self, round_idx, plans, deltas, weights, masks):
        if not deltas:
            return
        w = jnp.asarray(weights, jnp.float32)
        m = jnp.stack([mk["layer_mask"] for mk in masks])     # (n, L)
        denom = jnp.maximum(1e-9, (m * w[:, None]).sum(0))    # (L,)
        # zero out unheld layers' deltas: AdamW weight decay otherwise
        # leaks nonzero deltas into them, which the per-layer holder
        # normalisation below would divide by ~0 (NaN explosion)
        for d, mk in zip(deltas, masks):
            lm = mk["layer_mask"]
            d["adapters"] = tree_map(
                lambda x: x * lm.reshape((-1,) + (1,) * (x.ndim - 1)),
                d["adapters"])

        def agg_layers(*ds):
            s = sum(wi * d for wi, d in zip(w, ds))
            return s / denom.reshape((-1,) + (1,) * (s.ndim - 1))

        master = self.engine.init_trainable(plans[0], self._params,
                                            self.adapters, self.head)
        new = dict(master)
        new["adapters"] = tree_map(
            lambda a, d: (a + d).astype(a.dtype), master["adapters"],
            tree_map(agg_layers, *[d["adapters"] for d in deltas]))
        if "head" in master:
            agg_head = self.engine.fedavg([d["head"] for d in deltas], weights)
            new["head"] = tree_map(
                lambda a, d: (a + d).astype(a.dtype), master["head"], agg_head)
        self._params, self.adapters, self.head = self.engine.commit(
            plans[0], self._params, self.adapters, self.head, new)
