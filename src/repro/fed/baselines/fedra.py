"""FedRA [arXiv:2311.11227]: random allocation — each client is assigned a
random subset of layers matching its memory budget and trains only those
adapters; the server aggregates per layer over the clients that held it."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...utils.tree import tree_map
from ..strategies import Strategy, layer_mask_apply


class FedRA(Strategy):
    name = "fedra"
    memory_method = "fedra"

    def __init__(self, cfg, chain, key):
        super().__init__(cfg, chain, key)
        self._rng = np.random.default_rng(4242)

    def client_mask(self, client, round_idx):
        L = self.cfg.total_chain_layers
        keep = max(1, L // 2)
        sel = self._rng.choice(L, keep, replace=False)
        mask = np.zeros((L,), np.float32)
        mask[sel] = 1.0
        return jnp.asarray(mask)

    def round(self, sim, clients, round_idx):
        deltas, masks, weights = [], [], []
        master = self.master_trainable()
        for c in clients:
            mask = self.client_mask(c, round_idx)
            tr = master
            st = self.opt.init(tr)
            for batch in sim.client_batches(c, self.chain.local_steps):
                tr, st, _ = self._local_step(tr, st, self._params, batch, mask)
            delta = tree_map(lambda a, b: a - b, tr, master)
            # zero out unheld layers' deltas: AdamW weight decay otherwise
            # leaks nonzero deltas into them, which the per-layer holder
            # normalisation below would divide by ~0 (NaN explosion)
            delta["adapters"] = tree_map(
                lambda d: d * mask.reshape((-1,) + (1,) * (d.ndim - 1)),
                delta["adapters"])
            deltas.append(delta)
            masks.append(mask)
            weights.append(c.n_samples)
        if not deltas:
            return
        w = jnp.asarray(weights, jnp.float32)
        m = jnp.stack(masks)                                  # (n, L)
        denom = jnp.maximum(1e-9, (m * w[:, None]).sum(0))    # (L,)

        def agg_layers(*ds):
            s = sum(wi * d for wi, d in zip(w, ds))
            return s / denom.reshape((-1,) + (1,) * (s.ndim - 1))

        def agg_flat(*ds):
            return sum(wi * d for wi, d in zip(w / w.sum(), ds))

        new = dict(master)
        new["adapters"] = tree_map(
            lambda a, d: (a + d).astype(a.dtype), master["adapters"],
            tree_map(agg_layers, *[d["adapters"] for d in deltas]))
        if "head" in master:
            new["head"] = tree_map(
                lambda a, d: (a + d).astype(a.dtype), master["head"],
                tree_map(agg_flat, *[d["head"] for d in deltas]))
        self._commit(new)
