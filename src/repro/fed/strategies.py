"""Strategy base + shared jitted machinery for the baseline suite (paper §5.2
/ App. E).  Every strategy owns its global state and implements:

    round(sim, clients, round_idx)   — one federated round
    evaluate(batch) -> (loss, acc)   — end-to-end eval
    memory_method / memory_kwargs    — ties into the memory-wall sampler
    comm_bytes_per_round()           — uplink accounting

All methods train the task output layer (``cls_head``) alongside their own
trainables — standard fine-tuning protocol for classification backbones.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.memory import comm_bytes_per_round
from ..models.config import ChainConfig, ModelConfig
from ..models.transformer import (forward_full, init_adapters, init_cls_head,
                                  init_lm)
from ..optim.base import make_optimizer
from ..train.losses import accuracy, cross_entropy, moe_penalty
from ..utils.tree import tree_map


def layer_mask_apply(grads, mask):
    """mask: (L,) float — zero out gradients of unselected layers."""
    return tree_map(lambda g: g * mask.reshape((-1,) + (1,) * (g.ndim - 1)), grads)


class Strategy:
    name = "base"
    memory_method = "full_adapters"

    def __init__(self, cfg: ModelConfig, chain: ChainConfig, key):
        self.cfg, self.chain = cfg, chain
        k1, k2 = jax.random.split(key)
        self._params = init_lm(k1, cfg)
        self.adapters = init_adapters(k2, cfg)
        self.head = init_cls_head(self._params) if chain.train_head else None
        self.opt = make_optimizer(chain.optimizer, chain.lr)
        self._build()

    # base params are swappable (pretrained checkpoints); the head re-derives
    @property
    def params(self):
        return self._params

    @params.setter
    def params(self, p):
        self._params = p
        if self.head is not None:
            self.head = init_cls_head(p)

    def eval_params(self):
        if self.head is None:
            return self._params
        return {**self._params, "cls_head": self.head}

    def _with_head(self, params, trainable):
        if "head" in trainable:
            return {**params, "cls_head": trainable["head"]}
        return params

    def master_trainable(self):
        t = {"adapters": self.adapters}
        if self.head is not None:
            t["head"] = self.head
        return t

    def _commit(self, trainable):
        self.adapters = trainable["adapters"]
        if "head" in trainable:
            self.head = trainable["head"]

    # -------------------------------------------------- shared jitted pieces
    def _build(self):
        cfg = self.cfg

        def loss_fn(trainable, params, batch):
            p = self._with_head(params, trainable)
            logits, aux = forward_full(p, trainable["adapters"], batch, cfg,
                                       remat=False)
            return (cross_entropy(logits, batch["labels"])
                    + moe_penalty(aux, cfg))

        @jax.jit
        def local_step(trainable, opt_state, params, batch, mask):
            loss, grads = jax.value_and_grad(loss_fn)(trainable, params, batch)
            grads["adapters"] = layer_mask_apply(grads["adapters"], mask)
            trainable, opt_state = self.opt.step(trainable, grads, opt_state)
            return trainable, opt_state, loss

        @jax.jit
        def eval_fn(params, adapters, batch):
            logits, aux = forward_full(params, adapters, batch, cfg, remat=False)
            return (cross_entropy(logits, batch["labels"]) + moe_penalty(aux, cfg),
                    accuracy(logits, batch["labels"],
                             batch.get("class_tokens")))

        self._local_step, self._eval = local_step, eval_fn

    def full_mask(self):
        return jnp.ones((self.cfg.total_chain_layers,), jnp.float32)

    # -------------------------------------------------- default adapter FedAvg
    def client_mask(self, client, round_idx):
        return self.full_mask()

    def round(self, sim, clients, round_idx):
        deltas, weights = [], []
        master = self.master_trainable()
        for c in clients:
            mask = self.client_mask(c, round_idx)
            tr = master
            opt_state = self.opt.init(tr)
            for batch in sim.client_batches(c, self.chain.local_steps):
                tr, opt_state, _ = self._local_step(tr, opt_state, self._params,
                                                    batch, mask)
            deltas.append(tree_map(lambda a, b: a - b, tr, master))
            weights.append(c.n_samples)
        self._fedavg(deltas, weights)

    def _fedavg(self, deltas, weights):
        if not deltas:
            return
        w = jnp.asarray(weights, jnp.float32)
        w = w / jnp.sum(w)
        agg = tree_map(lambda *ds: sum(wi * d for wi, d in zip(w, ds)), *deltas)
        new = tree_map(lambda a, d: (a + d).astype(a.dtype),
                       self.master_trainable(), agg)
        self._commit(new)

    def evaluate(self, batch):
        loss, acc = self._eval(self.eval_params(), self.adapters, batch)
        return float(loss), float(acc)

    def memory_kwargs(self, round_idx):
        return {}

    def comm_bytes_per_round(self) -> int:
        return comm_bytes_per_round(self.cfg, self.memory_method)
