"""Strategy base + the shared plan-driven training engine (paper §5.2 /
App. E).

The federated API is declarative: a strategy says *what* it trains via a
``TrainablePlan`` (an ``ActiveAdapters`` composition spec plus head/embedding
flags, a loss hook, a gradient program and an optional trainable transform);
one ``PlanEngine`` owns the jitted ``local_step``/``eval_fn`` machinery and
the FedAvg aggregation for every strategy — baselines and CHAINFED alike.
Plans are hashable, so the engine's jit cache is keyed on them: the DLCT
cyclic window reuses ≤ L compilations (the old per-offset stage cache), and
baselines share a single compilation.

**Gradient programs** (``GRAD_PROGRAMS``) decouple *how the update direction
is estimated* from the rest of the engine: ``"ad"`` is reverse-mode
``value_and_grad`` (the default), ``"spsa"`` the backprop-free perturbation
estimator (FwdLLM), and ``"kseed"`` the K-seed zeroth-order coefficient
estimator (FedKSeed), whose per-client output is a ``(K,)`` coefficient
vector instead of a trainable delta.  A plan selects its program by name
(``grad=``) with frozen knobs in ``grad_cfg`` — both hash into the jit-cache
key, so every program rides the same batched cohort path.

The round hot path is **batched cohort execution**: sampled clients are
grouped by plan, each group's local batches are stacked into
``(C, local_steps, b, ...)`` arrays (``FedSim.cohort_batches``), and one
jitted ``cohort_step`` per plan runs ``lax.scan`` over local steps inside
``vmap`` over the client axis — optimizer init, per-client masking and the
sample-weighted FedAvg all inside the same compilation.  The pjit pod path
(``repro.train.steps``) builds its fed step from the same
``make_client_update``; per-client sequential dispatch survives only as
``Strategy.sequential_round`` (the benchmark baseline and the fallback for
strategies with host-side aggregation).

A strategy implements:

    plan(client, round_idx)            — the TrainablePlan for this update
    plan_masks(sim, client, round_idx) — runtime mask arrays (traced, no
                                         recompile; RNG keys and aux inputs
                                         like C2A's label histogram ride here)
    init_trainable(plan)               — round-start trainable (extra leaves
                                         like C2A's hypernetwork hook in here)
    cohort_aggregate(plan)             — optional in-graph aggregation override
    commit_trainable(plan, new)        — commit the aggregated cohort output
    round(sim, clients, round_idx)     — one federated round (generic default)
    evaluate(batch) -> (loss, acc)     — end-to-end eval
    memory_method / memory_kwargs      — ties into the memory-wall sampler
    comm_bytes_per_round()             — uplink accounting

All methods train the task output layer (``cls_head``) alongside their own
trainables — standard fine-tuning protocol for classification backbones.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adapters import ActiveAdapters
from ..core.memory import comm_bytes_per_round
from ..models.config import ChainConfig, ModelConfig
from ..models.transformer import (ChainSegments, forward_chain, forward_full,
                                  init_adapters, init_cls_head, init_lm)
from ..optim.base import make_optimizer
from ..optim.zeroth import (forward_value_and_grad, kseed_directional,
                            spsa_value_and_grad)
from ..train.losses import accuracy, cross_entropy, gpo_loss, moe_penalty
from ..utils.tree import tree_map


def layer_mask_apply(grads, mask):
    """mask: (L,) float — zero out gradients of unselected layers."""
    return tree_map(lambda g: g * mask.reshape((-1,) + (1,) * (g.ndim - 1)), grads)


def rank_mask_apply(adapters, rmask):
    """rmask: (r,) float — keep only the leading bottleneck ranks (FLoRA)."""
    return {"down": adapters["down"] * rmask[None, None, :],
            "up": adapters["up"] * rmask[None, :, None]}


def stack_masks(mask_dicts):
    """Stack per-client mask dicts along a leading client axis: a list of
    ``{name: (...)}`` becomes ``{name: (C, ...)}`` — the vmapped runtime
    arguments of a cohort step."""
    if not mask_dicts or not mask_dicts[0]:
        return {}
    return {k: jnp.stack([m[k] for m in mask_dicts])
            for k in mask_dicts[0]}


# ===================================================================== plans
@dataclasses.dataclass(frozen=True)
class TrainablePlan:
    """Declarative description of one client update: which adapter layers are
    active (an ``ActiveAdapters`` spec; None = adapters frozen entirely),
    whether the task head / embedding train, which runtime masks apply, which
    loss hook drives the step, and which gradient program estimates the
    update direction.

    Hashable — the engine compiles one jitted step per distinct plan.  Mask
    *values* are runtime arguments (see ``Strategy.plan_masks``) so per-round
    or per-client masks never trigger recompilation; ``grad_cfg`` is a frozen
    ``((name, value), ...)`` tuple of program knobs (``eps``, ``n_samples``,
    ``seeds``) that *does* key the cache — change a knob, get a new
    compilation, exactly like changing the loss.
    """
    adapters: Optional[ActiveAdapters]
    train_head: bool = True
    train_embedding: bool = False
    layer_masked: bool = False      # expects masks["layer_mask"]: (L,)
    rank_masked: bool = False       # expects masks["rank_mask"]: (r,)
    loss: str = "ce"                # key into LOSS_HOOKS
    lam: float = 0.0                # GPO global-loss weight (loss == "gpo*")
    remat: bool = False             # checkpoint the forward (pod-scale steps)
    grad: str = "ad"                # key into GRAD_PROGRAMS
    grad_cfg: tuple = ()            # frozen (knob, value) pairs for the program
    transform: Optional[str] = None  # key into TRANSFORM_HOOKS (e.g. C2A FiLM)
    opt_bits: Optional[int] = None  # optimizer-state precision override:
                                    # None inherits ``chain.opt_bits``; 8
                                    # stores int8 blockwise moments (keys
                                    # the jit cache — int8 state has a
                                    # different structure)

    @property
    def grad_options(self) -> dict:
        return dict(self.grad_cfg)

    @property
    def window_segments(self) -> ChainSegments:
        a, b = self.adapters.train_span
        return ChainSegments(a, b - a)

    @property
    def is_window(self) -> bool:
        return self.adapters is not None and not self.adapters.is_full


# ================================================================ loss hooks
LOSS_HOOKS = {}


def register_loss_hook(name):
    def deco(fn):
        LOSS_HOOKS[name] = fn
        return fn
    return deco


def _apply_trainable(params, trainable):
    """Overlay trainable head/embedding leaves onto the base params."""
    if "head" in trainable:
        params = {**params, "cls_head": trainable["head"]}
    if "embed" in trainable:
        params = {**params, "embed": trainable["embed"]}
    return params


@register_loss_hook("ce")
def _ce_hook(cfg: ModelConfig, chain: ChainConfig, plan: TrainablePlan):
    """End-to-end cross-entropy over the full adapter stack (baselines)."""

    def loss_fn(trainable, params, frozen_adapters, batch, masks):
        ad = trainable.get("adapters", frozen_adapters)
        if plan.rank_masked:
            ad = rank_mask_apply(ad, masks["rank_mask"])
        p = _apply_trainable(params, trainable)
        logits, aux = forward_full(p, ad, batch, cfg, remat=plan.remat)
        loss = cross_entropy(logits, batch["labels"]) + moe_penalty(aux, cfg)
        return loss, {"local": loss, "global": loss}

    return loss_fn


@register_loss_hook("gpo")
def _gpo_hook(cfg: ModelConfig, chain: ChainConfig, plan: TrainablePlan):
    """CHAINFED staged forward + GPO dual objective (paper Eq. 2).  The
    trainable adapter sub-stack is the DLCT window; prefix/suffix come from
    the frozen full stack via the plan's ActiveAdapters spec."""
    seg = plan.window_segments
    final = seg.prefix + seg.window >= cfg.total_chain_layers

    def loss_fn(trainable, params, frozen_adapters, batch, masks):
        p = _apply_trainable(params, trainable)
        out = forward_chain(p, trainable["adapters"], frozen_adapters, batch,
                            cfg, seg, remat=plan.remat)
        return gpo_loss(out, batch["labels"], cfg, plan.lam, final)

    return loss_fn


@register_loss_hook("gpo_seq")
def _gpo_seq_hook(cfg: ModelConfig, chain: ChainConfig, plan: TrainablePlan):
    """Sequential GPO (§Perf lever, single-stack models only): each CE branch
    is checkpointed inside ``forward_chain`` so only the (B, S, d) window
    output stays live for backward instead of both vocab-sized logits."""
    seg = plan.window_segments
    final = seg.prefix + seg.window >= cfg.total_chain_layers

    def loss_fn(trainable, params, frozen_adapters, batch, masks):
        p = _apply_trainable(params, trainable)
        out = forward_chain(p, trainable["adapters"], frozen_adapters, batch,
                            cfg, seg, remat=plan.remat,
                            loss_ctx=(batch["labels"], plan.lam, final))
        loss = out["loss"] + moe_penalty(out["aux"], cfg)
        return loss, {"local": out["local"], "global": out["global"]}

    return loss_fn


# =========================================================== transform hooks
TRANSFORM_HOOKS = {}


def register_transform(name):
    """Register a plan-level trainable transform: ``factory(cfg, chain, plan)
    -> fn(trainable, masks) -> trainable`` applied inside the loss (so
    gradients flow through it).  This is how C2A's hypernetwork-generated
    FiLM modulation rides the shared engine: the hypernetwork is an extra
    trainable leaf, the client's label histogram a runtime mask."""
    def deco(fn):
        TRANSFORM_HOOKS[name] = fn
        return fn
    return deco


def make_loss_fn(cfg: ModelConfig, chain: ChainConfig, plan: TrainablePlan):
    """The plan's loss hook, with its trainable transform (if any) applied
    inside — the single loss entry point every gradient program sees."""
    loss_fn = LOSS_HOOKS[plan.loss](cfg, chain, plan)
    if plan.transform is None:
        return loss_fn
    tf = TRANSFORM_HOOKS[plan.transform](cfg, chain, plan)

    def transformed(trainable, params, frozen_adapters, batch, masks):
        return loss_fn(tf(trainable, masks), params, frozen_adapters, batch,
                       masks)

    return transformed


# ========================================================= gradient programs
GRAD_PROGRAMS = {}


def register_grad_program(name, whole_client=False, needs_rng=False):
    """Register a gradient program under ``name`` (mirrors LOSS_HOOKS).
    ``needs_rng`` marks stochastic programs that read
    ``masks["grad_key"]`` — callers that build the masks themselves (the
    pod step) use it to fail loudly when no key is supplied.

    Two shapes:

    * per-step estimator (default): ``factory(cfg, chain, plan, loss_fn) ->
      grad_fn(trainable, params, frozen_adapters, batch, masks) -> (loss,
      parts, grads)`` — the engine wraps it in the shared scan-over-local-
      steps × optimizer machinery.  Stochastic estimators read their
      per-client RNG from ``masks["grad_key"]`` (already folded with the
      local-step index — see ``fold_step_masks``).
    * ``whole_client=True``: ``factory(cfg, chain, plan, loss_fn) ->
      client_update(trainable0, params, frozen_adapters, batches, masks) ->
      (update, mean_loss)`` — the program owns the entire local phase and
      returns the client *update* directly (not necessarily trainable-shaped:
      FedKSeed returns ``{"kseed": (K,)}`` coefficients).  Donation is
      disabled for such plans since the round-start state survives the step.
    """
    def deco(fn):
        fn.whole_client = whole_client
        fn.needs_rng = needs_rng
        GRAD_PROGRAMS[name] = fn
        return fn
    return deco


def _is_whole_client(plan: TrainablePlan) -> bool:
    return getattr(GRAD_PROGRAMS[plan.grad], "whole_client", False)


def fold_step_masks(masks, step_idx):
    """Per-step view of the runtime masks: the per-client RNG key (if any)
    is folded with the local-step index so every (round, client, step) draws
    an independent, reproducible key."""
    if "grad_key" not in masks:
        return masks
    return {**masks, "grad_key": jax.random.fold_in(masks["grad_key"],
                                                    step_idx)}


@register_grad_program("ad")
def _ad_program(cfg: ModelConfig, chain: ChainConfig, plan: TrainablePlan,
                loss_fn):
    """Reverse-mode autodiff — today's ``value_and_grad`` step."""

    def grad_fn(trainable, params, frozen_adapters, batch, masks):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainable, params, frozen_adapters, batch, masks)
        return loss, parts, grads

    return grad_fn


@register_grad_program("spsa", needs_rng=True)
def _spsa_program(cfg: ModelConfig, chain: ChainConfig, plan: TrainablePlan,
                  loss_fn):
    """Backprop-free SPSA perturbation estimator (FwdLLM): antithetic
    central differences over the trainable, vectorized over ``n_samples``
    perturbations with ``vmap``.  No activation storage — two forward passes
    per sample.  Knobs: ``eps`` (default 1e-3), ``n_samples`` (default 4);
    RNG from ``masks["grad_key"]``."""
    opts = plan.grad_options
    eps = opts.get("eps", 1e-3)
    n_samples = opts.get("n_samples", 4)

    def grad_fn(trainable, params, frozen_adapters, batch, masks):
        def scalar_loss(tr):
            loss, _ = loss_fn(tr, params, frozen_adapters, batch, masks)
            return loss

        loss, grads, _ = spsa_value_and_grad(scalar_loss, trainable,
                                             masks["grad_key"], eps=eps,
                                             n_samples=n_samples)
        return loss, {"local": loss, "global": loss}, grads

    return grad_fn


@register_grad_program("jvp", needs_rng=True)
def _jvp_program(cfg: ModelConfig, chain: ChainConfig, plan: TrainablePlan,
                 loss_fn):
    """True forward-mode gradient program (FwdLLM fidelity): ``jax.jvp``
    per perturbation direction — the exact directional derivative in one
    forward pass, no finite-difference bias and no ``eps`` knob, with the
    same no-activation-storage memory profile as ``"spsa"``.  Knobs:
    ``n_samples`` (default 4); RNG from ``masks["grad_key"]``."""
    n_samples = plan.grad_options.get("n_samples", 4)

    def grad_fn(trainable, params, frozen_adapters, batch, masks):
        def scalar_loss(tr):
            loss, _ = loss_fn(tr, params, frozen_adapters, batch, masks)
            return loss

        loss, grads, _ = forward_value_and_grad(scalar_loss, trainable,
                                                masks["grad_key"],
                                                n_samples=n_samples)
        return loss, {"local": loss, "global": loss}, grads

    return grad_fn


@register_grad_program("kseed", whole_client=True)
def _kseed_program(cfg: ModelConfig, chain: ChainConfig, plan: TrainablePlan,
                   loss_fn):
    """K-seed zeroth-order coefficient estimation (FedKSeed): the client's
    whole local phase estimates the directional derivative along K fixed
    seed-reconstructed directions of the *full* parameter set (base params
    ride along as the ``_base`` leaf) and uploads only the ``(K,)``
    coefficient vector — the cohort output is ``(C, K)``, aggregated
    in-graph by ``FedKSeed.cohort_aggregate`` and materialized once
    server-side with ``kseed_apply``.  Knobs: ``seeds`` (tuple of K ints),
    ``eps``."""
    opts = plan.grad_options
    seeds = jnp.asarray(opts["seeds"], jnp.int32)
    eps = opts.get("eps", 1e-3)

    def client_update(trainable0, params, frozen_adapters, batches, masks):
        full0 = {"_base": params, **trainable0}

        def one_batch(_, mb):
            def scalar_loss(full):
                tr = {k: v for k, v in full.items() if k != "_base"}
                loss, _ = loss_fn(tr, full["_base"], frozen_adapters, mb,
                                  masks)
                return loss

            return None, kseed_directional(scalar_loss, full0, seeds,
                                           eps=eps)

        # estimate on every local batch at the round-start point and average
        # — local steps sharpen the estimate instead of walking the iterate
        _, (coeffs, losses) = jax.lax.scan(one_batch, None, batches)
        return {"kseed": jnp.mean(coeffs, axis=0)}, jnp.mean(losses)

    return client_update


# ======================================================= client-local update
def make_client_update(cfg: ModelConfig, chain: ChainConfig,
                       plan: TrainablePlan, opt):
    """One client's whole local optimisation as a traced function:

        client_update(trainable0, params, frozen_adapters, batches, masks)
            -> (update, mean_loss)

    ``batches`` leaves are ``(local_steps, b, ...)`` — ``lax.scan`` consumes
    the leading axis; optimizer state is initialized *inside* the trace so a
    cohort step can vmap this over a stacked client axis with no host work.
    ``update`` is the client's round contribution: the trainable delta for
    delta-style programs, the program-defined upload (e.g. FedKSeed's
    coefficients) for whole-client programs.  Shared by the single-host
    ``PlanEngine.cohort_step`` and the pjit pod step builders in
    ``repro.train.steps``."""
    loss_fn = make_loss_fn(cfg, chain, plan)
    factory = GRAD_PROGRAMS[plan.grad]
    if factory.whole_client:
        return factory(cfg, chain, plan, loss_fn)
    grad_fn = factory(cfg, chain, plan, loss_fn)

    def client_update(trainable0, params, frozen_adapters, batches, masks):
        def one_step(carry, xs):
            mb, i = xs
            tr, opt_state = carry
            loss, _parts, grads = grad_fn(tr, params, frozen_adapters, mb,
                                          fold_step_masks(masks, i))
            if plan.layer_masked:
                grads["adapters"] = layer_mask_apply(grads["adapters"],
                                                     masks["layer_mask"])
            if plan.rank_masked:
                grads["adapters"] = rank_mask_apply(grads["adapters"],
                                                    masks["rank_mask"])
            tr, opt_state = opt.step(tr, grads, opt_state)
            return (tr, opt_state), loss

        n_steps = jax.tree_util.tree_leaves(batches)[0].shape[0]
        (tr, _), losses = jax.lax.scan(
            one_step, (trainable0, opt.init(trainable0)),
            (batches, jnp.arange(n_steps)))
        return tree_map(lambda a, b: a - b, tr, trainable0), jnp.mean(losses)

    return client_update


def cohort_fedavg(trainable0, deltas, weights, masks):
    """Default in-graph aggregation: sample-weighted mean over the cohort
    axis, committed onto the round-start trainable.  ``deltas`` leaves are
    ``(C, ...)``; ``weights`` is ``(C,)``."""
    w = weights / jnp.sum(weights)
    return tree_map(
        lambda t0, d: (t0 + jnp.tensordot(w, d.astype(jnp.float32), axes=1)
                       ).astype(t0.dtype),
        trainable0, deltas)


def cohort_norms(deltas):
    """Per-client global L2 norm over a stacked ``(C, ...)`` update tree:
    returns ``(C,)`` — the quantity DP clipping and the norm-clip robust
    aggregator bound."""
    sq = [jnp.sum(jnp.square(d.astype(jnp.float32)).reshape(d.shape[0], -1),
                  axis=1)
          for d in jax.tree_util.tree_leaves(deltas)]
    return jnp.sqrt(sum(sq))


def scale_cohort(deltas, scales):
    """Multiply each client's update by its ``(C,)`` scale factor."""
    return tree_map(
        lambda d: (d.astype(jnp.float32)
                   * scales.reshape((-1,) + (1,) * (d.ndim - 1))), deltas)


# ======================================================= aggregator registry
AGGREGATORS = {}


def register_aggregator(name):
    """Register a cohort-aggregation *factory* under ``name``:
    ``factory(**opts) -> agg(trainable0, deltas, weights, masks)``.  The
    default ``"fedavg"`` is the fused sample-weighted mean; the robust
    variants (trimmed mean, coordinate median, norm-clip — byzantine
    tolerance, see ``repro.fed.faults``) register alongside it.  A strategy
    selects one via its ``aggregator`` / ``aggregator_opts`` attributes
    (``run_experiment(aggregator=...)``, ``launch.train --aggregator``)."""
    def deco(fn):
        AGGREGATORS[name] = fn
        return fn
    return deco


def make_aggregator(name, **opts):
    from . import faults  # noqa: F401  (registers the robust aggregators)
    if name not in AGGREGATORS:
        raise KeyError(f"unknown aggregator {name!r}; available: "
                       f"{', '.join(sorted(AGGREGATORS))}")
    return AGGREGATORS[name](**opts)


@register_aggregator("fedavg")
def _fedavg_factory():
    return cohort_fedavg


def as_rng_aggregate(agg):
    """Normalize an aggregation to the engine's 5-arg calling convention
    ``agg(trainable0, deltas, weights, masks, rng)``.  Legacy 4-arg
    aggregations (``cohort_fedavg``, strategy ``cohort_aggregate``
    overrides) ignore the rng; DP-wrapped aggregations consume it for the
    per-round noise draw."""
    if agg is None:
        agg = cohort_fedavg
    try:
        n = len(inspect.signature(agg).parameters)
    except (TypeError, ValueError):
        n = 4
    if n >= 5:
        return agg
    return lambda t0, deltas, weights, masks, rng: agg(t0, deltas, weights,
                                                       masks)


# ==================================================================== engine
class PlanEngine:
    """Shared jitted machinery: one ``local_step`` / ``cohort_step`` per
    distinct plan, one ``eval_fn``, plan-aware trainable slicing/commit,
    weighted FedAvg."""

    def __init__(self, cfg: ModelConfig, chain: ChainConfig, opt):
        self.cfg, self.chain, self.opt = cfg, chain, opt
        self._steps = {}
        self._cohort = {}
        self._cohort_updates = {}
        self._client_updates = {}
        self._opts = {}             # opt_bits override → Optimizer
        self._eval = None

    def opt_for(self, plan: TrainablePlan):
        """The optimizer a plan's steps run — ``self.opt`` (built from the
        chain's ``optimizer``/``opt_bits``/``fused_optim`` knobs) unless the
        plan overrides ``opt_bits``.  Cached per bits: the plan keys the jit
        caches, so a plan always meets the same optimizer (and the same
        state structure) across rounds."""
        if plan.opt_bits is None:
            return self.opt
        if plan.opt_bits not in self._opts:
            self._opts[plan.opt_bits] = make_optimizer(
                self.chain.optimizer, self.chain.lr,
                opt_bits=plan.opt_bits,
                fused=getattr(self.chain, "fused_optim", None))
        return self._opts[plan.opt_bits]

    # ------------------------------------------------------------ jit cache
    def local_step(self, plan: TrainablePlan):
        """One jitted optimizer step for a plan — the sequential-path unit of
        dispatch.  The gradient comes from the plan's program (``grad=``);
        whole-client programs have no per-step form (use
        ``client_update_fn``)."""
        if plan not in self._steps:
            if _is_whole_client(plan):
                raise ValueError(
                    f"grad program {plan.grad!r} owns the whole client "
                    "update; dispatch through client_update_fn/cohort_step")
            grad_fn = GRAD_PROGRAMS[plan.grad](
                self.cfg, self.chain, plan,
                make_loss_fn(self.cfg, self.chain, plan))
            opt = self.opt_for(plan)

            @jax.jit
            def step(trainable, opt_state, params, frozen_adapters, batch,
                     masks):
                loss, parts, grads = grad_fn(trainable, params,
                                             frozen_adapters, batch, masks)
                if plan.layer_masked:
                    grads["adapters"] = layer_mask_apply(grads["adapters"],
                                                         masks["layer_mask"])
                if plan.rank_masked:
                    grads["adapters"] = rank_mask_apply(grads["adapters"],
                                                        masks["rank_mask"])
                trainable, opt_state = opt.step(trainable, grads, opt_state)
                return trainable, opt_state, loss, parts

            self._steps[plan] = step
        return self._steps[plan]

    def client_update_fn(self, plan: TrainablePlan):
        """Jitted single-client update (``(ls, b, ...)`` batch leaves) — the
        sequential-path unit of dispatch for whole-client grad programs."""
        if plan not in self._client_updates:
            self._client_updates[plan] = jax.jit(
                make_client_update(self.cfg, self.chain, plan,
                                   self.opt_for(plan)))
        return self._client_updates[plan]

    def cohort_step(self, plan: TrainablePlan, aggregate=None):
        """One jitted round for a whole plan-group:

            step(trainable0, params, frozen_adapters, batches, masks, weights,
                 rng=None)
                -> (new_trainable, mean_loss)

        ``batches`` leaves are ``(C, local_steps, b, ...)`` and mask leaves
        ``(C, ...)``: ``vmap`` strips the client axis, ``lax.scan`` the local
        steps.  Optimizer init, per-client masking and the sample-weighted
        FedAvg (mean over the cohort axis) are fused into one compilation —
        no per-client dispatch, no host-side aggregation.

        ``aggregate(trainable0, deltas, weights, masks)`` overrides the
        in-graph FedAvg (e.g. FedRA's holder-normalized mean); a 5-arg
        aggregation additionally receives ``rng`` — the per-round key the
        DP path draws its Gaussian noise from (``repro.fed.privacy``).  The
        compiled step is cached per plan: a strategy must pass the same
        aggregation semantics for a given plan across rounds.

        **Donation** — the round-start trainable is split into a donated and
        a referenced argument so every leaf that cannot alias another
        argument is donated (XLA writes the committed trainable into the
        donated buffers):

        * full-stack CE plans don't read ``frozen_adapters`` at all, so the
          engine drops it from the call and donates the whole trainable —
          adapter buffers included (the ROADMAP follow-up);
        * full-span GPO plans still read prefix/suffix from
          ``frozen_adapters`` (which *is* the trainable's adapter buffer),
          so only the adapters leaf rides the referenced argument;
        * trained embeddings alias ``params["embed"]`` and stay referenced.

        A donated trainable is consumed: callers must use the returned
        committed trainable, never the arrays they passed in
        (``ActiveAdapters.scatter_train`` short-circuits full spans for
        exactly this reason).  Whole-client grad programs (FedKSeed) return
        a non-trainable-shaped cohort output that is materialized onto the
        round-start state *after* the step, so their plans donate nothing.
        """
        if plan not in self._cohort:
            client_update = make_client_update(self.cfg, self.chain, plan,
                                               self.opt_for(plan))
            agg = as_rng_aggregate(aggregate)
            whole = _is_whole_client(plan)
            full_stack = plan.adapters is not None and plan.adapters.is_full
            needs_frozen = (plan.adapters is None or not full_stack
                            or plan.loss.startswith("gpo"))
            ref_keys = ()
            if full_stack and needs_frozen:
                ref_keys += ("adapters",)
            if plan.train_embedding:
                ref_keys += ("embed",)

            @functools.partial(jax.jit, donate_argnums=(0,))
            def step(tr_don, tr_ref, params, frozen_adapters, batches, masks,
                     weights, rng):
                trainable0 = {**tr_don, **tr_ref}
                updates, losses = jax.vmap(
                    client_update,
                    in_axes=(None, None, None, 0, 0))(
                        trainable0, params, frozen_adapters, batches, masks)
                new = agg(trainable0, updates, weights, masks, rng)
                return new, jnp.mean(losses)

            def call(trainable0, params, frozen_adapters, batches, masks,
                     weights, rng=None):
                if whole:   # round-start state survives: nothing to donate
                    tr_don, tr_ref = {}, trainable0
                else:
                    tr_don = {k: v for k, v in trainable0.items()
                              if k not in ref_keys}
                    tr_ref = {k: trainable0[k] for k in ref_keys
                              if k in trainable0}
                if not needs_frozen:
                    frozen_adapters = {}
                if rng is None:
                    # dead arg for rng-less aggregations (DCE'd by XLA);
                    # keeping it traced means a DP aggregation swaps in
                    # with no signature change and no recompile per round
                    rng = jax.random.PRNGKey(0)
                return step(tr_don, tr_ref, params, frozen_adapters, batches,
                            masks, weights, rng)

            self._cohort[plan] = call
        return self._cohort[plan]

    def cohort_updates(self, plan: TrainablePlan):
        """One jitted *dispatch wave* for a plan bucket:

            step(trainable0, params, frozen_adapters, batches, masks)
                -> (updates, losses)

        Same layout as ``cohort_step`` (``(C, local_steps, b, ...)`` batch
        leaves, ``(C, ...)`` masks) but the per-client updates come back
        stacked ``(C, ...)`` **unaggregated** — the event-driven runtime
        (``repro.fed.runtime``) computes a bucket's updates when the clients
        are *dispatched*, parks them on the virtual clock until each client's
        completion event, and folds staleness-discounted weights into the
        fused FedAvg tensordot only at commit time.  Nothing is donated: the
        round-start state must survive (updates from one model version are
        applied onto a later one — that is what staleness *is*)."""
        if plan not in self._cohort_updates:
            client_update = make_client_update(self.cfg, self.chain, plan,
                                               self.opt_for(plan))

            @jax.jit
            def step(trainable0, params, frozen_adapters, batches, masks):
                return jax.vmap(client_update,
                                in_axes=(None, None, None, 0, 0))(
                                    trainable0, params, frozen_adapters,
                                    batches, masks)

            self._cohort_updates[plan] = step
        return self._cohort_updates[plan]

    def eval_fn(self):
        if self._eval is None:
            cfg = self.cfg

            @jax.jit
            def ev(params, adapters, batch):
                logits, aux = forward_full(params, adapters, batch, cfg,
                                           remat=False)
                return (cross_entropy(logits, batch["labels"])
                        + moe_penalty(aux, cfg),
                        accuracy(logits, batch["labels"],
                                 batch.get("class_tokens")))

            self._eval = ev
        return self._eval

    # -------------------------------------------------------- plan plumbing
    def init_trainable(self, plan: TrainablePlan, params, adapters, head):
        t = {}
        if plan.adapters is not None:
            t["adapters"] = plan.adapters.train_slice(adapters)
        if plan.train_head and head is not None:
            t["head"] = head
        if plan.train_embedding:
            t["embed"] = params["embed"]
        return t

    def commit(self, plan: TrainablePlan, params, adapters, head, trainable):
        """Scatter an updated trainable back into (params, adapters, head)."""
        if "adapters" in trainable:
            adapters = plan.adapters.scatter_train(adapters,
                                                   trainable["adapters"])
        if "head" in trainable:
            head = trainable["head"]
        if "embed" in trainable:
            params = {**params, "embed": trainable["embed"]}
        return params, adapters, head

    @staticmethod
    def fedavg(deltas, weights):
        """Sample-weighted mean of client deltas (list-of-pytrees form — the
        sequential fallback path's aggregation).  Each leaf stacks to
        ``(C, ...)`` and contracts against the normalized weights in one
        ``tensordot`` instead of C scalar multiply-adds."""
        w = jnp.asarray(weights, jnp.float32)
        w = w / jnp.sum(w)
        return tree_map(
            lambda *ds: jnp.tensordot(
                w, jnp.stack(ds).astype(jnp.float32), axes=1),
            *deltas)


# ================================================================== strategy
class Strategy:
    name = "base"
    memory_method = "full_adapters"
    # --- privacy & robustness knobs (attached post-construction: subclass
    # --- __init__ signatures are bespoke, so `privacy.enable_dp` /
    # --- `privacy.enable_secure_agg` set instance attributes instead of
    # --- threading constructor kwargs through every strategy)
    dp = None                 # privacy.DPConfig — clip + noise in-graph
    secure = None             # privacy.SecureAggConfig — pairwise masking
    compression = None        # compress.CompressionConfig — lossy update
                              # compression + error feedback (attached via
                              # compress.enable_compression)
    aggregator = "fedavg"     # AGGREGATORS entry when cohort_aggregate is None
    aggregator_opts = None    # kwargs for the aggregator factory
    secure_compatible = True  # False: aggregation is not a linear weighted
                              # mean of uploads (FedRA holder normalization)
    grad_programs = ("ad",)   # gradient programs the strategy can run —
                              # "ad" backprop; fwdllm adds "spsa"/"jvp",
                              # fedkseed "kseed" (describe_strategy reads it)

    def __init__(self, cfg: ModelConfig, chain: ChainConfig, key):
        self.cfg, self.chain = cfg, chain
        k1, k2 = jax.random.split(key)
        self._params = init_lm(k1, cfg)
        self.adapters = init_adapters(k2, cfg)
        self.head = init_cls_head(self._params) if chain.train_head else None
        self.opt = make_optimizer(chain.optimizer, chain.lr,
                                  opt_bits=getattr(chain, "opt_bits", 32),
                                  fused=getattr(chain, "fused_optim", None))
        self.engine = PlanEngine(cfg, chain, self.opt)
        self._last_round_loss = None    # device scalar from the latest step
        self._adaptive_agg = {}         # jitted resolve_aggregate per plan
                                        # (adaptive-clip sync path)

    # base params are swappable (pretrained checkpoints); the head re-derives
    @property
    def params(self):
        return self._params

    @params.setter
    def params(self, p):
        self._params = p
        if self.head is not None:
            self.head = init_cls_head(p)

    def eval_params(self):
        if self.head is None:
            return self._params
        return {**self._params, "cls_head": self.head}

    # ------------------------------------------------------------ the plan
    def plan(self, client, round_idx) -> TrainablePlan:
        """Default: every adapter trains end-to-end (Full Adapters†)."""
        return TrainablePlan(
            adapters=ActiveAdapters.full(self.cfg.total_chain_layers),
            train_head=self.head is not None)

    def plan_masks(self, sim, client, round_idx) -> dict:
        """Runtime values for the plan's declared masks and program inputs
        (traced args): layer/rank masks, per-client RNG keys
        (``grad_key``), auxiliary conditioning like C2A's label histogram.
        ``sim`` gives access to population statistics; per-client leaves
        stack along a cohort axis (``stack_masks``)."""
        return {}

    def init_trainable(self, plan: TrainablePlan):
        """The round-start trainable for a plan.  Strategies with extra
        trainable leaves beyond adapters/head/embedding (e.g. C2A's
        hypernetwork) extend the dict here."""
        return self.engine.init_trainable(plan, self._params, self.adapters,
                                          self.head)

    def commit_trainable(self, plan: TrainablePlan, new):
        """Commit an aggregated cohort output back into strategy state.
        ``new`` is trainable-shaped for delta-style grad programs; strategies
        whose program uploads something else (FedKSeed's coefficients)
        materialize it here."""
        self._params, self.adapters, self.head = self.engine.commit(
            plan, self._params, self.adapters, self.head, new)

    # ------------------------------------------------- durable state (ckpt)
    def extra_state(self) -> dict:
        """Strategy-specific mutable state beyond params/adapters/head —
        subclasses with per-round host state (chainfed's stage machine,
        FedRA's layer-mask rng, C2A's hypernetwork) override this pair.
        Keep it cheap and serializable (``ckpt.io.save_state`` handles
        arrays, nested dicts/tuples and big ints)."""
        return {}

    def load_extra_state(self, state: dict) -> None:
        pass

    def state_dict(self) -> dict:
        """Everything a checkpoint needs to continue this strategy
        bit-identically: the full trainable surface (params / adapters /
        head), privacy machinery positions (RDP accountant, adaptive clip,
        secure-session counter), the last round loss (plateau schedulers
        read it), and subclass ``extra_state``."""
        s = {"params": self._params, "adapters": self.adapters,
             "extra": self.extra_state()}
        if self.head is not None:
            s["head"] = self.head
        if self._last_round_loss is not None:
            s["last_loss"] = jnp.asarray(self._last_round_loss)
        if self.dp is not None:
            s["dp"] = {"accountant": self.dp_accountant.to_state(),
                       "clip": float(getattr(self, "_dp_clip",
                                             self.dp.clip))}
        if self.secure is not None:
            s["secure_sessions"] = int(self._secure_sessions)
        if self.compression is not None:
            s["compress"] = {
                "residuals": {str(cid): r for cid, r
                              in self._compress_residuals.items()},
                "key": self._compress_key}
        return s

    def load_state_dict(self, s: dict) -> None:
        """Inverse of :meth:`state_dict`.  The strategy must already be
        *configured* like the checkpointed one (same arch/chain, DP/secure
        enabled the same way) — configuration is rebuilt from flags, only
        mutable state restores.  Sets ``_params`` directly: the ``params``
        property setter re-derives a fresh head, which would clobber the
        checkpointed one."""
        self._params = s["params"]
        self.adapters = s["adapters"]
        if self.head is not None:
            if "head" not in s:
                raise ValueError("checkpoint has no head but this strategy "
                                 "trains one — config mismatch")
            self.head = s["head"]
        if "last_loss" in s:
            self._last_round_loss = s["last_loss"]
        if self.dp is not None:
            if "dp" not in s:
                raise ValueError("strategy has DP enabled but the "
                                 "checkpoint was taken without it")
            from .privacy import RDPAccountant
            self.dp_accountant = RDPAccountant.from_state(
                s["dp"]["accountant"])
            self._dp_clip = float(s["dp"]["clip"])
        elif "dp" in s:
            raise ValueError("checkpoint carries DP state but DP is not "
                             "enabled on this strategy")
        if self.secure is not None:
            self._secure_sessions = int(s.get("secure_sessions", 0))
        if self.compression is not None:
            if "compress" not in s:
                raise ValueError("strategy has update compression enabled "
                                 "but the checkpoint was taken without it")
            cs = s["compress"]
            self._compress_residuals = {int(cid): r for cid, r
                                        in cs["residuals"].items()}
            self._compress_key = jnp.asarray(cs["key"])
        elif "compress" in s:
            raise ValueError("checkpoint carries compression residuals but "
                             "compression is not enabled on this strategy")
        self.load_extra_state(s.get("extra", {}))

    # ----------------------------------------------------- scheduler hooks
    def begin(self, sim):
        """One-off setup before any scheduling (FOAT boundary detection,
        warm starts).  The event-driven runtime calls this once at clock 0
        for every mode; the default is a no-op."""

    def begin_commit(self):
        """Bracket for one *server* commit that may span several plan
        groups (the event-driven runtime's buffered commits): strategies
        whose ``commit_trainable`` also does per-commit bookkeeping
        (chainfed's stage events) debounce it between ``begin_commit`` /
        ``end_commit`` so one server commit fires exactly one event,
        however many plan groups it aggregates.  Base: no-ops."""

    def end_commit(self):
        pass

    def staleness_weight(self, staleness: int) -> float:
        """Aggregation-weight discount for an update computed ``staleness``
        model versions before it is committed (FedBuff's polynomial decay:
        1/√(1+s)).  Multiplies the client's sample count inside the fused
        FedAvg tensordot; fresh updates (``staleness == 0``) keep weight 1.
        Strategies override for bespoke decay (or ``return 1.0`` to ignore
        staleness entirely)."""
        return float(1.0 / np.sqrt(1.0 + max(0, staleness)))

    # -------------------------------------------------- generic plan round
    def cohort_aggregate(self, plan: TrainablePlan):
        """In-graph aggregation override for the cohort step, or None for the
        default fused sample-weighted FedAvg.  A strategy with a bespoke
        host-side ``aggregate`` must either express it here (traceable over
        stacked ``(C, ...)`` deltas/masks — see FedRA) or fall back to
        ``sequential_round``."""
        return None

    def resolve_aggregate(self, plan: TrainablePlan):
        """The aggregation the engine (and the event-driven runtime's commit)
        actually runs for ``plan``, normalized to the 5-arg convention
        ``agg(trainable0, deltas, weights, masks, rng)``.  Resolution order:
        the strategy's bespoke ``cohort_aggregate``, else the registered
        ``aggregator`` (robust variants from ``repro.fed.faults``), with the
        DP clip+noise wrapper (``repro.fed.privacy``) applied outermost when
        DP is enabled.  Stable per plan — the engine caches the compiled
        step, so DP / aggregator selection must happen before the first
        round (the enable helpers enforce this)."""
        agg = self.cohort_aggregate(plan)
        if agg is None and self.aggregator != "fedavg":
            agg = make_aggregator(self.aggregator,
                                  **dict(self.aggregator_opts or {}))
        agg = as_rng_aggregate(agg)
        if self.dp is not None:
            from .privacy import make_private_aggregate
            agg = make_private_aggregate(self.dp, agg)
        return agg

    def apply_update(self, plan: TrainablePlan, trainable0, mean_update):
        """Server-side finalization of an aggregated *mean upload* — the
        secure-aggregation path's commit step (the server only ever holds
        the masked sum, so the usual fused ``aggregate`` never runs).
        Delta-style grad programs commit ``trainable0 + mean``; strategies
        whose clients upload something else (FedKSeed's seed coefficients)
        override."""
        return tree_map(lambda t0, m: (t0 + m.astype(jnp.float32)
                                       ).astype(t0.dtype),
                        trainable0, mean_update)

    def round(self, sim, clients, round_idx):
        """One federated round on the batched cohort path: group sampled
        clients by plan, run one jitted ``cohort_step`` per group, commit.
        Groups commit sequentially in first-seen plan order (in practice a
        round produces a single group — per-client variation lives in the
        runtime masks, not the plan)."""
        if not clients:
            return
        if (type(self).aggregate is not Strategy.aggregate
                and self.cohort_aggregate(self.plan(clients[0], round_idx))
                is None):
            # host-side aggregation with no in-graph counterpart
            return self.sequential_round(sim, clients, round_idx)
        groups = {}
        for c in clients:
            groups.setdefault(self.plan(c, round_idx), []).append(c)
        dp_rng = (jax.random.fold_in(self._dp_key, round_idx)
                  if self.dp is not None else None)
        for gi, (plan, cohort) in enumerate(groups.items()):
            # each group reads the *current* state: a donated trainable from
            # an earlier group's step must never be re-read, so later groups
            # see earlier commits (rounds have one group in practice)
            batches = sim.cohort_batches(cohort, self.chain.local_steps)
            masks = stack_masks([self.plan_masks(sim, c, round_idx)
                                 for c in cohort])
            weights = jnp.asarray([c.n_samples for c in cohort], jnp.float32)
            tr0 = self.init_trainable(plan)
            rng = (jax.random.fold_in(dp_rng, gi)
                   if dp_rng is not None else None)
            if self.secure is not None:
                if self.aggregator != "fedavg":
                    raise ValueError(
                        "secure aggregation only supports the linear fedavg "
                        f"mean; robust aggregator {self.aggregator!r} needs "
                        "plaintext per-client updates")
                # masked per-client uploads: the aggregation cannot fuse —
                # the server must see (and sum) each client's masked update
                from .privacy import secure_round
                updates, losses = self.engine.cohort_updates(plan)(
                    tr0, self._params, self.adapters, batches, masks)
                new = secure_round(self, plan, tr0, updates, weights,
                                   [c.cid for c in cohort], rng=rng)
                self._last_round_loss = jnp.mean(losses)
            elif self.dp is not None and self.dp.adaptive_clip:
                # adaptive clipping needs the observed update norms, which
                # the fused step never exposes — run the unaggregated wave
                # plus one cached jitted aggregate; the live bound rides in
                # as a traced (C,) mask entry, so it drifts with no
                # recompile
                from .privacy import current_clip, observe_update_norms
                updates, losses = self.engine.cohort_updates(plan)(
                    tr0, self._params, self.adapters, batches, masks)
                if plan not in self._adaptive_agg:
                    self._adaptive_agg[plan] = jax.jit(
                        self.resolve_aggregate(plan))
                clip_vec = jnp.full((len(cohort),), current_clip(self),
                                    jnp.float32)
                new = self._adaptive_agg[plan](
                    tr0, updates, weights, {**masks, "dp_clip": clip_vec},
                    rng)
                observe_update_norms(self, cohort_norms(updates))
                self._last_round_loss = jnp.mean(losses)
            elif self.compression is not None:
                # lossy compression needs per-client plaintext updates (and
                # error-feedback residuals keyed by cid) — unaggregated wave,
                # in-graph compress, then the cached jitted aggregate; fixed-
                # clip DP noise rides the aggregate *after* compression
                if _is_whole_client(plan):
                    raise ValueError(
                        f"update compression expects delta-style uploads; "
                        f"grad program {plan.grad!r} uploads a "
                        "program-defined payload (already compact)")
                updates, losses = self.engine.cohort_updates(plan)(
                    tr0, self._params, self.adapters, batches, masks)
                new = self._compressed_aggregate(plan, cohort, tr0, updates,
                                                 weights, masks, rng,
                                                 round_idx)
                self._last_round_loss = jnp.mean(losses)
            else:
                step = self.engine.cohort_step(plan,
                                               self.resolve_aggregate(plan))
                new, _loss = step(tr0, self._params, self.adapters, batches,
                                  masks, weights, rng)
                # device scalar, never blocked on here — convergence-driven
                # schedulers (chainfed plateau advance) read it lazily
                self._last_round_loss = _loss
            self.commit_trainable(plan, new)
        if self.dp is not None:
            self.dp_accountant.step(
                self.dp.noise_multiplier,
                q=len(clients) / max(1, sim.n_clients))

    def _compressed_aggregate(self, plan, cohort, tr0, updates, weights,
                              masks, rng, round_idx):
        """Compress the stacked ``(C, ...)`` updates (error feedback against
        the per-cid residual store), then run the cached jitted aggregation
        — the compression branch of :meth:`round`."""
        from .compress import make_compress_fn
        if plan not in self._compress_fn:
            self._compress_fn[plan] = jax.jit(
                make_compress_fn(self.compression))
        if plan not in self._adaptive_agg:   # same cache slot as adaptive
            self._adaptive_agg[plan] = jax.jit(   # clip (mutually exclusive)
                self.resolve_aggregate(plan))
        template = tree_map(lambda u: jnp.zeros(u.shape[1:], jnp.float32),
                            updates)
        tdef = jax.tree_util.tree_structure(template)

        def residual_for(cid):
            # a residual stored under an older plan (chainfed's window
            # advances reshape the trainable) is dropped, not reshaped —
            # error feedback restarts from zero on the new surface
            r = self._compress_residuals.get(cid)
            if r is None or jax.tree_util.tree_structure(r) != tdef:
                return template
            if any(a.shape != b.shape for a, b in zip(
                    jax.tree_util.tree_leaves(r),
                    jax.tree_util.tree_leaves(template))):
                return template
            return r

        residuals = tree_map(lambda *rs: jnp.stack(rs),
                             *[residual_for(c.cid) for c in cohort])
        crng = jax.random.fold_in(self._compress_key, round_idx)
        compressed, new_res = self._compress_fn[plan](updates, residuals,
                                                      crng)
        if self.compression.error_feedback:
            for i, c in enumerate(cohort):
                self._compress_residuals[c.cid] = tree_map(
                    lambda r: r[i], new_res)
        return self._adaptive_agg[plan](tr0, compressed, weights, masks, rng)

    def sequential_round(self, sim, clients, round_idx):
        """Legacy per-client dispatch loop: one jitted ``local_step`` call per
        client per local step (one ``client_update_fn`` call per client for
        whole-client grad programs), host-side update aggregation.  Kept as
        the benchmark baseline (``bench_round``) and the fallback for
        strategies whose server aggregation cannot be traced into the cohort
        step."""
        plans, all_masks, updates, weights = [], [], [], []
        for c in clients:
            plan = self.plan(c, round_idx)
            masks = self.plan_masks(sim, c, round_idx)
            tr0 = self.init_trainable(plan)
            if _is_whole_client(plan):
                raw = sim.client_batches(c, self.chain.local_steps)
                batches = {k: jnp.stack([jnp.asarray(b[k]) for b in raw])
                           for k in raw[0]}
                upd, _ = self.engine.client_update_fn(plan)(
                    tr0, self._params, self.adapters, batches, masks)
                updates.append(upd)
            else:
                step = self.engine.local_step(plan)
                tr, opt_state = tr0, self.engine.opt_for(plan).init(tr0)
                for i, batch in enumerate(
                        sim.client_batches(c, self.chain.local_steps)):
                    tr, opt_state, _, _ = step(tr, opt_state, self._params,
                                               self.adapters, batch,
                                               fold_step_masks(masks, i))
                updates.append(tree_map(lambda a, b: a - b, tr, tr0))
            plans.append(plan)
            all_masks.append(masks)
            weights.append(c.n_samples)
        self.aggregate(round_idx, plans, updates, weights, all_masks)

    def aggregate(self, round_idx, plans, deltas, weights, masks):
        """Weighted FedAvg of deltas, scattered back through the plan spec.
        Assumes all clients shared one spec this round (strategies with
        per-client specs override)."""
        if not deltas:
            return
        plan = plans[0]
        agg = self.engine.fedavg(deltas, weights)
        master = self.init_trainable(plan)
        new = tree_map(lambda a, d: (a + d).astype(a.dtype), master, agg)
        self.commit_trainable(plan, new)

    # ---------------------------------------------------------------- eval
    def evaluate(self, batch):
        loss, acc = self.engine.eval_fn()(self.eval_params(), self.adapters,
                                          batch)
        return float(loss), float(acc)

    def memory_kwargs(self, round_idx):
        return {}

    def base_comm_bytes(self) -> int:
        """Payload bytes a client uploads per round (adapter deltas, seed
        coefficients, ...).  Strategies with bespoke payloads override
        *this*, not ``comm_bytes_per_round``, so the privacy overhead
        composes uniformly."""
        return comm_bytes_per_round(self.cfg, self.memory_method)

    def privacy_comm_bytes(self) -> int:
        """Per-client per-round overhead of the enabled privacy machinery:
        secure-agg pairwise key agreement + recovery shares, DP metadata.
        Zero when neither is enabled."""
        if self.dp is None and self.secure is None:
            return 0
        from ..core.memory import privacy_comm_overhead
        cohort = self.secure.cohort if self.secure is not None else 0
        return privacy_comm_overhead(cohort, secure=self.secure is not None,
                                     dp=self.dp is not None)

    def comm_bytes_per_round(self) -> int:
        base = self.base_comm_bytes()
        if self.compression is not None:
            base = self.compression.compressed_bytes(base)
        return base + self.privacy_comm_bytes()
