"""Privacy subsystem: in-graph DP-FedAvg and secure-aggregation simulation.

Two independent mechanisms that compose with any registry strategy through
the ``PlanEngine``/``FedScheduler`` aggregation seams (no per-strategy code):

**DP-FedAvg** (`enable_dp`) — per-client update clipping and Gaussian noise
fused into the cohort aggregation.  ``make_private_aggregate`` wraps the
resolved 5-arg aggregation: clip every client's stacked ``(C, ...)`` update
to an L2 bound, force uniform weights (sample-count weighting would make the
per-client sensitivity data-dependent), aggregate, then add
``N(0, (σ·clip/C)²)`` per coordinate — all inside the jitted cohort step, so
the DP run compiles once like the clean run.  Noise keys are ``fold_in``'d
from the DP seed by round (and leaf), so a run is bit-reproducible from its
seed.  An `RDPAccountant` tracks the Rényi-DP curve of the subsampled
Gaussian mechanism and reports ``(ε, δ)`` per round in ``RoundMetrics``.

**Secure aggregation** (`enable_secure_agg`) — the Bonawitz-style masking
protocol simulated faithfully enough to test the systems questions: updates
are quantized to a fixed-point int32 field, every client pair derives an
additive mask from a shared seed (``fold_in`` of the session key by the
ordered pair), the lower-id client adds the mask and the higher-id client
subtracts it, and sums are taken with int32 wraparound so the masks cancel
**bit-exactly** in the server's sum.  The server only ever holds masked
per-client uploads.  When a masked client drops after dispatch, survivors
reconstruct the dropped client's pairwise masks from the shared seeds and
the server subtracts them — the round still commits (`SecureSession.
unmask_sum` with a non-empty dropped set).  With zero dropouts the
dequantized result equals plain FedAvg to quantization precision (~2⁻¹⁶).

DP composes with secure aggregation: clipping is client-side (before
masking), the noise is added server-side after unmasking — the central-DP
simulation of distributed noise.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..utils.tree import tree_map
from .strategies import cohort_norms, scale_cohort


# ==================================================== differential privacy
@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Client-level DP-FedAvg knobs.

    clip              per-client L2 bound on the uploaded update (the
                      *initial* bound when ``adaptive_clip`` is on)
    noise_multiplier  σ — noise std in units of the mean's sensitivity
                      (clip / cohort size)
    delta             target δ for the ε report
    seed              root of the fold_in'd per-round noise keys
    adaptive_clip     track the clip norm from observed update norms
                      (Andrew et al. 2021): after each commit the bound
                      moves geometrically toward the ``target_quantile`` of
                      the cohort's update-norm distribution,
                      ``C ← C · exp(−clip_lr · (b̄ − γ))`` with b̄ the
                      fraction of clients whose norm is ≤ C.  σ stays
                      fixed, so the RDP accounting is unchanged; the clip
                      rides into the jitted aggregate as a traced ``(C,)``
                      mask entry → no recompiles as it drifts.
    target_quantile   γ above
    clip_lr           η above
    """
    clip: float = 1.0
    noise_multiplier: float = 1.0
    delta: float = 1e-5
    seed: int = 0
    adaptive_clip: bool = False
    target_quantile: float = 0.5
    clip_lr: float = 0.2


def clip_cohort(deltas, clip: float):
    """Scale each client's ``(C, ...)`` update so its global L2 norm is at
    most ``clip`` (below-bound updates pass through unscaled)."""
    norms = cohort_norms(deltas)
    return scale_cohort(deltas, jnp.minimum(1.0, clip / (norms + 1e-12)))


def gaussian_noise_tree(rng, tree, std):
    """Per-leaf Gaussian noise from fold_in'd leaf keys (stable leaf order
    via tree flattening), matching each leaf's shape, float32."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(leaves)) if leaves else []
    noise = [std * jax.random.normal(k, l.shape, jnp.float32)
             for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, noise)


def make_private_aggregate(dp: DPConfig, base_agg):
    """Wrap a 5-arg aggregation with the DP mechanism: clip → uniform-weight
    aggregate → add ``N(0, (σ·clip/C)²)`` to every committed coordinate.
    Traceable — lives inside the jitted cohort step / commit.  When the
    caller injects a ``"dp_clip"`` entry into ``masks`` (a ``(C,)`` vector —
    (C,)-shaped so it survives the engine's per-client vmap), that traced
    value is the bound; otherwise the static ``dp.clip`` is baked in."""
    def agg(trainable0, deltas, weights, masks, rng):
        if isinstance(masks, dict) and "dp_clip" in masks:
            clip = masks["dp_clip"][0].astype(jnp.float32)
        else:
            clip = jnp.float32(dp.clip)
        clipped = clip_cohort(deltas, clip)
        # uniform weights: with sample-count weights the per-client
        # sensitivity of the mean would be w_i·clip/Σw — data-dependent
        uniform = jnp.ones_like(weights)
        new = base_agg(trainable0, clipped, uniform, masks, rng)
        cohort = weights.shape[0]
        std = dp.noise_multiplier * clip / cohort
        noise = gaussian_noise_tree(jax.random.fold_in(rng, 0x0D9), new, std)
        return tree_map(lambda x, n: (x.astype(jnp.float32) + n
                                      ).astype(x.dtype), new, noise)
    return agg


def current_clip(strategy) -> float:
    """The live clip bound: the tracked value under adaptive clipping,
    ``dp.clip`` otherwise."""
    return float(getattr(strategy, "_dp_clip", strategy.dp.clip))


def observe_update_norms(strategy, norms) -> float:
    """Adaptive-clip tracking step (Andrew et al. 2021, geometric form):
    fed the cohort's observed per-client update norms after a commit, move
    the bound toward the target quantile.  Host-side — the updated value
    enters the next commit as a traced mask entry, never a new constant.
    Returns the new clip."""
    dp = strategy.dp
    if dp is None or not dp.adaptive_clip:
        return current_clip(strategy)
    norms = np.asarray(jax.device_get(norms), np.float64).reshape(-1)
    if norms.size == 0:
        return strategy._dp_clip
    frac_below = float(np.mean(norms <= strategy._dp_clip))
    strategy._dp_clip = float(
        strategy._dp_clip
        * math.exp(-dp.clip_lr * (frac_below - dp.target_quantile)))
    return strategy._dp_clip


DEFAULT_RDP_ORDERS = tuple(range(2, 64)) + (80, 96, 128, 192, 256, 512)


def _log_binom(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def rdp_gaussian(alpha: int, noise_multiplier: float, q: float) -> float:
    """RDP of one step of the Poisson-subsampled Gaussian mechanism at
    integer order ``alpha``.  ``q >= 1`` is the unsubsampled closed form
    α/(2σ²); ``q < 1`` is the exact integer-order expansion (Mironov,
    Talwar & Zhang 2019, eq. 9):

        RDP(α) = log( Σ_{k=0}^{α} C(α,k) (1−q)^{α−k} q^k e^{k(k−1)/(2σ²)} )
                 / (α − 1)
    """
    if noise_multiplier <= 0:
        return float("inf")
    if q <= 0:
        return 0.0
    s2 = float(noise_multiplier) ** 2
    if q >= 1.0:
        return alpha / (2.0 * s2)
    terms = []
    for k in range(alpha + 1):
        t = _log_binom(alpha, k) + k * math.log(q) + k * (k - 1) / (2.0 * s2)
        if q < 1.0:
            t += (alpha - k) * math.log1p(-q)
        terms.append(t)
    m = max(terms)
    return (m + math.log(sum(math.exp(t - m) for t in terms))) / (alpha - 1)


class RDPAccountant:
    """Moments accountant over a fixed grid of integer Rényi orders.  Each
    server commit adds one mechanism invocation (`step`); `epsilon` converts
    the accumulated RDP curve to ``(ε, δ)`` via the standard bound
    ε = min_α [ RDP(α) + log(1/δ)/(α−1) ]."""

    def __init__(self, orders: Sequence[int] = DEFAULT_RDP_ORDERS):
        self.orders = tuple(int(a) for a in orders)
        self._rdp = np.zeros(len(self.orders))
        self.steps = 0

    def step(self, noise_multiplier: float, q: float = 1.0, steps: int = 1):
        self._rdp = self._rdp + steps * np.array(
            [rdp_gaussian(a, noise_multiplier, q) for a in self.orders])
        self.steps += steps

    def epsilon(self, delta: float) -> tuple:
        """Best ``(ε, order)`` over the grid at the given δ."""
        if self.steps == 0:
            return 0.0, self.orders[0]
        orders = np.array(self.orders, dtype=np.float64)
        eps = self._rdp + math.log(1.0 / delta) / (orders - 1.0)
        i = int(np.argmin(eps))
        return float(eps[i]), self.orders[i]

    def to_state(self) -> dict:
        """Serializable snapshot: the orders grid, the accumulated RDP
        curve, and the step counter — everything ε depends on."""
        return {"orders": list(self.orders),
                "rdp": [float(x) for x in self._rdp],
                "steps": int(self.steps)}

    @classmethod
    def from_state(cls, state: dict) -> "RDPAccountant":
        """Inverse of :meth:`to_state`; ε after restore equals ε of the
        uninterrupted accountant bit for bit."""
        acc = cls(tuple(int(a) for a in state["orders"]))
        acc._rdp = np.asarray(state["rdp"], np.float64)
        acc.steps = int(state["steps"])
        return acc


def enable_dp(strategy, dp: Optional[DPConfig] = None):
    """Attach client-level DP to a constructed strategy (post-construction:
    strategy ``__init__`` signatures are bespoke).  Must run before the
    first round — the engine caches compiled cohort steps per plan, and the
    DP wrapper has to be in the first trace."""
    dp = dp if dp is not None else DPConfig()
    if strategy.engine._cohort or strategy.engine._cohort_updates:
        raise RuntimeError(
            "enable_dp after cohort steps compiled: the cached aggregation "
            "would silently stay non-private — enable DP before training")
    strategy.dp = dp
    strategy._dp_key = jax.random.PRNGKey(dp.seed)
    strategy._dp_clip = float(dp.clip)
    strategy.dp_accountant = RDPAccountant()
    return strategy


# ====================================================== secure aggregation
@dataclasses.dataclass(frozen=True)
class SecureAggConfig:
    """Pairwise-masking simulation knobs.

    fixedpoint_bits  fractional bits of the int32 field encoding (quantized
                     value = round(x · 2^bits); masks cancel bit-exactly in
                     int32 wraparound sums)
    seed             root of the per-session mask keys
    cohort           roster size hint for the comm-overhead model
    """
    fixedpoint_bits: int = 16
    seed: int = 0
    cohort: int = 0


class SecureSession:
    """One masking session: the roster fixed at dispatch, pairwise mask
    seeds derived from the session key.  All arithmetic on the int32 field
    (wraparound = mod 2³²), so masking is exactly invertible."""

    def __init__(self, cfg: SecureAggConfig, key, cids: Sequence[int]):
        self.cfg = cfg
        self.key = key
        self.cids = tuple(cids)
        self._index = {cid: i for i, cid in enumerate(self.cids)}
        self._scale = float(2 ** cfg.fixedpoint_bits)

    # ------------------------------------------------------------- encoding
    def quantize(self, tree):
        return tree_map(
            lambda x: jnp.round(x.astype(jnp.float32) * self._scale
                                ).astype(jnp.int32), tree)

    def dequantize(self, tree):
        return tree_map(lambda x: x.astype(jnp.float32) / self._scale, tree)

    # ---------------------------------------------------------------- masks
    def _pair_mask(self, a: int, b: int, ref_tree):
        """The shared additive mask of the unordered pair (a, b): uniform
        int32 bits per leaf from the fold_in'd pair key.  Symmetric — both
        clients derive the identical tree."""
        i, j = sorted((self._index[a], self._index[b]))
        k = jax.random.fold_in(jax.random.fold_in(self.key, i), j)
        leaves, treedef = jax.tree_util.tree_flatten(ref_tree)
        keys = jax.random.split(k, len(leaves)) if leaves else []
        masks = [jax.lax.bitcast_convert_type(
                     jax.random.bits(kk, l.shape, jnp.uint32), jnp.int32)
                 for kk, l in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, masks)

    def _sign(self, a: int, b: int) -> int:
        """Lower roster index adds the pair mask, higher subtracts it."""
        return 1 if self._index[a] < self._index[b] else -1

    def mask_update(self, cid: int, float_tree):
        """What client ``cid`` uploads: its quantized update plus the signed
        sum of its pairwise masks (int32, wraps)."""
        out = self.quantize(float_tree)
        for other in self.cids:
            if other == cid:
                continue
            m = self._pair_mask(cid, other, float_tree)
            s = self._sign(cid, other)
            out = tree_map(lambda x, mm: x + s * mm, out, m)
        return out

    def unmask_sum(self, masked_trees, survivors: Sequence[int]):
        """Sum the survivors' masked uploads and remove the residual masks
        of dropped roster members (pairs among survivors cancel on their
        own).  Returns the int32 field sum — exactly the sum of the
        survivors' quantized updates, bit for bit."""
        total = masked_trees[0]
        for t in masked_trees[1:]:
            total = tree_map(lambda a, b: a + b, total, t)
        surv = set(survivors)
        dropped = [c for c in self.cids if c not in surv]
        for d in dropped:
            for s_cid in survivors:
                m = self._pair_mask(s_cid, d, total)
                s = self._sign(s_cid, d)
                total = tree_map(lambda x, mm: x - s * mm, total, m)
        return total


def _clip_single(tree, clip: float):
    batched = tree_map(lambda x: x[None], tree)
    return tree_map(lambda x: x[0], clip_cohort(batched, clip))


def _session_field_sum(strategy, session: "SecureSession", contributions,
                       wsum: float):
    """The unmasked int32 field sum of one session's survivors.  Each client
    pre-scales its (DP-clipped, when enabled) update by ``w_i/Σw`` before
    quantizing and masking, so the field sum *is* the weighted-mean
    contribution — no plaintext post-division.  Roster members missing from
    ``contributions`` are the dropped set; their reconstructed masks are
    removed inside ``unmask_sum``."""
    dp = strategy.dp
    masked = []
    for cid, u, w in contributions:
        if dp is not None:
            u, w = _clip_single(u, current_clip(strategy)), 1.0
        scaled = tree_map(lambda x: x.astype(jnp.float32) * (w / wsum), u)
        masked.append(session.mask_update(cid, scaled))
    return session.unmask_sum(masked, [c for c, _, _ in contributions])


def secure_commit(strategy, plan, trainable0, groups, rng=None):
    """Server-side secure commit over one or more masking sessions.

    ``groups`` — list of ``(session, contributions)`` where contributions is
    ``[(cid, update_tree, weight)]`` for that session's surviving roster
    members (weights already include any staleness discount; an event-driven
    commit can mix arrivals from several dispatch buckets, each with its own
    session).  With DP enabled, updates are clipped client-side (pre-mask),
    weights are forced uniform, and the Gaussian noise lands on the unmasked
    mean — the central-DP simulation of distributed noise."""
    dp = strategy.dp
    n_contrib = sum(len(c) for _, c in groups)
    if dp is not None:
        wsum = float(max(1, n_contrib))
        clip = current_clip(strategy)
        if dp.adaptive_clip and n_contrib:
            # client-side knowledge: each client reports its (plaintext)
            # update norm; the tracked bound moves after this commit so the
            # value clipping *this* commit stays the pre-observation one
            norms = [float(jnp.sqrt(sum(
                jnp.sum(jnp.square(x.astype(jnp.float32)))
                for x in jax.tree_util.tree_leaves(u))))
                for _, cs in groups for _, u, _ in cs]
        else:
            norms = None
    else:
        wsum = float(sum(w for _, cs in groups for _, _, w in cs)) or 1.0
        clip, norms = None, None
    total, ref = None, groups[0][0]
    for session, contribs in groups:
        if not contribs:
            continue    # every roster member dropped: no uploads arrived
        s = _session_field_sum(strategy, session, contribs, wsum)
        total = s if total is None else tree_map(lambda a, b: a + b, total, s)
    if total is None:
        return trainable0
    mean = ref.dequantize(total)
    if dp is not None:
        if rng is None:
            rng = jax.random.PRNGKey(0)
        std = dp.noise_multiplier * clip / max(1, n_contrib)
        noise = gaussian_noise_tree(jax.random.fold_in(rng, 0x0D9), mean,
                                    std)
        mean = tree_map(lambda x, n: x + n, mean, noise)
        if norms is not None:
            observe_update_norms(strategy, np.asarray(norms))
    return strategy.apply_update(plan, trainable0, mean)


def new_session(strategy, cids) -> "SecureSession":
    """A fresh masking session for a fixed roster — the dispatch-time key
    agreement.  Keys fold a per-strategy session counter, so replaying a
    run replays its masks."""
    strategy._secure_sessions += 1
    return SecureSession(
        strategy.secure,
        jax.random.fold_in(strategy._secure_key, strategy._secure_sessions),
        cids)


def secure_round(strategy, plan, trainable0, updates, weights, cids,
                 rng=None):
    """Sync-path secure aggregation of one full cohort (``updates`` stacked
    ``(C, ...)``): a fresh session whose roster is exactly the cohort —
    nobody drops on the lockstep path."""
    session = new_session(strategy, cids)
    w = np.asarray(jax.device_get(weights), np.float64)
    contributions = [
        (cid, tree_map(lambda x: x[i], updates), float(w[i]))
        for i, cid in enumerate(cids)]
    return secure_commit(strategy, plan, trainable0,
                         [(session, contributions)], rng=rng)


def enable_secure_agg(strategy, cfg: Optional[SecureAggConfig] = None):
    """Attach secure-aggregation simulation to a constructed strategy.
    Requires a linear weighted-mean aggregation (the server never sees
    plaintext per-client updates, so holder-normalized schemes like FedRA
    cannot run under masking — they set ``secure_compatible = False``)."""
    cfg = cfg if cfg is not None else SecureAggConfig()
    if not getattr(strategy, "secure_compatible", True):
        raise ValueError(
            f"strategy {strategy.name!r} aggregation is not a linear "
            "weighted mean of client uploads — secure aggregation cannot "
            "reproduce it from the masked sum")
    if strategy.aggregator != "fedavg":
        raise ValueError(
            "secure aggregation only supports the linear fedavg mean; "
            f"robust aggregator {strategy.aggregator!r} needs plaintext "
            "per-client updates")
    strategy.secure = cfg
    strategy._secure_key = jax.random.PRNGKey(cfg.seed)
    strategy._secure_sessions = 0
    return strategy
