"""Cohort-update compression with error feedback (ISSUE 10 tentpole §3).

Uplink bytes, not FLOPs, dominate a federated round for edge clients — the
communication-efficient PEFT line (arXiv:2404.06448) and the federated
fine-tuning survey (arXiv:2503.12016) both put update compression next to
optimizer-state memory as the binding client cost.  This module is the
strategy seam for it: two classic compressors over the *stacked* cohort-axis
updates (``(C, ...)`` leaves, straight out of ``PlanEngine.cohort_updates``),
composed with error feedback (Seide et al. 2014; Karimireddy et al. 2019) so
the bias a lossy compressor injects is carried in per-client residual state
and re-fed the next time the client is sampled — compressed SGD then
converges wherever its dense counterpart does.

* ``topk``   — per-client, per-leaf magnitude sparsification: keep the
  ``ratio`` fraction of largest-|x| entries, zero the rest.  Wire format is
  (index, value) pairs → 8 bytes per kept entry.
* ``qsgd``   — per-client, per-leaf absmax int8 *stochastic-rounding*
  quantization (QSGD, Alistarh et al. 2017): unbiased (the expectation over
  the rounding draw is the input), 1 byte per entry + one fp32 scale per
  leaf.

Both are applied *before* the fused FedAvg tensordot and simulated in-graph:
the aggregation consumes the dequantized/sparsified values, while
``comm_bytes_per_round`` reports the wire-format bytes
(:meth:`CompressionConfig.compressed_bytes`).

Attachment follows the ``enable_dp`` pattern (post-construction, refused
once cohort programs have compiled).  Composition rules:

* secure aggregation — **refused**: the server only ever sees a masked sum,
  so there is no per-client plaintext update to compress (compress-then-mask
  changes the field encoding; out of scope).
* adaptive-clip DP — **refused**: both paths own the unaggregated-wave +
  host-side-extras slot in ``Strategy.round``; fixed-clip DP composes fine
  (noise is added by the aggregation wrapper *after* compression, exactly
  the compress-then-privatize order the DP analysis assumes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..utils.tree import tree_map

QSGD_LEVELS = 127          # symmetric int8 grid


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Declarative compressor choice, hashable (keys jit caches).

    kind            "topk" | "qsgd"
    ratio           topk: fraction of entries kept per leaf (≥ 1 entry)
    bits            qsgd: quantization bits (8 is the only wired width —
                    the int8 grid matches the optimizer-state quantizer)
    error_feedback  carry the compression residual per client and add it
                    back before the next compression (EF-SGD)
    seed            root key for qsgd's stochastic rounding draws
    """
    kind: str = "topk"
    ratio: float = 0.05
    bits: int = 8
    error_feedback: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ("topk", "qsgd"):
            raise ValueError(f"unknown compressor {self.kind!r}")
        if self.kind == "topk" and not 0.0 < self.ratio <= 1.0:
            raise ValueError(f"topk ratio must be in (0, 1], got {self.ratio}")
        if self.kind == "qsgd" and self.bits != 8:
            raise ValueError("qsgd: only 8-bit quantization is wired")

    # ------------------------------------------------------ byte accounting
    def compressed_bytes(self, fp32_bytes: int) -> int:
        """Wire bytes for a payload that is ``fp32_bytes`` dense fp32:
        topk ships (int32 index, fp32 value) pairs for the kept fraction;
        qsgd ships one byte per entry plus a per-leaf scale (amortized into
        the entry count: one fp32 per 2¹⁵ entries rounds to zero here)."""
        n = fp32_bytes // 4
        if self.kind == "topk":
            return max(1, int(n * self.ratio)) * 8
        return n * self.bits // 8 + 4


def _topk_leaf(x, ratio):
    """Keep the top-``ratio`` fraction of |x| per client row (axis 0 is the
    cohort axis), zero the rest.  Threshold via ``lax.top_k`` on the
    flattened magnitudes — ties at the threshold all survive, the wire
    format still budgets exactly k entries."""
    C = x.shape[0]
    flat = jnp.abs(x.reshape(C, -1))
    k = max(1, int(flat.shape[1] * ratio))
    kth = jax.lax.top_k(flat, k)[0][:, -1]          # (C,)
    keep = flat >= kth[:, None]
    return (x.reshape(C, -1) * keep).reshape(x.shape)


def _qsgd_leaf(x, key):
    """Unbiased absmax int8 stochastic rounding per client row: the value
    grid is ``scale · {-127..127}`` and ``floor(y + u)`` with ``u~U[0,1)``
    rounds up with probability equal to the fractional part."""
    C = x.shape[0]
    flat = x.reshape(C, -1)
    scale = jnp.max(jnp.abs(flat), axis=1) / QSGD_LEVELS        # (C,)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    y = flat * inv[:, None]
    u = jax.random.uniform(key, flat.shape)
    q = jnp.clip(jnp.floor(y + u), -QSGD_LEVELS, QSGD_LEVELS)
    return (q * scale[:, None]).reshape(x.shape)


def make_compress_fn(config: CompressionConfig):
    """``fn(updates, residuals, rng) -> (compressed, new_residuals)`` over
    stacked ``(C, ...)`` update trees — traceable, jitted once per plan by
    the strategy.  With error feedback the compressor sees
    ``carried = update + residual`` and the new residual is
    ``carried - compressed``; without, residuals pass through as zeros."""

    def fn(updates, residuals, rng):
        if config.error_feedback:
            carried = tree_map(
                lambda u, r: u.astype(jnp.float32) + r, updates, residuals)
        else:
            carried = tree_map(lambda u: u.astype(jnp.float32), updates)
        if config.kind == "topk":
            compressed = tree_map(
                lambda x: _topk_leaf(x, config.ratio), carried)
        else:
            leaves, treedef = jax.tree_util.tree_flatten(carried)
            keys = jax.random.split(rng, len(leaves))
            compressed = jax.tree_util.tree_unflatten(
                treedef, [_qsgd_leaf(x, k) for x, k in zip(leaves, keys)])
        if config.error_feedback:
            new_res = tree_map(lambda c, q: c - q, carried, compressed)
        else:
            new_res = residuals
        return compressed, new_res

    return fn


# ================================================================ attachment
def enable_compression(strategy, config: Optional[CompressionConfig] = None):
    """Attach update compression to a constructed strategy (the
    ``enable_dp`` pattern — bespoke ``__init__`` signatures make a
    constructor kwarg impractical).  Must run before the first round: the
    compression branch of ``Strategy.round`` dispatches through
    ``cohort_updates`` instead of the fused ``cohort_step``, so a cached
    uncompressed step would silently keep winning."""
    config = config if config is not None else CompressionConfig()
    if strategy.engine._cohort or strategy.engine._cohort_updates:
        raise RuntimeError(
            "enable_compression after cohort steps compiled: cached "
            "programs would silently bypass the compressor — enable "
            "compression before training")
    if strategy.secure is not None:
        raise ValueError(
            "update compression and secure aggregation are mutually "
            "exclusive: the server never sees per-client plaintext updates "
            "under masking, so there is nothing to compress server-side")
    if strategy.dp is not None and strategy.dp.adaptive_clip:
        raise ValueError(
            "update compression with adaptive-clip DP is not wired (both "
            "own the unaggregated-wave slot of Strategy.round); use a "
            "fixed clip")
    strategy.compression = config
    strategy._compress_residuals = {}          # cid → residual tree (host)
    strategy._compress_key = jax.random.PRNGKey(config.seed)
    strategy._compress_fn = {}                 # plan → jitted compress fn
    return strategy
