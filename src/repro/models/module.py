"""Minimal functional module layer: params are nested dicts, layers are
(init, apply) function pairs.  No flax in the environment — this is the
framework's own substrate, kept deliberately small and fully tested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- initializers
def normal_init(key, shape, dtype, stddev=0.02):
    return (stddev * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


def lecun_init(key, shape, dtype):
    fan_in = shape[0] if len(shape) >= 1 else 1
    return (jax.random.normal(key, shape) / jnp.sqrt(jnp.maximum(fan_in, 1))).astype(dtype)


# ---------------------------------------------------------------- dense
def dense_init(key, d_in, d_out, dtype, bias=False, init=normal_init):
    p = {"w": init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------- norms
def norm_init(_key, d, dtype, kind="rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y + 0.0  # keep float32 until scale
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- embedding
def embed_init(key, vocab, d, dtype):
    return {"table": normal_init(key, (vocab, d), dtype, stddev=0.02)}


def embed(p, ids, compute_dtype=None):
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


def unembed(p, x, vocab_size=None):
    """Tied readout.  Masks padded vocab rows to -inf."""
    logits = x @ p["table"].astype(x.dtype).T
    if vocab_size is not None and vocab_size < p["table"].shape[0]:
        pad = p["table"].shape[0] - vocab_size
        mask = jnp.concatenate([jnp.zeros((vocab_size,), logits.dtype),
                                jnp.full((pad,), -1e9, logits.dtype)])
        logits = logits + mask
    return logits


# ---------------------------------------------------------------- rope
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    ang = ang[..., None, :]                            # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta=10000.0):
    """Qwen2-VL M-RoPE [arXiv:2409.12191]: three position streams (temporal,
    height, width) rotate disjoint frequency sections of each head.

    x: (..., S, H, hd); positions3: (3, ..., S); sections: per-axis counts of
    frequency pairs, sum(sections) == hd // 2.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    assert sum(sections) == hd // 2, (sections, hd)
    # pick which position axis drives each frequency pair
    sel = jnp.concatenate([jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    pos = jnp.take_along_axis(
        jnp.moveaxis(positions3, 0, -1),               # (..., S, 3)
        jnp.broadcast_to(sel, positions3.shape[1:] + (hd // 2,)), axis=-1)
    ang = pos.astype(jnp.float32) * freqs              # (..., S, hd/2)
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}
