"""Model assembly: scan-over-layers stacks for all families, with four entry
points —

* ``forward_full``   : end-to-end logits (Full Adapters† baseline, eval)
* ``forward_chain``  : CHAINFED's staged forward — frozen prefix → DLCT
                       window → local head + GPO auxiliary branch
* ``prefill``        : full-sequence forward building the decode cache
* ``decode_step``    : one-token cached decode (serve path)

plus ``collect_layer_outputs`` for FOAT's CKA profiling.
Base params and adapters are separate pytrees; adapters are stacked (L, ...)
so the chain can slice them with static bounds.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.adapters import (AUX, FROZEN, TRAIN, ActiveAdapters,
                             adapter_apply, adapter_apply_routed,
                             adapter_chain_apply, adapter_stack_init)
from ..sharding.hooks import constrain_logits, constrain_residual
from .blocks import (block_apply, block_cache_init, block_decode,
                     block_decode_paged, block_init, block_prefill)
from .config import ModelConfig
from .module import apply_norm, embed, embed_init, norm_init, unembed
from .attention import default_positions

ZERO = jnp.float32(0.0)

# Dry-run cost-accounting mode: XLA's cost_analysis counts while-loop bodies
# ONCE, so roofline FLOPs/bytes/collectives would be ~L× under-counted with
# scan-over-layers.  Setting UNROLL_SCANS=True (repro.models.set_unroll)
# unrolls every structural scan so the compiled HLO carries the true totals.
UNROLL_SCANS = False


def set_unroll(flag: bool):
    global UNROLL_SCANS
    UNROLL_SCANS = bool(flag)


def _unroll():
    return True if UNROLL_SCANS else 1


@dataclasses.dataclass(frozen=True)
class ChainSegments:
    """Static chain-stage geometry: layers [0, prefix) are frozen context,
    [prefix, prefix+window) is the DLCT co-tuning window, the rest feeds the
    GPO auxiliary branch."""
    prefix: int
    window: int

    def clip(self, n_layers: int) -> "ChainSegments":
        p = max(0, min(self.prefix, n_layers - 1))
        w = max(1, min(self.window, n_layers - p))
        return ChainSegments(p, w)


# =================================================================== init
def _stack_init(key, cfg: ModelConfig, kind: str, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(k, cfg, kind))(keys)


def _kinds(cfg: ModelConfig):
    if cfg.family == "encdec":
        return "enc", "xdec"
    k = {"dense": "dense", "vlm": "dense", "moe": "moe", "ssm": "ssm",
         "hybrid": "hybrid"}[cfg.family]
    return None, k


def init_lm(key, cfg: ModelConfig):
    k_emb, k_enc, k_dec, k_nrm = jax.random.split(key, 4)
    params = {"embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model, cfg.pdtype()),
              "final_norm": norm_init(k_nrm, cfg.d_model, cfg.pdtype(), cfg.norm)}
    enc_kind, dec_kind = _kinds(cfg)
    if cfg.is_encdec:
        params["enc_layers"] = _stack_init(k_enc, cfg, enc_kind, cfg.n_encoder_layers)
        params["enc_norm"] = norm_init(k_nrm, cfg.d_model, cfg.pdtype(), cfg.norm)
    params["layers"] = _stack_init(k_dec, cfg, dec_kind, cfg.n_layers)
    return params


def init_adapters(key, cfg: ModelConfig):
    return adapter_stack_init(key, cfg, cfg.total_chain_layers)


# =================================================================== embed
def embed_inputs(params, batch, cfg: ModelConfig):
    """Returns (x, positions).  Audio/VLM frontends are stubbed per spec:
    ``embeds`` are precomputed frame/patch embeddings of the right shape."""
    if "embeds" in batch:
        x = batch["embeds"].astype(cfg.cdtype())
    else:
        x = embed(params["embed"], batch["tokens"], cfg.cdtype())
    B, S = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, B, S)
    return x, positions


def head(params, x, cfg: ModelConfig):
    """Readout: tied embedding by default; a trainable task head (``cls_head``,
    (d, V)) overrides it when present — classification fine-tuning trains the
    output layer in every method (paper Fig. 4 'output layer')."""
    h = apply_norm(params["final_norm"], x, cfg.norm)
    if "cls_head" in params:
        logits = h @ params["cls_head"]["w"].astype(h.dtype)
        V = params["cls_head"]["w"].shape[1]
        if cfg.vocab_size < V:
            mask = jnp.concatenate([jnp.zeros((cfg.vocab_size,), logits.dtype),
                                    jnp.full((V - cfg.vocab_size,), -1e9,
                                             logits.dtype)])
            logits = logits + mask
        return constrain_logits(logits)
    return constrain_logits(unembed(params["embed"], h, cfg.vocab_size))


def init_cls_head(params):
    """Task head initialized from the (pretrained) tied embedding — identical
    logits at step 0, trainable thereafter."""
    return {"w": params["embed"]["table"].T.copy()}


# =================================================================== scans
def _scan_layers(stack, adapters, x, cfg: ModelConfig, kind, positions,
                 enc_out=None, remat=False, mode=None, collect=False):
    """Scan a (possibly empty) stacked segment; adapters may be None."""
    n = jax.tree_util.tree_leaves(stack)[0].shape[0]
    if n == 0:
        return x, (ZERO, ZERO), None

    def body(carry, xs):
        h, lb, rz = carry
        lp, ap = xs
        h, aux = block_apply(lp, h, cfg, kind, positions=positions,
                             enc_out=enc_out, mode=mode)
        h = adapter_apply(ap, h, cfg)
        h = constrain_residual(h)
        # FOAT profiles *pooled* per-layer features (B, d): CKA treats the
        # batch as the sample dimension, which also keeps collection O(L·B·d)
        out = h.mean(axis=1) if collect else None
        return (h, lb + aux["load_balance"], rz + aux["router_z"]), out

    if remat:
        body = jax.checkpoint(body)
    (x, lb, rz), ys = jax.lax.scan(body, (x, ZERO, ZERO), (stack, adapters),
                                   unroll=_unroll())
    return x, (lb, rz), ys


def _require_adapters(adapters):
    assert adapters is not None, "all stacks carry adapters in this framework"


# =================================================================== full fwd
def forward_full(params, adapters, batch, cfg: ModelConfig, remat=True,
                 collect=False):
    """End-to-end forward with every adapter active.  Returns (logits, aux)
    or (logits, aux, layer_outputs[L+1, B, S, d]) when collect=True."""
    _require_adapters(adapters)
    x, positions = embed_inputs(params, batch, cfg)
    enc_kind, dec_kind = _kinds(cfg)
    E = cfg.n_encoder_layers
    enc_out = None
    outs = []
    lb = rz = ZERO
    if cfg.is_encdec:
        xe, _ = _enc_embed(params, batch, cfg)
        spec = encdec_spec(cfg)
        enc_ad = spec.select(adapters, "encoder")
        xe, (lb1, rz1), ys = _scan_layers(params["enc_layers"], enc_ad, xe, cfg,
                                          enc_kind, None, remat=remat,
                                          mode="bidir", collect=collect)
        enc_out = apply_norm(params["enc_norm"], xe, cfg.norm)
        lb, rz = lb + lb1, rz + rz1
        if collect:
            outs.append(ys)
        dec_ad = spec.select(adapters, "decoder")
    else:
        dec_ad = adapters
    x, (lb2, rz2), ys = _scan_layers(params["layers"], dec_ad, x, cfg, dec_kind,
                                     positions, enc_out=enc_out, remat=remat,
                                     collect=collect)
    lb, rz = lb + lb2, rz + rz2
    logits = head(params, x, cfg)
    aux = {"load_balance": lb, "router_z": rz}
    if collect:
        outs.append(ys)
        return logits, aux, jnp.concatenate([o for o in outs if o is not None], axis=0)
    return logits, aux


def _enc_embed(params, batch, cfg: ModelConfig):
    if "enc_embeds" in batch:
        return batch["enc_embeds"].astype(cfg.cdtype()), None
    return embed(params["embed"], batch["enc_tokens"], cfg.cdtype()), None


def _slice(tree, a, b):
    return jax.tree_util.tree_map(lambda x: x[a:b], tree)


def encdec_spec(cfg: ModelConfig) -> ActiveAdapters:
    """Named encoder/decoder split of the concatenated adapter stack."""
    from ..core.adapters import AdapterSegment
    E, D = cfg.n_encoder_layers, cfg.n_layers
    return ActiveAdapters(E + D, (AdapterSegment("encoder", 0, E, TRAIN),
                                  AdapterSegment("decoder", E, E + D, TRAIN)))


# =================================================================== chain fwd
def forward_chain(params, window_adapters, frozen_adapters, batch,
                  cfg: ModelConfig, seg: ChainSegments, remat=True,
                  loss_ctx=None):
    """CHAINFED staged forward (paper §4).

    ``window_adapters`` — stacked (Q, ...) trainable adapters (the DLCT window).
    ``frozen_adapters`` — the full (L, ...) stack *as constants*; prefix and
    suffix segments are read from it (stop-gradient semantics come from taking
    grads only w.r.t. ``window_adapters``).

    Returns {"local_logits", "global_logits", "aux"}.
    Suffix base layers are never executed: the GPO auxiliary branch applies
    only the suffix adapters + final output layer.
    """
    if cfg.is_encdec:
        assert loss_ctx is None, "sequential GPO: single-stack models only"
        return _forward_chain_encdec(params, window_adapters, frozen_adapters,
                                     batch, cfg, seg, remat)
    L = cfg.n_layers
    seg = seg.clip(L)
    k, Q = seg.prefix, seg.window
    spec = ActiveAdapters.window(L, k, Q)
    x, positions = embed_inputs(params, batch, cfg)
    _, kind = _kinds(cfg)

    # frozen prefix: inference mode, activations never saved for backward
    pre_layers = _slice(params["layers"], 0, k)
    pre_ad = spec.select(frozen_adapters, "prefix")
    x, (lb0, rz0), _ = _scan_layers(pre_layers, pre_ad, x, cfg, kind, positions,
                                    remat=False)
    x = jax.lax.stop_gradient(x)

    # DLCT window: the only segment holding gradients / optimizer state
    win_layers = _slice(params["layers"], k, k + Q)
    x, (lb1, rz1), _ = _scan_layers(win_layers, window_adapters, x, cfg, kind,
                                    positions, remat=remat)

    aux = {"load_balance": lb0 + lb1, "router_z": rz0 + rz1}
    suf_ad = spec.select(frozen_adapters, "suffix")

    if loss_ctx is not None:
        # §Perf lever (GPO_SEQUENTIAL): the dual objective normally keeps BOTH
        # vocab-sized logits tensors (+f32 softmax temps) live for backward —
        # dominant for big-vocab models.  Checkpointing each CE branch holds
        # only the (B,S,d) window output; logits are recomputed per branch.
        from ..train.losses import cross_entropy
        labels, lam, final = loss_ctx

        @jax.checkpoint
        def local_branch(xw):
            return cross_entropy(head(params, xw, cfg), labels)

        @jax.checkpoint
        def global_branch(xw):
            xa = adapter_chain_apply(suf_ad, xw, cfg)
            return cross_entropy(head(params, xa, cfg), labels)

        local = local_branch(x)
        if final:
            return {"loss": local, "local": local, "global": local, "aux": aux}
        glob = global_branch(x)
        return {"loss": local + lam * glob, "local": local, "global": glob,
                "aux": aux}

    local_logits = head(params, x, cfg)

    # GPO auxiliary branch: suffix adapters as low-rank layer approximations
    xa = adapter_chain_apply(suf_ad, x, cfg)
    global_logits = head(params, xa, cfg)

    return {"local_logits": local_logits, "global_logits": global_logits,
            "aux": aux}


def _forward_chain_encdec(params, window_adapters, frozen_adapters, batch,
                          cfg: ModelConfig, seg: ChainSegments, remat=True):
    """Chain over the concatenated [encoder ‖ decoder] layer list.  The stage
    scheduler guarantees the window never straddles the enc/dec boundary."""
    from ..core.adapters import AdapterSegment
    E, D = cfg.n_encoder_layers, cfg.n_layers
    k, Q = seg.prefix, seg.window
    if k < E and k + Q > E:   # snap straddling windows to the decoder start
        k = E
    Q = min(Q, E + D - k)
    xd, positions = embed_inputs(params, batch, cfg)
    xe, _ = _enc_embed(params, batch, cfg)

    if k + Q <= E:  # ---- window inside the encoder
        spec = ActiveAdapters(E + D, (
            AdapterSegment("prefix", 0, k, FROZEN),
            AdapterSegment("window", k, k + Q, TRAIN),
            AdapterSegment("suffix", k + Q, E, AUX),
            AdapterSegment("decoder", E, E + D, AUX)))
        pre = _slice(params["enc_layers"], 0, k)
        xe, _, _ = _scan_layers(pre, spec.select(frozen_adapters, "prefix"),
                                xe, cfg, "enc", None, mode="bidir")
        xe = jax.lax.stop_gradient(xe)
        win = _slice(params["enc_layers"], k, k + Q)
        xe, (lb, rz), _ = _scan_layers(win, window_adapters, xe, cfg, "enc",
                                       None, mode="bidir", remat=remat)
        # cross-modal GPO bridge (DESIGN §6): pooled encoder state injected
        # into the decoder token stream; no downstream base layer executes.
        pool = jnp.mean(xe, axis=1, keepdims=True)
        local_logits = head(params, jax.lax.stop_gradient(xd) + pool, cfg)
        xs = adapter_chain_apply(spec.select(frozen_adapters, "suffix"), xe, cfg)
        pool_g = jnp.mean(xs, axis=1, keepdims=True)
        dec_ad = spec.select(frozen_adapters, "decoder")
        xg = adapter_chain_apply(dec_ad, jax.lax.stop_gradient(xd) + pool_g, cfg)
        global_logits = head(params, xg, cfg)
        return {"local_logits": local_logits, "global_logits": global_logits,
                "aux": {"load_balance": lb, "router_z": rz}}

    # ---- window inside the decoder: full frozen encoder provides cross-attn
    kd = k - E
    spec = ActiveAdapters(E + D, (
        AdapterSegment("encoder", 0, E, FROZEN),
        AdapterSegment("prefix", E, E + kd, FROZEN),
        AdapterSegment("window", k, k + Q, TRAIN),
        AdapterSegment("suffix", k + Q, E + D, AUX)))
    xe, _, _ = _scan_layers(params["enc_layers"],
                            spec.select(frozen_adapters, "encoder"), xe, cfg,
                            "enc", None, mode="bidir")
    enc_out = jax.lax.stop_gradient(apply_norm(params["enc_norm"], xe, cfg.norm))
    pre = _slice(params["layers"], 0, kd)
    xd, _, _ = _scan_layers(pre, spec.select(frozen_adapters, "prefix"), xd,
                            cfg, "xdec", positions, enc_out=enc_out)
    xd = jax.lax.stop_gradient(xd)
    win = _slice(params["layers"], kd, kd + Q)
    xd, (lb, rz), _ = _scan_layers(win, window_adapters, xd, cfg, "xdec",
                                   positions, enc_out=enc_out, remat=remat)
    local_logits = head(params, xd, cfg)
    xa = adapter_chain_apply(spec.select(frozen_adapters, "suffix"), xd, cfg)
    global_logits = head(params, xa, cfg)
    return {"local_logits": local_logits, "global_logits": global_logits,
            "aux": {"load_balance": lb, "router_z": rz}}


# =================================================================== FOAT
def collect_layer_outputs(params, adapters, batch, cfg: ModelConfig):
    """(L+1, B, d): pooled embedding output followed by every layer's pooled
    output — FOAT computes CKA(Z_i, Z_0) from these (paper §4.4, Fig. 7)."""
    x, _ = embed_inputs(params, batch, cfg)
    logits, aux, ys = forward_full(params, adapters, batch, cfg, remat=False,
                                   collect=True)
    if cfg.is_encdec:
        xe, _ = _enc_embed(params, batch, cfg)
        # chain order: encoder first — prepend the *encoder* embedding as Z_0
        return jnp.concatenate([xe.mean(axis=1)[None], ys], axis=0)
    return jnp.concatenate([x.mean(axis=1)[None], ys], axis=0)


# =================================================================== serving
def prefill(params, adapters, batch, cfg: ModelConfig, max_len=None,
            tenant_ids=None):
    """Full-sequence forward building the decode cache.
    Returns (last_logits (B, V), cache, n_prefilled).

    ``tenant_ids`` (B,) switches multi-tenant routing on: ``adapters`` is
    then a tenant library in scan layout ``(L, T, ...)``
    (``AdapterLibrary.stacked_scan()``) — the layer scan consumes one
    ``(T, ...)`` slab per step and ``adapter_apply_routed`` gathers each
    batch row's tenant inside the compiled program.  Tenant ids stay traced
    data: a mixed-tenant batch runs the exact program a single-tenant batch
    compiled."""
    _require_adapters(adapters)
    x, positions = embed_inputs(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    enc_kind, dec_kind = _kinds(cfg)
    enc_out = None
    if tenant_ids is not None:
        assert not cfg.is_encdec, "multi-tenant serving: single-stack models"
        assert tenant_ids.ndim == 1, "tenant_ids: (B,) int32"
        dec_ad = adapters
    elif cfg.is_encdec:
        xe, _ = _enc_embed(params, batch, cfg)
        spec = encdec_spec(cfg)
        xe, _, _ = _scan_layers(params["enc_layers"],
                                spec.select(adapters, "encoder"), xe, cfg,
                                enc_kind, None, mode="bidir")
        enc_out = apply_norm(params["enc_norm"], xe, cfg.norm)
        dec_ad = spec.select(adapters, "decoder")
    else:
        dec_ad = adapters

    def body(carry, xs):
        h = carry
        lp, ap = xs
        h, cache = block_prefill(lp, h, cfg, dec_kind, positions=positions,
                                 enc_out=enc_out)
        if tenant_ids is not None:
            h = adapter_apply_routed(ap, h, tenant_ids, cfg)
        else:
            h = adapter_apply(ap, h, cfg)
        return h, cache

    x, cache = jax.lax.scan(body, x, (params["layers"], dec_ad),
                            unroll=_unroll())
    logits = head(params, x[:, -1:, :], cfg)[:, 0]
    return logits, cache, S


def init_cache(cfg: ModelConfig, batch, max_len, enc_len=None):
    """Stacked (L, ...) decode cache."""
    _, kind = _kinds(cfg)
    one = block_cache_init(cfg, kind, batch, max_len, enc_len=enc_len)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)


def init_paged_cache(cfg: ModelConfig, slots, n_pages, page_size):
    """Paged serve cache (ISSUE 9): ``{"kv", "state"}`` where ``kv`` is the
    stacked ``(L, n_pages, page_size, KV, hd)`` page pool (empty for
    attention-free families) and ``state`` holds the per-slot leaves that
    have no sequence axis (SSM conv/h), stacked ``(L, slots, ...)`` exactly
    like the dense cache.  Page lists (``core.paging.PageTable``) decide
    which pool pages belong to which slot — the shapes here never depend on
    request lengths or admission order."""
    from .blocks import init_paged_kv_pool
    from .ssm import init_ssm_cache
    _, kind = _kinds(cfg)
    assert kind in ("dense", "moe", "ssm", "hybrid"), \
        f"paged serving: single-stack decoder families only, got {kind!r}"
    L = cfg.n_layers

    def stack(one):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), one)

    kv = {} if kind == "ssm" else stack(
        init_paged_kv_pool(cfg, n_pages, page_size))
    state = stack(init_ssm_cache(cfg, slots)) if kind in ("ssm", "hybrid") \
        else {}
    return {"kv": kv, "state": state}


def decode_step_paged(params, adapters, token, cache, pages, idx,
                      cfg: ModelConfig, tenant_ids=None):
    """One decode step over the paged KV cache (``init_paged_cache``).

    ``pages`` (B, max_pages) int32 — per-row page lists (traced data:
    admission/drain/prefix-sharing never recompile); ``idx`` (B,) per-row
    decode depths, parked rows at ``idx >= max_pages·page_size``.  Tenant
    routing is identical to ``decode_step``.  Returns
    (logits (B, V), cache, idx + 1).
    """
    _require_adapters(adapters)
    assert not cfg.is_encdec, "paged serving: single-stack models"
    x = embed(params["embed"], token, cfg.cdtype())
    _, kind = _kinds(cfg)
    if tenant_ids is not None:
        assert tenant_ids.ndim == 1, "tenant_ids: (B,) int32"

    def body(carry, xs):
        h = carry
        lp, ap, kvc, st = xs
        h, kvc, st = block_decode_paged(lp, h, kvc, st, pages, idx, cfg,
                                        kind)
        if tenant_ids is not None:
            h = adapter_apply_routed(ap, h, tenant_ids, cfg)
        else:
            h = adapter_apply(ap, h, cfg)
        return h, (kvc, st)

    x, (kv, state) = jax.lax.scan(
        body, x, (params["layers"], adapters, cache["kv"], cache["state"]),
        unroll=_unroll())
    logits = head(params, x, cfg)[:, 0]
    return logits, {"kv": kv, "state": state}, idx + 1


def decode_step(params, adapters, token, cache, idx, cfg: ModelConfig,
                enc_len=None, embeds=None, tenant_ids=None):
    """One greedy decode step.

    token: (B, 1) int32 (or ``embeds`` (B,1,d) for stub-frontend archs);
    cache: stacked (L, ...); idx: count of cached tokens — scalar, or (B,)
    when slots decode at different depths (continuous batching).
    ``tenant_ids`` (B,) routes each row through its own tenant's adapter
    stack (``adapters`` is then the library's scan-layout (L, T, ...)
    pytree, ``AdapterLibrary.stacked_scan()``).
    Returns (logits (B, V), cache, idx+1).
    """
    _require_adapters(adapters)
    if embeds is not None:
        x = embeds.astype(cfg.cdtype())
    else:
        x = embed(params["embed"], token, cfg.cdtype())
    _, kind = _kinds(cfg)
    if tenant_ids is not None:
        assert not cfg.is_encdec, "multi-tenant serving: single-stack models"
        assert tenant_ids.ndim == 1, "tenant_ids: (B,) int32"
        dec_ad = adapters
    else:
        dec_ad = (encdec_spec(cfg).select(adapters, "decoder")
                  if cfg.is_encdec else adapters)

    def body(carry, xs):
        h = carry
        lp, ap, cc = xs
        h, cc = block_decode(lp, h, cc, idx, cfg, kind, enc_len=enc_len)
        if tenant_ids is not None:
            h = adapter_apply_routed(ap, h, tenant_ids, cfg)
        else:
            h = adapter_apply(ap, h, cfg)
        return h, cc

    x, cache = jax.lax.scan(body, x, (params["layers"], dec_ad, cache),
                            unroll=_unroll())
    logits = head(params, x, cfg)[:, 0]
    return logits, cache, idx + 1
