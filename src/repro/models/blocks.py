"""Transformer blocks for every assigned family.

kinds: dense (incl. vlm/M-RoPE via cfg), moe, ssm (FalconMamba), hybrid
(Hymba parallel attn+SSM heads), enc (bidirectional), xdec (decoder with
cross-attention).  Each kind provides init / apply (full-seq) / prefill /
decode so the same stack drives training, prefill and cached decoding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attention, attention_decode, attention_decode_paged,
                        attention_prefill, init_kv_cache, init_paged_kv_pool)
from .config import ModelConfig
from .mlp import mlp, mlp_init, moe, moe_init
from .module import apply_norm, norm_init
from .ssm import init_ssm_cache, mamba, mamba_decode, mamba_init
from .attention import attn_init

ZERO_AUX = {"load_balance": jnp.float32(0.0), "router_z": jnp.float32(0.0)}


def block_kind(cfg: ModelConfig) -> str:
    return {"dense": "dense", "vlm": "dense", "moe": "moe", "ssm": "ssm",
            "hybrid": "hybrid"}[cfg.family] if cfg.family != "encdec" else "xdec"


# ------------------------------------------------------------------ init
def block_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 6)
    dt = cfg.pdtype()
    p = {"norm1": norm_init(ks[0], cfg.d_model, dt, cfg.norm)}
    if kind == "ssm":
        p["mixer"] = mamba_init(ks[1], cfg)
        return p
    if kind == "hybrid":
        p["attn"] = attn_init(ks[1], cfg)
        p["ssm"] = mamba_init(ks[2], cfg)
        p["attn_out_norm"] = norm_init(ks[3], cfg.d_model, dt, cfg.norm)
        p["ssm_out_norm"] = norm_init(ks[4], cfg.d_model, dt, cfg.norm)
        p["norm2"] = norm_init(ks[5], cfg.d_model, dt, cfg.norm)
        p["ffn"] = mlp_init(ks[5], cfg)
        return p
    p["attn"] = attn_init(ks[1], cfg)
    p["norm2"] = norm_init(ks[2], cfg.d_model, dt, cfg.norm)
    if kind == "moe":
        p["ffn"] = moe_init(ks[3], cfg)
    else:
        p["ffn"] = mlp_init(ks[3], cfg)
    if kind == "xdec":
        p["cross"] = attn_init(ks[4], cfg)
        p["norm_cross"] = norm_init(ks[5], cfg.d_model, dt, cfg.norm)
    return p


# ------------------------------------------------------------------ full-seq
def block_apply(p, x, cfg: ModelConfig, kind: str, positions=None, enc_out=None,
                mode=None):
    """x: (B, S, d) -> (x, aux)."""
    aux = dict(ZERO_AUX)
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == "ssm":
        mix, _ = mamba(p["mixer"], h, cfg)
        return x + mix, aux
    if kind == "hybrid":
        a = attention(p["attn"], h, cfg, positions=positions, mode=mode)
        s, _ = mamba(p["ssm"], h, cfg)
        # Hymba: parallel heads, outputs normalised then averaged
        mix = 0.5 * (apply_norm(p["attn_out_norm"], a, cfg.norm)
                     + apply_norm(p["ssm_out_norm"], s, cfg.norm))
        x = x + mix
        x = x + mlp(p["ffn"], apply_norm(p["norm2"], x, cfg.norm), cfg)
        return x, aux
    x = x + attention(p["attn"], h, cfg, positions=positions, mode=mode)
    if kind == "xdec" and enc_out is not None:
        hc = apply_norm(p["norm_cross"], x, cfg.norm)
        x = x + attention(p["cross"], hc, cfg, kv_x=enc_out, mode="bidir")
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    if kind == "moe":
        y, aux = moe(p["ffn"], h2, cfg)
        x = x + y
    else:
        x = x + mlp(p["ffn"], h2, cfg)
    return x, aux


# ------------------------------------------------------------------ prefill
def block_prefill(p, x, cfg: ModelConfig, kind: str, positions=None, enc_out=None):
    """Returns (x, cache_entry)."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == "ssm":
        mix, st = mamba(p["mixer"], h, cfg)
        return x + mix, st
    if kind == "hybrid":
        a, (kc, vc) = attention_prefill(p["attn"], h, cfg, positions=positions)
        s, st = mamba(p["ssm"], h, cfg)
        mix = 0.5 * (apply_norm(p["attn_out_norm"], a, cfg.norm)
                     + apply_norm(p["ssm_out_norm"], s, cfg.norm))
        x = x + mix
        x = x + mlp(p["ffn"], apply_norm(p["norm2"], x, cfg.norm), cfg)
        return x, {"k": kc, "v": vc, **st}
    a, (kc, vc) = attention_prefill(p["attn"], h, cfg, positions=positions)
    x = x + a
    cache = {"k": kc, "v": vc}
    if kind == "xdec" and enc_out is not None:
        hc = apply_norm(p["norm_cross"], x, cfg.norm)
        x = x + attention(p["cross"], hc, cfg, kv_x=enc_out, mode="bidir")
        # cross K/V are static per request: precompute once
        from .attention import _split_heads
        from .module import dense
        hd = cfg.head_dim_
        cache["ck"] = _split_heads(dense(p["cross"]["k"], enc_out, cfg.cdtype()),
                                   cfg.n_kv_heads, hd)
        cache["cv"] = _split_heads(dense(p["cross"]["v"], enc_out, cfg.cdtype()),
                                   cfg.n_kv_heads, hd)
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    if kind == "moe":
        y, _ = moe(p["ffn"], h2, cfg)
        x = x + y
    else:
        x = x + mlp(p["ffn"], h2, cfg)
    return x, cache


# ------------------------------------------------------------------ decode
def block_decode(p, x, cache, idx, cfg: ModelConfig, kind: str, enc_len=None):
    """x: (B,1,d); cache: this layer's entry; idx: tokens already cached."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == "ssm":
        mix, st = mamba_decode(p["mixer"], h, cache, cfg)
        return x + mix, st
    if kind == "hybrid":
        kvc = {"k": cache["k"], "v": cache["v"]}
        a, kvc = attention_decode(p["attn"], h, kvc, idx, cfg)
        s, st = mamba_decode(p["ssm"], h, {"conv": cache["conv"], "h": cache["h"]}, cfg)
        mix = 0.5 * (apply_norm(p["attn_out_norm"], a, cfg.norm)
                     + apply_norm(p["ssm_out_norm"], s, cfg.norm))
        x = x + mix
        x = x + mlp(p["ffn"], apply_norm(p["norm2"], x, cfg.norm), cfg)
        return x, {**kvc, **st}
    kvc = {"k": cache["k"], "v": cache["v"]}
    a, kvc = attention_decode(p["attn"], h, kvc, idx, cfg)
    x = x + a
    new_cache = dict(kvc)
    if kind == "xdec" and "ck" in cache:
        hc = apply_norm(p["norm_cross"], x, cfg.norm)
        c, _ = attention_decode(p["cross"], hc, {"k": cache["ck"], "v": cache["cv"]},
                                enc_len, cfg, cross=True)
        x = x + c
        new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    if kind == "moe":
        y, _ = moe(p["ffn"], h2, cfg)
        x = x + y
    else:
        x = x + mlp(p["ffn"], h2, cfg)
    return x, new_cache


def block_decode_paged(p, x, kv, st, pages, idx, cfg: ModelConfig, kind: str):
    """Paged-KV decode step.  ``kv``: this layer's page pool ({"k","v"}
    (P, page_size, KV, hd), empty for attention-free kinds); ``st``: this
    layer's per-slot state (SSM conv/h, empty for pure-attention kinds);
    ``pages`` (B, max_pages) / ``idx`` (B,) route KV reads and writes.
    Returns (x, kv, st) — same contract as ``block_decode`` with the cache
    split into its paged and slot-resident halves."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == "ssm":
        mix, st = mamba_decode(p["mixer"], h, st, cfg)
        return x + mix, kv, st
    if kind == "hybrid":
        a, kv = attention_decode_paged(p["attn"], h, kv, pages, idx, cfg)
        s, st = mamba_decode(p["ssm"], h, {"conv": st["conv"], "h": st["h"]},
                             cfg)
        mix = 0.5 * (apply_norm(p["attn_out_norm"], a, cfg.norm)
                     + apply_norm(p["ssm_out_norm"], s, cfg.norm))
        x = x + mix
        x = x + mlp(p["ffn"], apply_norm(p["norm2"], x, cfg.norm), cfg)
        return x, kv, st
    a, kv = attention_decode_paged(p["attn"], h, kv, pages, idx, cfg)
    x = x + a
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    if kind == "moe":
        y, _ = moe(p["ffn"], h2, cfg)
        x = x + y
    else:
        x = x + mlp(p["ffn"], h2, cfg)
    return x, kv, st


# ------------------------------------------------------------------ cache init
def block_cache_init(cfg: ModelConfig, kind: str, batch, max_len, enc_len=None):
    if kind == "ssm":
        return init_ssm_cache(cfg, batch)
    cache = init_kv_cache(cfg, batch, max_len)
    if kind == "hybrid":
        cache.update(init_ssm_cache(cfg, batch))
    if kind == "xdec" and enc_len is not None:
        hd = cfg.head_dim_
        cache["ck"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), cfg.cdtype())
        cache["cv"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), cfg.cdtype())
    return cache
