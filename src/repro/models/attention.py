"""Attention: GQA/MQA, RoPE / M-RoPE, causal / bidirectional / sliding-window,
memory-efficient chunked softmax (the pure-JAX flash-attention used by the
multi-pod dry-run), and single-token decode against a KV cache.

Sharding notes (see repro/sharding/rules.py):
* training/prefill activations: batch on (pod,data), heads on model when the
  head count divides the axis, else head_dim on model;
* decode KV cache: (B, S, KV, hd) — batch on (pod,data), and KV on model when
  divisible else hd on model; the hd contraction then reduces over a sharded
  dim, which GSPMD turns into the flash-decode all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .module import apply_mrope, apply_rope, dense, dense_init, normal_init

NEG_INF = -1e30


# ------------------------------------------------------------------ params
def attn_init(key, cfg: ModelConfig, cross: bool = False):
    hd = cfg.head_dim_
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = cfg.pdtype()
    return {
        "q": dense_init(kq, cfg.d_model, cfg.n_heads * hd, dt, bias=cfg.qkv_bias),
        "k": dense_init(kk, cfg.d_model, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "v": dense_init(kv, cfg.d_model, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "o": dense_init(ko, cfg.n_heads * hd, cfg.d_model, dt, bias=False,
                        init=lambda k, s, d: normal_init(k, s, d, stddev=0.02 / max(1, cfg.n_layers) ** 0.5)),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def default_positions(cfg: ModelConfig, B, S):
    if cfg.mrope:
        return jnp.broadcast_to(jnp.arange(S), (3, B, S))
    return jnp.arange(S)


def _rope(q, k, positions, cfg: ModelConfig):
    if positions is None:
        return q, k
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


# ------------------------------------------------------------------ masks
def _mask_bias(q_pos, k_pos, mode: str, window):
    """(Sq, Sk) additive bias. q_pos/k_pos: int32 position vectors."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    if mode == "bidir":
        return jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    ok = dk <= dq
    if mode == "sliding" and window is not None:
        ok = ok & (dq - dk < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ------------------------------------------------------------------ core sdpa
def _sdpa_naive(q, k, v, bias):
    """q: (B,Sq,KV,G,hd)  k/v: (B,Sk,KV,hd)  bias: (Sq,Sk)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd)
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out


def _sdpa_chunked(q, k, v, q_pos, k_pos, mode, window, q_chunk=1024):
    """Online-softmax attention, scanning query chunks: never materialises the
    (Sq, Sk) score matrix for all queries at once.  Oracle for the Pallas
    flash_attention kernel; also the dry-run path (Pallas cannot lower on the
    CPU host platform)."""
    B, Sq, KV, G, hd = q.shape
    n_chunks = Sq // q_chunk
    assert n_chunks * q_chunk == Sq, (Sq, q_chunk)
    qs = q.reshape(B, n_chunks, q_chunk, KV, G, hd)
    qps = q_pos.reshape(n_chunks, q_chunk)

    @jax.checkpoint
    def step(_, inp):
        # checkpointed: backward recomputes the (bq, Sk) scores instead of
        # saving per-chunk softmax probs (flash-attention memory behaviour)
        qc, qp = inp
        bias = _mask_bias(qp, k_pos, mode, window)
        out = _sdpa_naive(qc, k, v, bias)
        return _, out

    from .transformer import _unroll
    _, outs = jax.lax.scan(step, None, (jnp.moveaxis(qs, 1, 0), qps),
                           unroll=_unroll())
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, G, hd)


# ------------------------------------------------------------------ train/prefill
def attention(p, x, cfg: ModelConfig, positions=None, kv_x=None, mode=None,
              q_chunk=1024):
    """Full-sequence attention.  kv_x != None -> cross attention (no rope on kv
    side beyond its own positions handled by caller)."""
    hd = cfg.head_dim_
    B, S, _ = x.shape
    cd = cfg.cdtype()
    q = _split_heads(dense(p["q"], x, cd), cfg.n_heads, hd)
    src = x if kv_x is None else kv_x
    k = _split_heads(dense(p["k"], src, cd), cfg.n_kv_heads, hd)
    v = _split_heads(dense(p["v"], src, cd), cfg.n_kv_heads, hd)

    if mode is None:
        if kv_x is not None:
            mode = "bidir"
        elif cfg.sliding_window is not None:
            mode = "sliding"
        else:
            mode = "causal" if cfg.causal else "bidir"

    if kv_x is None:  # self-attention: rotate q and k
        if positions is None:
            positions = default_positions(cfg, B, S)
        q, k = _rope(q, k, positions, cfg)

    # mask positions are always contiguous arange (no sequence packing here)
    q_pos, k_pos = jnp.arange(S), jnp.arange(src.shape[1])

    G = cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(B, S, cfg.n_kv_heads, G, hd)

    if S > q_chunk and S % q_chunk == 0:
        out = _sdpa_chunked(q, k, v, q_pos, k_pos, mode, cfg.sliding_window, q_chunk)
    else:
        out = _sdpa_naive(q, k, v, _mask_bias(q_pos, k_pos, mode, cfg.sliding_window))

    out = out.reshape(B, S, cfg.n_heads * hd).astype(cd)
    return dense(p["o"], out, cd)


# ------------------------------------------------------------------ prefill -> cache
def attention_prefill(p, x, cfg: ModelConfig, positions=None):
    """Returns (out, (k_cache_entry, v_cache_entry)) with layout (B, S, KV, hd)."""
    hd = cfg.head_dim_
    B, S, _ = x.shape
    cd = cfg.cdtype()
    q = _split_heads(dense(p["q"], x, cd), cfg.n_heads, hd)
    k = _split_heads(dense(p["k"], x, cd), cfg.n_kv_heads, hd)
    v = _split_heads(dense(p["v"], x, cd), cfg.n_kv_heads, hd)
    if positions is None:
        positions = default_positions(cfg, B, S)
    q, k = _rope(q, k, positions, cfg)
    mode = "sliding" if cfg.sliding_window is not None else ("causal" if cfg.causal else "bidir")
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, G, hd)
    pos1d = jnp.arange(S)
    if S > 1024 and S % 1024 == 0:
        out = _sdpa_chunked(qg, k, v, pos1d, pos1d, mode, cfg.sliding_window)
    else:
        out = _sdpa_naive(qg, k, v, _mask_bias(pos1d, pos1d, mode, cfg.sliding_window))
    out = out.reshape(B, S, cfg.n_heads * hd).astype(cd)
    return dense(p["o"], out, cd), (k, v)


# ------------------------------------------------------------------ decode
def attention_decode(p, x, cache, idx, cfg: ModelConfig, cross=False):
    """One-token decode.

    x: (B, 1, d).  cache: {"k","v"}: (B, Smax, KV, hd) (ring buffer when
    sliding-window).  idx: number of tokens already in cache — a scalar
    int32, or a per-row ``(B,)`` vector when batch rows sit at different
    depths (the continuous-batching serve loop admits requests mid-decode,
    so slots desynchronize).  Returns (out (B,1,d), updated cache).
    """
    hd = cfg.head_dim_
    B = x.shape[0]
    cd = cfg.cdtype()
    Smax = cache["k"].shape[1]
    q = _split_heads(dense(p["q"], x, cd), cfg.n_heads, hd)      # (B,1,H,hd)
    idx = jnp.asarray(idx, jnp.int32)
    per_row = idx.ndim == 1

    if not cross:
        k_new = _split_heads(dense(p["k"], x, cd), cfg.n_kv_heads, hd)
        v_new = _split_heads(dense(p["v"], x, cd), cfg.n_kv_heads, hd)
        pos = idx.reshape(B, 1) if per_row else jnp.full((1,), idx, jnp.int32)
        if cfg.mrope:
            pos3 = jnp.broadcast_to(pos[None] if per_row else pos, (3, B, 1))
            q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
            k_new = apply_mrope(k_new, pos3, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, pos, cfg.rope_theta)
            k_new = apply_rope(k_new, pos, cfg.rope_theta)
        from ..sharding.hooks import constrain_cache_entry
        slot = idx % Smax if cfg.sliding_window is not None else idx
        if per_row:
            # per-row write slot: a one-hot blend along the cache's seq axis
            # (out-of-range slots one-hot to zeros — rows parked at
            # slot >= Smax, e.g. drained serve slots, write nothing)
            oh = jax.nn.one_hot(slot, Smax, dtype=jnp.bool_)     # (B, Smax)
            k_cache = jnp.where(oh[:, :, None, None],
                                k_new.astype(cache["k"].dtype), cache["k"])
            v_cache = jnp.where(oh[:, :, None, None],
                                v_new.astype(cache["v"].dtype), cache["v"])
        else:
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        cache = {"k": constrain_cache_entry(k_cache),
                 "v": constrain_cache_entry(v_cache)}
        # valid positions: j <= idx (and within window for SWA ring buffer)
        j = jnp.arange(Smax)
        ii = idx[:, None] if per_row else idx
        if cfg.sliding_window is not None:
            valid = (j <= ii) | (ii >= Smax)        # ring full -> all slots valid
        else:
            valid = j <= ii
    else:
        j = jnp.arange(Smax)
        valid = j < idx  # idx == encoder length for cross attention
        if cfg.mrope:
            pos3 = jnp.broadcast_to(jnp.full((1,), idx, jnp.int32), (3, B, 1))
            q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)

    from ..sharding.hooks import constrain_decode_q
    G = cfg.n_heads // cfg.n_kv_heads
    qg = constrain_decode_q(q.reshape(B, 1, cfg.n_kv_heads, G, hd))
    # keep the cache in bf16 and accumulate in f32 (flash-decode numerics):
    # an .astype(f32) here gets hoisted by XLA into a full-cache f32 copy
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, cache["k"],
                        preferred_element_type=jnp.float32) / jnp.sqrt(hd)
    vb = (valid[:, None, None, None, :] if valid.ndim == 2
          else valid[None, None, None, None, :])
    scores = jnp.where(vb, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(cache["v"].dtype),
                     cache["v"], preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, cfg.n_heads * hd).astype(cd)
    return dense(p["o"], out, cd), cache


def attention_decode_paged(p, x, pool, pages, idx, cfg: ModelConfig,
                           use_kernel=None):
    """One-token decode over a **paged** KV pool (ISSUE 9).

    x: (B, 1, d).  pool: {"k","v"}: (P, page_size, KV, hd) — the flat page
    pool shared by every slot; ``pages`` (B, max_pages) int32 maps each
    row's token positions to pool pages in order (entries < 0 unallocated,
    see ``core.paging.PageTable``); ``idx`` (B,) int32 per-row decode depth
    (rows parked at ``idx >= max_pages·page_size`` write nothing, exactly
    like the dense one-hot OOB parking).  Returns (out (B,1,d), pool).

    The fallback path gathers the row's pages into a contiguous
    ``(B, max_pages·page_size, KV, hd)`` view and runs the *identical*
    masked-softmax einsums as the dense ``attention_decode`` — paged and
    dense decode are row-for-row equal by construction.  On TPU the Pallas
    kernel (``kernels.paged_attention``) skips the gather: the page table
    is scalar-prefetched and drives the KV BlockSpec index_map.

    Sliding-window ring semantics are not paged (the serve loop already
    refuses horizons beyond the window, so positions never wrap).
    """
    hd = cfg.head_dim_
    B = x.shape[0]
    cd = cfg.cdtype()
    P, ps = pool["k"].shape[0], pool["k"].shape[1]
    mp = pages.shape[1]
    horizon = mp * ps
    idx = jnp.asarray(idx, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.full((B,), idx, jnp.int32)

    q = _split_heads(dense(p["q"], x, cd), cfg.n_heads, hd)      # (B,1,H,hd)
    k_new = _split_heads(dense(p["k"], x, cd), cfg.n_kv_heads, hd)
    v_new = _split_heads(dense(p["v"], x, cd), cfg.n_kv_heads, hd)
    pos = idx.reshape(B, 1)
    if cfg.mrope:
        pos3 = jnp.broadcast_to(pos[None], (3, B, 1))
        q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k_new = apply_mrope(k_new, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)

    # -- paged KV write: token idx lands in page pages[b, idx // ps] at
    # offset idx % ps.  Parked rows (idx >= horizon) route to the OOB
    # sentinel P and scatter-drop; shared prefix pages are never written
    # here (decode positions sit past the prompt, hence past the prefix).
    pidx = jnp.clip(idx // ps, 0, mp - 1)
    page = jnp.take_along_axis(pages, pidx[:, None], axis=1)[:, 0]
    page = jnp.where((idx >= 0) & (idx < horizon), page, P)
    off = idx % ps
    k_pool = pool["k"].at[page, off].set(
        k_new[:, 0].astype(pool["k"].dtype), mode="drop")
    v_pool = pool["v"].at[page, off].set(
        v_new[:, 0].astype(pool["v"].dtype), mode="drop")
    pool = {"k": k_pool, "v": v_pool}

    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, cfg.n_kv_heads, G, hd)

    use = use_kernel
    if use is None:
        use = jax.default_backend() == "tpu"
    if use:
        from ..kernels import ops as kops
        out = kops.paged_attention(qg[:, 0], k_pool, v_pool, pages,
                                   jnp.minimum(idx, horizon - 1) + 1)
        out = out[:, None]                                   # (B,1,KV,G,hd)
    else:
        # contiguous per-row view of the pages, then the dense decode math
        gather = jnp.maximum(pages, 0)
        K = k_pool[gather].reshape(B, horizon, cfg.n_kv_heads, hd)
        V = v_pool[gather].reshape(B, horizon, cfg.n_kv_heads, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, K,
                            preferred_element_type=jnp.float32) / jnp.sqrt(hd)
        j = jnp.arange(horizon)
        valid = j[None, :] <= idx[:, None]
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(V.dtype), V,
                         preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, cfg.n_heads * hd).astype(cd)
    return dense(p["o"], out, cd), pool


def init_paged_kv_pool(cfg: ModelConfig, n_pages, page_size, dtype=None):
    """Per-layer paged pool entry; the model stacks these along axis 0."""
    hd = cfg.head_dim_
    dt = dtype or cfg.cdtype()
    return {
        "k": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, hd), dt),
    }


def init_kv_cache(cfg: ModelConfig, batch, max_len, dtype=None):
    """Per-layer cache entry; the model stacks these along axis 0."""
    hd = cfg.head_dim_
    dt = dtype or cfg.cdtype()
    if cfg.sliding_window is not None:
        max_len = min(max_len, cfg.sliding_window)
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
    }
