"""Mamba-1 selective SSM (FalconMamba [arXiv:2410.05355], Hymba SSM branch
[arXiv:2411.13676]).

The training/prefill path uses a *chunked* selective scan: a `lax.scan` over
sequence chunks carrying the (d_inner, N) state, with an associative scan
inside each chunk.  The (B, S, d_inner, N) discretised tensors therefore only
ever exist one chunk at a time — this is the structural adaptation of the
CUDA selective-scan kernel to TPU memory (HBM->VMEM streaming); the Pallas
`ssm_scan` kernel implements the same blocking explicitly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .module import dense, dense_init, normal_init

# §Perf lever: checkpoint each selective-scan chunk (see chunked_selective_scan)
SSM_CHUNK_CKPT = False


def set_ssm_chunk_ckpt(flag: bool):
    global SSM_CHUNK_CKPT
    SSM_CHUNK_CKPT = bool(flag)


# ------------------------------------------------------------------ params
def mamba_init(key, cfg: ModelConfig):
    dt_ = cfg.pdtype()
    d_in = cfg.d_inner
    N = cfg.ssm_state
    R = cfg.dt_rank
    ks = jax.random.split(key, 7)
    # S4D-real initialisation for A
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, N))
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * d_in, dt_),
        "conv_w": normal_init(ks[1], (cfg.ssm_conv_width, d_in), dt_, stddev=0.1),
        "conv_b": jnp.zeros((d_in,), dt_),
        "x_proj": dense_init(ks[2], d_in, R + 2 * N, dt_),
        "dt_proj": {"w": normal_init(ks[3], (R, d_in), dt_, stddev=R ** -0.5),
                    "b": jnp.log(jnp.expm1(jnp.full((d_in,), 0.01, jnp.float32))).astype(dt_)},
        "A_log": jnp.log(A).astype(dt_),
        "D": jnp.ones((d_in,), dt_),
        "out_proj": dense_init(ks[4], d_in, cfg.d_model, dt_,
                               init=lambda k, s, d: normal_init(k, s, d, 0.02 / max(1, cfg.n_layers) ** 0.5)),
    }


def _ssm_inputs(p, u, cfg: ModelConfig):
    """u: (B, S, d_inner) post-conv activations -> (dt, Bm, Cm)."""
    N, R = cfg.ssm_state, cfg.dt_rank
    xdbc = dense(p["x_proj"], u, jnp.float32)
    dt_r, Bm, Cm = jnp.split(xdbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"]["w"].astype(jnp.float32)
                         + p["dt_proj"]["b"].astype(jnp.float32))     # (B,S,d_in)
    return dt, Bm, Cm


def _causal_conv(p, x, cfg: ModelConfig, init_state=None):
    """Depthwise causal conv1d.  x: (B, S, d_inner).  init_state: (B, W-1, d)
    tail of previous tokens (decode/prefill continuation)."""
    W = cfg.ssm_conv_width
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    out = sum(xp[:, i:i + x.shape[1]].astype(jnp.float32) * w[i] for i in range(W))
    return (out + p["conv_b"].astype(jnp.float32)).astype(x.dtype), xp[:, -(W - 1):]


def chunked_selective_scan(u, dt, Bm, Cm, A, D, h0=None, chunk=256):
    """Selective scan  h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t ;  y_t = h_t·C_t + D u_t.

    u/dt: (B, S, d);  Bm/Cm: (B, S, N);  A: (d, N);  D: (d,);  h0: (B, d, N).
    Returns (y (B,S,d), h_final (B,d,N)).  All math float32.
    """
    Bsz, S, d = u.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    n_chunks = max(1, S // chunk)
    assert n_chunks * chunk == S, (S, chunk)
    if h0 is None:
        h0 = jnp.zeros((Bsz, d, N), jnp.float32)

    u_c = u.reshape(Bsz, n_chunks, chunk, d)
    dt_c = dt.reshape(Bsz, n_chunks, chunk, d)
    B_c = Bm.reshape(Bsz, n_chunks, chunk, N)
    C_c = Cm.reshape(Bsz, n_chunks, chunk, N)

    def chunk_step(h, xs):  # noqa: ANN001  (checkpointed below when enabled)
        uc, dtc, bc, cc = xs                                   # (B, chunk, ...)
        dA = dtc[..., None] * A                                # (B,chunk,d,N)  A<0
        a = jnp.exp(dA)
        b = (dtc * uc)[..., None] * bc[:, :, None, :]          # (B,chunk,d,N)

        def op(l, r):
            return (l[0] * r[0], l[1] * r[0] + r[1])

        a_sc, b_sc = jax.lax.associative_scan(op, (a, b), axis=1)
        h_all = b_sc + a_sc * h[:, None]                       # (B,chunk,d,N)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, cc) + D * uc
        return h_all[:, -1], y

    if SSM_CHUNK_CKPT:
        # §Perf iteration (EXPERIMENTS.md): without this, backward through the
        # chunk scan saves the (B, chunk, d_inner, N) discretised tensors of
        # EVERY chunk (≈ S·d_inner·N floats per layer) — checkpointing the
        # chunk recomputes them, saving only the (B, d_inner, N) carries.
        chunk_step = jax.checkpoint(chunk_step)

    from .transformer import _unroll
    h_fin, ys = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(u_c, 1, 0), jnp.moveaxis(dt_c, 1, 0),
         jnp.moveaxis(B_c, 1, 0), jnp.moveaxis(C_c, 1, 0)),
        unroll=_unroll())
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, d)
    return y, h_fin


# ------------------------------------------------------------------ block apply
def mamba(p, x, cfg: ModelConfig, state=None, use_kernel=False):
    """Full-sequence mamba mixer.  x: (B, S, d_model).
    state: optional {"conv": (B,W-1,d_in), "h": (B,d_in,N)} to continue from.
    Returns (out (B,S,d_model), new_state)."""
    cd = cfg.cdtype()
    xz = dense(p["in_proj"], x, cd)
    u, z = jnp.split(xz, 2, axis=-1)
    conv_in = None if state is None else state["conv"]
    u, conv_tail = _causal_conv(p, u, cfg, conv_in)
    u = jax.nn.silu(u.astype(jnp.float32))
    dt, Bm, Cm = _ssm_inputs(p, u, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    D = p["D"].astype(jnp.float32)
    h0 = None if state is None else state["h"]
    if use_kernel:
        from ..kernels import ops as kops
        y, h_fin = kops.ssm_scan(u, dt, Bm, Cm, A, D, h0=h0)
    else:
        y, h_fin = chunked_selective_scan(u, dt, Bm, Cm, A, D, h0=h0)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense(p["out_proj"], y.astype(cd), cd)
    return out, {"conv": conv_tail, "h": h_fin}


def mamba_decode(p, x, state, cfg: ModelConfig):
    """Single-token recurrence.  x: (B, 1, d_model)."""
    cd = cfg.cdtype()
    xz = dense(p["in_proj"], x, cd)
    u, z = jnp.split(xz, 2, axis=-1)                           # (B,1,d_in)
    W = cfg.ssm_conv_width
    conv_buf = jnp.concatenate([state["conv"], u], axis=1)     # (B,W,d_in)
    w = p["conv_w"].astype(jnp.float32)
    u1 = sum(conv_buf[:, i].astype(jnp.float32) * w[i] for i in range(W))
    u1 = jax.nn.silu(u1 + p["conv_b"].astype(jnp.float32))[:, None]  # (B,1,d_in)
    dt, Bm, Cm = _ssm_inputs(p, u1, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :, None] * A)                         # (B,d_in,N)
    b = (dt[:, 0] * u1[:, 0])[..., None] * Bm[:, 0, None, :]
    h = a * state["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + p["D"].astype(jnp.float32) * u1[:, 0]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32)))[:, None]
    out = dense(p["out_proj"], y.astype(cd), cd)
    return out, {"conv": conv_buf[:, 1:], "h": h}


def init_ssm_cache(cfg: ModelConfig, batch):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.d_inner), cfg.cdtype()),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
