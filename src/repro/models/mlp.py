"""Feed-forward layers: dense GeGLU/SwiGLU/GELU MLPs and GShard-style
top-k Mixture-of-Experts with capacity-based dispatch (+ shared experts for
DeepSeekMoE [arXiv:2401.06066]).

The MoE dispatch is expressed as dense einsums over a (groups, tokens,
experts, capacity) one-hot so that, under pjit with experts sharded on the
"model" mesh axis, GSPMD lowers it to the canonical all-to-all pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .module import ACTIVATIONS, dense, dense_init, normal_init


# ------------------------------------------------------------------ dense mlp
def mlp_init(key, cfg: ModelConfig, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    dt = cfg.pdtype()
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": dense_init(k1, cfg.d_model, d_ff, dt),
         "down": dense_init(k2, d_ff, cfg.d_model, dt,
                            init=lambda k, s, d: normal_init(k, s, d, 0.02 / max(1, cfg.n_layers) ** 0.5))}
    if cfg.activation in ("swiglu", "geglu"):
        p["gate"] = dense_init(k3, cfg.d_model, d_ff, dt)
    return p


def mlp(p, x, cfg: ModelConfig, act=None):
    cd = cfg.cdtype()
    act = act or cfg.activation
    if act in ("swiglu", "geglu"):
        g = dense(p["gate"], x, cd)
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * dense(p["up"], x, cd)
    else:
        h = ACTIVATIONS[act](dense(p["up"], x, cd))
    return dense(p["down"], h, cd)


# ------------------------------------------------------------------ moe
def moe_init(key, cfg: ModelConfig):
    dt = cfg.pdtype()
    kr, ke, ks = jax.random.split(key, 3)
    E, dff = cfg.n_experts, cfg.expert_d_ff

    def expert_bank(k):
        kg, ku, kd = jax.random.split(k, 3)
        return {
            "gate": normal_init(kg, (E, cfg.d_model, dff), dt),
            "up": normal_init(ku, (E, cfg.d_model, dff), dt),
            "down": normal_init(kd, (E, dff, cfg.d_model), dt,
                                stddev=0.02 / max(1, cfg.n_layers) ** 0.5),
        }

    p = {"router": dense_init(kr, cfg.d_model, E, dt), "experts": expert_bank(ke)}
    if cfg.n_shared_experts:
        keys = jax.random.split(ks, cfg.n_shared_experts)
        p["shared"] = [mlp_init(k, cfg, d_ff=dff) for k in keys]
    return p


def _expert_ffn(bank, x, cfg: ModelConfig):
    """x: (E, C_total, d) -> (E, C_total, d); SwiGLU expert MLP."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, bank["gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", x, bank["up"].astype(x.dtype))
    return jnp.einsum("ecf,efd->ecd", g * u, bank["down"].astype(x.dtype))


def moe(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux) where aux = {load_balance, router_z}."""
    cd = cfg.cdtype()
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    G = min(cfg.moe_group_size, B * S)
    T = B * S
    assert T % G == 0, (T, G)
    n_groups = T // G
    cap = max(1, int(cfg.capacity_factor * G * K / E))
    cap = min(cap, G)

    xg = x.reshape(n_groups, G, d)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                      # (g, G, K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)       # (g, G, K, E)
    # flatten (token, k) assignments in token-major order for capacity ranking
    flat = onehot.reshape(n_groups, G * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                     # (g, G*K, E)
    keep = (pos < cap).astype(jnp.float32) * flat
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    disp_flat = keep[..., None] * pos_oh                      # (g, G*K, E, C)
    disp = disp_flat.reshape(n_groups, G, K, E, cap)
    dispatch = jnp.sum(disp, axis=2)                          # (g, G, E, C) 0/1
    combine = jnp.sum(disp * topv[..., None, None], axis=2)   # (g, G, E, C)

    # ---- all-to-all in, expert compute, all-to-all out (under GSPMD) ----
    expert_in = jnp.einsum("gtec,gtd->egcd", dispatch.astype(cd), xg.astype(cd))
    expert_in = expert_in.reshape(E, n_groups * cap, d)
    expert_out = _expert_ffn(p["experts"], expert_in, cfg)
    expert_out = expert_out.reshape(E, n_groups, cap, d)
    y = jnp.einsum("gtec,egcd->gtd", combine.astype(cd), expert_out)
    y = y.reshape(B, S, d)

    if cfg.n_shared_experts:
        for sp in p["shared"]:
            y = y + mlp(sp, x, cfg, act="swiglu")

    # ---- aux losses (GShard load-balance + router z-loss) ----
    me = jnp.mean(probs, axis=(0, 1))                         # mean gate prob per expert
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))       # mean assignment per expert
    load_balance = E * jnp.sum(me * ce)
    router_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"load_balance": load_balance, "router_z": router_z}
    return y, aux
