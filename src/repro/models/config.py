"""Model / adapter / chain configuration dataclasses.

One `ModelConfig` covers every assigned architecture family:
dense / moe / ssm / hybrid / encdec(audio) / vlm.  Each
``src/repro/configs/<arch>.py`` instantiates it with the exact published
hyper-parameters (source cited there) and provides a reduced smoke variant.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class AdapterConfig:
    """Houlsby bottleneck adapter (paper Eq. 1)."""
    rank: int = 64                  # v — bottleneck width
    activation: str = "gelu"        # f(.)
    dropout: float = 0.0            # kept for API completeness (inference-mode in chain prefix)
    fused: Optional[bool] = None    # Pallas fused-adapter forward: None →
                                    # backend-aware (TPU only), True/False force

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    arch_id: str = "unnamed"
    family: str = "dense"            # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""                 # citation for the config values

    # trunk
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1000
    activation: str = "swiglu"       # swiglu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = True
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10000.0
    mrope: bool = False              # Qwen2-VL multimodal rope (3 position axes)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # per-axis head_dim halves
    sliding_window: Optional[int] = None   # SWA variant (enables long_500k for dense)
    causal: bool = True

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 0.001
    moe_group_size: int = 512        # GShard dispatch group size (tokens)

    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv_width: int = 4
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model / 16)

    # encoder-decoder (audio / seq2seq); n_layers is the DECODER depth then
    n_encoder_layers: int = 0
    frontend: str = "none"           # none | audio_stub | vision_stub

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # adapter
    adapter: AdapterConfig = dataclasses.field(default_factory=AdapterConfig)

    # ----- derived -----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, (self.d_model + 15) // 16)

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, 128)

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def uses_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def total_chain_layers(self) -> int:
        """Layers the optimization chain runs over (enc+dec for encdec)."""
        return self.n_layers + self.n_encoder_layers

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, n_layers: int = 2, d_model: int = 128, n_experts: int = 4,
                vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family (<=2 layers, d_model<=512, <=4 experts)."""
        kw = dict(
            n_layers=n_layers,
            d_model=min(d_model, 512),
            n_heads=max(2, min(self.n_heads, 4)),
            d_ff=4 * min(d_model, 512),
            vocab_size=vocab,
            head_dim=0,
            param_dtype="float32",
            compute_dtype="float32",
            adapter=self.adapter.replace(rank=8),
            moe_group_size=64,
        )
        kw["n_kv_heads"] = max(1, min(self.n_kv_heads, kw["n_heads"]))
        if self.n_experts:
            kw["n_experts"] = min(n_experts, 4)
            kw["top_k"] = min(self.top_k, 2)
            kw["n_shared_experts"] = min(self.n_shared_experts, 1)
            kw["expert_d_ff"] = min(d_model, 512) // 2
        if self.ssm_state:
            kw["ssm_state"] = min(self.ssm_state, 8)
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
        if self.sliding_window:
            kw["sliding_window"] = 64
        if self.mrope:
            hd = min(d_model, 512) // kw["n_heads"]
            s = hd // 2 // 4
            kw["mrope_sections"] = (hd // 2 - 2 * s, s, s)
        return self.replace(**kw)


@dataclasses.dataclass(frozen=True)
class ChainConfig:
    """CHAINFED hyper-parameters (paper §4 + App. D.3)."""
    window: int = 3                 # Q — DLCT co-tuning window size
    lam: float = 0.2                # λ — GPO global-loss weight (Eq. 2)
    foat_threshold: float = 0.8     # T — FOAT CKA threshold
    local_steps: int = 1            # local optimisation steps per round
    lr: float = 1e-3
    optimizer: str = "adamw"        # adamw | sgd
    advance_every: int = 1          # rounds per window advance (paper: 1)
    cycles: int = 1                 # holistic passes over the chain
    train_head: bool = True         # train the output layer (classification)
    opt_bits: int = 32              # optimizer-state precision: 32 fp32
                                    # moments, 8 blockwise-int8 (optim.quant)
    fused_optim: Optional[bool] = None  # single-pass fused update: None →
                                    # backend-aware (Pallas kernel on TPU,
                                    # op-identical XLA elsewhere), True
                                    # force kernel, False legacy multi-pass

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int = 16
    clients_per_round: int = 4
    rounds: int = 10
    dirichlet_alpha: float = 1.0    # non-IID partition (paper: α=1)
    iid: bool = False
    seed: int = 0

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)
