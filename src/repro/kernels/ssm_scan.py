"""Chunked selective-scan kernel (Mamba-1 recurrence) —

    h_t = exp(dt_t · A) · h_{t-1} + dt_t · B_t · u_t ;   y_t = h_t · C_t + D·u_t

TPU adaptation of the CUDA selective-scan: instead of one thread-block per
channel slab with shared-memory state, the grid's *minor* dimension walks
sequence chunks **sequentially** (TPU grid order guarantee), carrying the
(d, N) state in a VMEM scratch buffer across grid steps.  The discretised
(chunk, d, N) tensors exist only per-chunk in VMEM — HBM traffic is the
optimal  2·S·d (read u/dt + write y)  + 2·S·N (read B/C).

Grid: (B, S/chunk); the state scratch resets at chunk 0 of every batch row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref, y_ref, hout_ref,
            h_sc, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_sc[...] = h0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)          # (chunk, d)
    dt = dt_ref[0].astype(jnp.float32)        # (chunk, d)
    bm = b_ref[0].astype(jnp.float32)         # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)         # (chunk, N)
    A = a_ref[...].astype(jnp.float32)        # (d, N)
    D = d_ref[...].astype(jnp.float32)        # (1, d)

    def step(t, carry):
        h, ys = carry
        a_t = jnp.exp(dt[t][:, None] * A)                     # (d, N)
        h = a_t * h + (dt[t] * u[t])[:, None] * bm[t][None, :]
        y = h @ cm[t] + D[0] * u[t]                           # (d,)
        ys = jax.lax.dynamic_update_slice(ys, y[None], (t, 0))
        return h, ys

    h = h_sc[...]
    ys = jnp.zeros_like(u)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h, ys))
    h_sc[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        hout_ref[0] = h_sc[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(u, dt, B, C, A, D, h0=None, chunk=128, interpret=True):
    """u/dt: (Bt, S, d); B/C: (Bt, S, N); A: (d, N); D: (d,).
    Returns (y (Bt, S, d) float32, h_final (Bt, d, N) float32)."""
    Bt, S, d = u.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    if h0 is None:
        h0 = jnp.zeros((Bt, d, N), jnp.float32)
    D2 = D.reshape(1, d)

    y, h_fin = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=(Bt, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((d, N), lambda i, j: (0, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, d, N), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d, N), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, S, d), jnp.float32),
            jax.ShapeDtypeStruct((Bt, d, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, B, C, A, D2, h0)
    return y, h_fin
