"""Blockwise (flash) attention forward kernel: causal / sliding-window, with
the online-softmax running max/denominator so the (Sq, Sk) score matrix never
leaves VMEM.

Grid: (B·H, Sq/bq) — one query tile per step; K/V for that head stay
VMEM-resident (Sk·hd·2B ≈ 8 MB at Sk = 32k, hd = 128, bf16), and the kernel
walks KV tiles with `fori_loop`, skipping tiles that the causal/window mask
fully excludes (this is the Pallas analogue of flash-attention 2's block
skipping, adapted to the MXU's 128-aligned tiles).

Inference/prefill path only (no backward kernel): CHAINFED's training
backward never crosses frozen-prefix attention, and trainable-window
attention uses the jnp chunked path (see models/attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, causal, window, sk, scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale              # (bq, hd)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)

    n_kv = sk // bk

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
        if causal:
            ok = k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                ok &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc = acc * corr[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    hd = q_ref.shape[-1]
    acc = jnp.zeros((bq, hd), jnp.float32)
    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)

    if causal:
        # only KV tiles up to (and incl.) the query tile's diagonal participate
        hi = (qi + 1) * bq
        n_iter = (hi + bk - 1) // bk
        lo = 0
        if window is not None:
            lo = jnp.maximum(0, (qi * bq - window) // bk)
        acc, m, l = jax.lax.fori_loop(lo, n_iter, body, (acc, m, l))
    else:
        acc, m, l = jax.lax.fori_loop(0, n_kv, body, (acc, m, l))

    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal=True, window=None, bq=128, bk=128,
                    interpret=True):
    """q: (B, H, Sq, hd); k/v: (B, H, Sk, hd) — GQA repeat folded by caller.
    Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = 1.0 / (hd ** 0.5)
    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * H, Sk, hd)
    vf = v.reshape(B * H, Sk, hd)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal, window=window,
                          sk=Sk, scale=scale),
        grid=(B * H, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Sk, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, Sk, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd)
