"""Jit'd public wrappers for the Pallas kernels.

On this container (CPU host) kernels execute via ``interpret=True`` — the
kernel body runs in Python with the exact same blocking; on a real TPU set
``REPRO_KERNEL_INTERPRET=0`` (or pass interpret=False) to compile to Mosaic.
"""
from __future__ import annotations

import os

import jax

from .cka_gram import cka_gram as _cka_gram
from .flash_attention import flash_attention as _flash_attention
from .fused_adapter import fused_adapter as _fused_adapter
from .fused_adapter import fused_adapter_grad as _fused_adapter_grad
from .fused_adapter import fused_adapter_tenants as _fused_adapter_tenants
from .fused_optim import fused_adamw as _fused_adamw
from .fused_optim import fused_adamw8 as _fused_adamw8
from .fused_optim import fused_sgdm as _fused_sgdm
from .fused_optim import fused_sgdm8 as _fused_sgdm8
from .paged_attention import paged_attention as _paged_attention
from .ssm_scan import ssm_scan as _ssm_scan


def _interpret() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def fused_adapter(h, w_down, w_up, activation="gelu", **kw):
    kw.setdefault("interpret", _interpret())
    return _fused_adapter(h, w_down, w_up, activation=activation, **kw)


def fused_adapter_grad(h, w_down, w_up, activation="gelu", **kw):
    """Differentiable variant (custom VJP) — what the model forward calls."""
    kw.setdefault("interpret", _interpret())
    return _fused_adapter_grad(h, w_down, w_up, activation=activation, **kw)


def fused_adapter_tenants(h, tenant_ids, w_down, w_up, activation="gelu",
                          **kw):
    """Tenant-routed variant — the multi-tenant serving forward's kernel
    path (``adapter_apply_routed``); inference-only, no VJP."""
    kw.setdefault("interpret", _interpret())
    return _fused_adapter_tenants(h, tenant_ids, w_down, w_up,
                                  activation=activation, **kw)


def fused_adamw(p, g, mu, nu, scalars, **kw):
    """Fused clip→moments→AdamW update, one HBM pass per leaf — the
    ``optim.base`` kernel route when ``fused`` resolves to the Pallas path
    (inference-only: runs post-grad, no VJP)."""
    kw.setdefault("interpret", _interpret())
    return _fused_adamw(p, g, mu, nu, scalars, **kw)


def fused_adamw8(p, g, mu_q, mu_s, nu_q, nu_s, scalars, **kw):
    """int8-state variant: blockwise dequant/requant fused into the same
    tile pass (``opt_bits=8``), fp32 moments never hit HBM."""
    kw.setdefault("interpret", _interpret())
    return _fused_adamw8(p, g, mu_q, mu_s, nu_q, nu_s, scalars, **kw)


def fused_sgdm(p, g, mu, scalars, **kw):
    kw.setdefault("interpret", _interpret())
    return _fused_sgdm(p, g, mu, scalars, **kw)


def fused_sgdm8(p, g, mu_q, mu_s, scalars, **kw):
    kw.setdefault("interpret", _interpret())
    return _fused_sgdm8(p, g, mu_q, mu_s, scalars, **kw)


def paged_attention(q, k_pool, v_pool, pages, lengths, **kw):
    """Paged-KV decode attention — the serve path's kernel route
    (``attention_decode_paged``); the page table is scalar-prefetched so the
    per-row page gather never materializes."""
    kw.setdefault("interpret", _interpret())
    return _paged_attention(q, k_pool, v_pool, pages, lengths, **kw)


def flash_attention(q, k, v, causal=True, window=None, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash_attention(q, k, v, causal=causal, window=window, **kw)


def ssm_scan(u, dt, B, C, A, D, h0=None, **kw):
    kw.setdefault("interpret", _interpret())
    return _ssm_scan(u, dt, B, C, A, D, h0=h0, **kw)


def cka_gram(X, Y, **kw):
    kw.setdefault("interpret", _interpret())
    return _cka_gram(X, Y, **kw)
