"""FOAT's measurement primitive: centered linear-CKA HSIC terms.

    hxy = ‖XᵀY‖_F² = Σ_ij (XXᵀ)_ij (YYᵀ)_ij ,  hxx, hyy analogous.

TPU adaptation: the naive form materialises (d×d) cross-covariances
(d ≤ 8192 → 256 MB — far beyond VMEM).  We instead accumulate the two n×n
Gram matrices (n = CKA sample count, ≤ a few hundred) in VMEM scratch while
streaming feature blocks from HBM once, then reduce the three Frobenius
inner products in the final grid step.  Activations are read exactly once.

Grid: (d / bd,) sequential; scratch: Kx, Ky (n, n) float32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, y_ref, o_ref, kx_sc, ky_sc, *, n_blocks):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        kx_sc[...] = jnp.zeros_like(kx_sc)
        ky_sc[...] = jnp.zeros_like(ky_sc)

    xb = x_ref[...].astype(jnp.float32)        # (n, bd)
    yb = y_ref[...].astype(jnp.float32)
    kx_sc[...] += jnp.dot(xb, xb.T, preferred_element_type=jnp.float32)
    ky_sc[...] += jnp.dot(yb, yb.T, preferred_element_type=jnp.float32)

    @pl.when(i == n_blocks - 1)
    def _final():
        kx, ky = kx_sc[...], ky_sc[...]
        o_ref[0, 0] = jnp.sum(kx * ky)
        o_ref[0, 1] = jnp.sum(kx * kx)
        o_ref[0, 2] = jnp.sum(ky * ky)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def cka_gram(X, Y, bd=512, interpret=True):
    """X: (n, d1), Y: (n, d2), columns centered.  Returns (hxy, hxx, hyy).
    d1/d2 are zero-padded to a common multiple of bd (zero columns do not
    change Gram matrices)."""
    n = X.shape[0]
    d = max(X.shape[1], Y.shape[1])
    bd = min(bd, d)
    d_pad = ((d + bd - 1) // bd) * bd
    Xp = jnp.pad(X, ((0, 0), (0, d_pad - X.shape[1])))
    Yp = jnp.pad(Y, ((0, 0), (0, d_pad - Y.shape[1])))
    n_blocks = d_pad // bd
    out = pl.pallas_call(
        functools.partial(_kernel, n_blocks=n_blocks),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((n, bd), lambda i: (0, i)),
            pl.BlockSpec((n, bd), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 3), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32),
                        pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(Xp, Yp)
    return out[0, 0], out[0, 1], out[0, 2]
