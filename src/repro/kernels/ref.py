"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth for
the per-kernel allclose sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

ACTS = {"gelu": jax.nn.gelu, "relu": jax.nn.relu, "silu": jax.nn.silu}


def fused_adapter_ref(h, w_down, w_up, activation="gelu"):
    """h: (T, d); w_down: (d, r); w_up: (r, d)."""
    z = ACTS[activation](h.astype(jnp.float32) @ w_down.astype(jnp.float32))
    return (h.astype(jnp.float32) + z @ w_up.astype(jnp.float32)).astype(h.dtype)


def flash_attention_ref(q, k, v, causal=True, window=None):
    """q: (B, H, Sq, hd); k/v: (B, H, Sk, hd) (GQA folded outside)."""
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(hd)
    if causal:
        Sq, Sk = q.shape[2], k.shape[2]
        i = jnp.arange(Sq)[:, None] + (Sk - Sq)
        j = jnp.arange(Sk)[None, :]
        ok = j <= i
        if window is not None:
            ok = ok & (i - j < window)
        s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(u, dt, B, C, A, D, h0=None):
    """Sequential selective scan (the definitional recurrence).
    u/dt: (Bt, S, d); B/C: (Bt, S, N); A: (d, N); D: (d,)."""
    Bt, S, d = u.shape
    N = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bt, d, N), jnp.float32)
    uf, dtf = u.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)

    def step(h, xs):
        ut, dtt, bt, ct = xs
        a = jnp.exp(dtt[..., None] * A)                       # (Bt,d,N)
        h = a * h + (dtt * ut)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct) + D * ut
        return h, y

    h, ys = jax.lax.scan(step, h0, (jnp.moveaxis(uf, 1, 0), jnp.moveaxis(dtf, 1, 0),
                                    jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), h


def cka_gram_ref(X, Y):
    """Centered linear-kernel HSIC terms via n×n Grams.
    X: (n, d1), Y: (n, d2) — columns already centered.
    Returns (hxy, hxx, hyy) with hxy = ||XᵀY||_F² = Σ_ij Kx_ij·Ky_ij."""
    Xf, Yf = X.astype(jnp.float32), Y.astype(jnp.float32)
    Kx = Xf @ Xf.T
    Ky = Yf @ Yf.T
    return (jnp.sum(Kx * Ky), jnp.sum(Kx * Kx), jnp.sum(Ky * Ky))
