"""Fused update+optimizer Pallas kernel (ISSUE 10 tentpole).

The unfused optimizer (``optim.base``) streams every trainable leaf through
HBM four times per local step — clip-scale the gradient, update ``mu``,
update ``nu``, apply the bias-corrected parameter update — and the vmapped
cohort program multiplies that by the client axis, which is exactly where
``bench_round`` shows the round hot path going memory-bound.  This kernel
performs the whole chain

    g ← g · clip_scale
    mu ← b1·mu + (1−b1)·g          nu ← b2·nu + (1−b2)·g²
    p  ← p − lr_t·(mû/(√ν̂+ε) + wd·p)

in ONE pass per leaf: each (bm, 128) tile of the flattened leaf is read
once, updated in VMEM and written once.  The int8 variant additionally
dequantizes/requantizes the moments *inside* the tile, so fp32 moments never
materialize in HBM — per-element traffic drops from 28 B (7 fp32 streams) to
~16 B, and resident optimizer state drops 4× (``optim.quant``).

Layout: leaves are flattened and zero-padded to ``(rows, 128)`` — the lane
dim matches both the TPU tile width and the quantization block, so one
kernel row IS one quant block and requantization is a row-local reduction.
AdamW's second moment is stored as ``√nu`` (requantized from the square
root, squared after dequant): linear absmax on ``nu`` itself has a dead
zone of ``max/254`` that zeroes every small second moment in a block, and
the ``1/(√ν̂+ε)`` preconditioner then blows those coordinates up — in
sqrt-space the dead zone is ``(max/254)²`` in value terms and the int8
trajectory tracks fp32 (the same reason production 8-bit Adam uses a
nonlinear quantization map for ``nu``).
The four traced scalars (clip scale, lr_t, bias corrections) ride a single
``(1, 128)`` operand broadcast to every grid step.  Per-row fp32 scales ride
``(bm, 1)`` blocks — interpret-mode exact; a Mosaic build would pad them to
the (8, 128) min tile or scalar-prefetch them.

Inference-only contract: the kernel runs post-grad (no custom VJP — nothing
differentiates through an optimizer step).  ``*_ref`` are the XLA
single-pass fallbacks with identical op ordering — the non-TPU path and the
parity oracle for the kernel tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


# ------------------------------------------------------------ tiling helpers
def _to_rows(x, bm):
    """Flatten + zero-pad a leaf to ``(R, LANE)`` with R a multiple of bm."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % (bm * LANE)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANE), n


def _from_rows(rows, n, shape):
    return rows.reshape(-1)[:n].reshape(shape)


def row_block(n: int) -> int:
    """Rows per grid step: cover small leaves in one program, cap tile size
    at 256·128 fp32 ≈ 128 KB so in+out streams sit comfortably in VMEM."""
    rows = (n + LANE - 1) // LANE
    return max(8, min(256, ((rows + 7) // 8) * 8))


def pack_scalars(scale, lr_t, bc1, bc2):
    """The traced per-step scalars as one (1, LANE) operand (first four
    lanes; the rest is padding so the operand is lane-aligned)."""
    sc = jnp.zeros((1, LANE), jnp.float32)
    return sc.at[0, :4].set(jnp.stack([
        jnp.asarray(scale, jnp.float32), jnp.asarray(lr_t, jnp.float32),
        jnp.asarray(bc1, jnp.float32), jnp.asarray(bc2, jnp.float32)]))


# ============================================================== fp32 kernels
def _adamw_kernel(sc_ref, p_ref, g_ref, mu_ref, nu_ref,
                  op_ref, omu_ref, onu_ref, *, b1, b2, eps, wd):
    s, lr = sc_ref[0, 0], sc_ref[0, 1]
    bc1, bc2 = sc_ref[0, 2], sc_ref[0, 3]
    g = g_ref[...].astype(jnp.float32) * s
    m = b1 * mu_ref[...] + (1 - b1) * g
    v = b2 * nu_ref[...] + (1 - b2) * jnp.square(g)
    p = p_ref[...].astype(jnp.float32)
    new_p = p - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * p)
    op_ref[...] = new_p.astype(op_ref.dtype)
    omu_ref[...] = m
    onu_ref[...] = v


def _sgdm_kernel(sc_ref, p_ref, g_ref, mu_ref, op_ref, omu_ref, *, momentum):
    s, lr = sc_ref[0, 0], sc_ref[0, 1]
    g = g_ref[...].astype(jnp.float32) * s
    m = momentum * mu_ref[...] + g
    op_ref[...] = (p_ref[...].astype(jnp.float32) - lr * m
                   ).astype(op_ref.dtype)
    omu_ref[...] = m


# ============================================================== int8 kernels
def _requant_rows(x):
    """Row-wise absmax int8 requantization — one quant block per row."""
    s = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0
    q = jnp.round(x * jnp.where(s > 0, 1.0 / s, 0.0)).astype(jnp.int8)
    return q, s


def _adamw8_kernel(sc_ref, p_ref, g_ref, muq_ref, mus_ref, nuq_ref, nus_ref,
                   op_ref, omuq_ref, omus_ref, onuq_ref, onus_ref,
                   *, b1, b2, eps, wd):
    s, lr = sc_ref[0, 0], sc_ref[0, 1]
    bc1, bc2 = sc_ref[0, 2], sc_ref[0, 3]
    g = g_ref[...].astype(jnp.float32) * s
    m = muq_ref[...].astype(jnp.float32) * mus_ref[...]   # dequant in-tile
    # nu is stored as √nu (see module doc): linear absmax on nu itself
    # zeroes every second moment below max/254, and 1/√ν̂ then explodes
    v = jnp.square(nuq_ref[...].astype(jnp.float32) * nus_ref[...])
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    p = p_ref[...].astype(jnp.float32)
    new_p = p - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * p)
    op_ref[...] = new_p.astype(op_ref.dtype)
    omuq_ref[...], omus_ref[...] = _requant_rows(m)       # requant in-tile
    onuq_ref[...], onus_ref[...] = _requant_rows(jnp.sqrt(v))


def _sgdm8_kernel(sc_ref, p_ref, g_ref, muq_ref, mus_ref,
                  op_ref, omuq_ref, omus_ref, *, momentum):
    s, lr = sc_ref[0, 0], sc_ref[0, 1]
    g = g_ref[...].astype(jnp.float32) * s
    m = momentum * (muq_ref[...].astype(jnp.float32) * mus_ref[...]) + g
    op_ref[...] = (p_ref[...].astype(jnp.float32) - lr * m
                   ).astype(op_ref.dtype)
    omuq_ref[...], omus_ref[...] = _requant_rows(m)


# ================================================================= wrappers
def _row_spec(bm):
    return pl.BlockSpec((bm, LANE), lambda i: (i, 0))


def _scale_spec(bm):
    return pl.BlockSpec((bm, 1), lambda i: (i, 0))


def _sc_spec():
    return pl.BlockSpec((1, LANE), lambda i: (0, 0))


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "wd", "interpret",
                                    "bm"))
def fused_adamw(p, g, mu, nu, scalars, b1=0.9, b2=0.999, eps=1e-8, wd=0.01,
                interpret=True, bm=None):
    """One fused AdamW step on one leaf (fp32 moments).

    ``scalars`` is :func:`pack_scalars`' (1, 128) operand; returns
    ``(new_p, new_mu, new_nu)`` in the leaf's shape/dtypes."""
    bm = bm or row_block(p.size)
    p2, n = _to_rows(p, bm)
    g2, _ = _to_rows(g, bm)
    mu2, _ = _to_rows(mu, bm)
    nu2, _ = _to_rows(nu, bm)
    grid = (p2.shape[0] // bm,)
    op, omu, onu = pl.pallas_call(
        functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps, wd=wd),
        grid=grid,
        in_specs=[_sc_spec()] + [_row_spec(bm)] * 4,
        out_specs=[_row_spec(bm)] * 3,
        out_shape=[jax.ShapeDtypeStruct(p2.shape, p.dtype),
                   jax.ShapeDtypeStruct(p2.shape, jnp.float32),
                   jax.ShapeDtypeStruct(p2.shape, jnp.float32)],
        interpret=interpret,
    )(scalars, p2, g2, mu2, nu2)
    return (_from_rows(op, n, p.shape), _from_rows(omu, n, mu.shape),
            _from_rows(onu, n, nu.shape))


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "wd", "interpret",
                                    "bm"))
def fused_adamw8(p, g, mu_q, mu_s, nu_q, nu_s, scalars, b1=0.9, b2=0.999,
                 eps=1e-8, wd=0.01, interpret=True, bm=None):
    """Fused AdamW step with int8 block-quantized moments: dequant → update
    → requant inside the tile.  ``mu_q``/``nu_q`` are int8 in the leaf
    shape, ``mu_s``/``nu_s`` fp32 ``(n_blocks,)`` (``optim.quant`` layout —
    one 128-wide block per kernel row).  Returns
    ``(new_p, mu_q', mu_s', nu_q', nu_s')``."""
    bm = bm or row_block(p.size)
    p2, n = _to_rows(p, bm)
    g2, _ = _to_rows(g, bm)
    muq2, _ = _to_rows(mu_q, bm)
    nuq2, _ = _to_rows(nu_q, bm)
    rows = p2.shape[0]
    nb = mu_s.shape[0]
    mus2 = jnp.pad(mu_s, (0, rows - nb)).reshape(rows, 1)
    nus2 = jnp.pad(nu_s, (0, rows - nb)).reshape(rows, 1)
    grid = (rows // bm,)
    op, omuq, omus, onuq, onus = pl.pallas_call(
        functools.partial(_adamw8_kernel, b1=b1, b2=b2, eps=eps, wd=wd),
        grid=grid,
        in_specs=[_sc_spec(), _row_spec(bm), _row_spec(bm), _row_spec(bm),
                  _scale_spec(bm), _row_spec(bm), _scale_spec(bm)],
        out_specs=[_row_spec(bm), _row_spec(bm), _scale_spec(bm),
                   _row_spec(bm), _scale_spec(bm)],
        out_shape=[jax.ShapeDtypeStruct(p2.shape, p.dtype),
                   jax.ShapeDtypeStruct(p2.shape, jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct(p2.shape, jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(scalars, p2, g2, muq2, mus2, nuq2, nus2)
    return (_from_rows(op, n, p.shape),
            _from_rows(omuq, n, p.shape), omus.reshape(-1)[:nb],
            _from_rows(onuq, n, p.shape), onus.reshape(-1)[:nb])


@functools.partial(jax.jit, static_argnames=("momentum", "interpret", "bm"))
def fused_sgdm(p, g, mu, scalars, momentum=0.9, interpret=True, bm=None):
    """One fused SGD-momentum step on one leaf (fp32 buffer)."""
    bm = bm or row_block(p.size)
    p2, n = _to_rows(p, bm)
    g2, _ = _to_rows(g, bm)
    mu2, _ = _to_rows(mu, bm)
    grid = (p2.shape[0] // bm,)
    op, omu = pl.pallas_call(
        functools.partial(_sgdm_kernel, momentum=momentum),
        grid=grid,
        in_specs=[_sc_spec()] + [_row_spec(bm)] * 3,
        out_specs=[_row_spec(bm)] * 2,
        out_shape=[jax.ShapeDtypeStruct(p2.shape, p.dtype),
                   jax.ShapeDtypeStruct(p2.shape, jnp.float32)],
        interpret=interpret,
    )(scalars, p2, g2, mu2)
    return _from_rows(op, n, p.shape), _from_rows(omu, n, mu.shape)


@functools.partial(jax.jit, static_argnames=("momentum", "interpret", "bm"))
def fused_sgdm8(p, g, mu_q, mu_s, scalars, momentum=0.9, interpret=True,
                bm=None):
    """Fused SGD-momentum step with an int8 block-quantized buffer."""
    bm = bm or row_block(p.size)
    p2, n = _to_rows(p, bm)
    g2, _ = _to_rows(g, bm)
    muq2, _ = _to_rows(mu_q, bm)
    rows = p2.shape[0]
    nb = mu_s.shape[0]
    mus2 = jnp.pad(mu_s, (0, rows - nb)).reshape(rows, 1)
    grid = (rows // bm,)
    op, omuq, omus = pl.pallas_call(
        functools.partial(_sgdm8_kernel, momentum=momentum),
        grid=grid,
        in_specs=[_sc_spec(), _row_spec(bm), _row_spec(bm), _row_spec(bm),
                  _scale_spec(bm)],
        out_specs=[_row_spec(bm), _row_spec(bm), _scale_spec(bm)],
        out_shape=[jax.ShapeDtypeStruct(p2.shape, p.dtype),
                   jax.ShapeDtypeStruct(p2.shape, jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(scalars, p2, g2, muq2, mus2)
    return (_from_rows(op, n, p.shape),
            _from_rows(omuq, n, p.shape), omus.reshape(-1)[:nb])


# ==================================================== XLA fallback reference
# Identical op ordering to the kernels — the non-TPU single-pass path (XLA
# fuses the whole elementwise chain into one loop) and the parity oracle.
def adamw_ref(p, g, mu, nu, scale, lr_t, bc1, bc2, b1=0.9, b2=0.999,
              eps=1e-8, wd=0.01):
    g = g.astype(jnp.float32) * scale
    m = b1 * mu + (1 - b1) * g
    v = b2 * nu + (1 - b2) * jnp.square(g)
    p32 = p.astype(jnp.float32)
    new_p = p32 - lr_t * ((m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * p32)
    return new_p.astype(p.dtype), m, v


def adamw8_ref(p, g, mu_q, mu_s, nu_q, nu_s, scale, lr_t, bc1, bc2, b1=0.9,
               b2=0.999, eps=1e-8, wd=0.01):
    from ..optim.quant import dequantize_blockwise, quantize_blockwise
    mu = dequantize_blockwise(mu_q, mu_s)
    nu = jnp.square(dequantize_blockwise(nu_q, nu_s))   # stored as √nu
    new_p, m, v = adamw_ref(p, g, mu, nu, scale, lr_t, bc1, bc2, b1, b2,
                            eps, wd)
    mq, ms = quantize_blockwise(m)
    vq, vs = quantize_blockwise(jnp.sqrt(v))
    return new_p, mq, ms, vq, vs


def sgdm_ref(p, g, mu, scale, lr_t, momentum=0.9):
    g = g.astype(jnp.float32) * scale
    m = momentum * mu + g
    return (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), m


def sgdm8_ref(p, g, mu_q, mu_s, scale, lr_t, momentum=0.9):
    from ..optim.quant import dequantize_blockwise, quantize_blockwise
    new_p, m = sgdm_ref(p, g, dequantize_blockwise(mu_q, mu_s), scale, lr_t,
                        momentum)
    mq, ms = quantize_blockwise(m)
    return new_p, mq, ms
