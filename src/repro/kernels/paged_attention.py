"""Paged-attention decode kernel (ISSUE 9 tentpole).

One-token decode over a **paged** KV pool: each batch row's keys/values live
in fixed-size pages scattered through a flat ``(P, page_size, KV, hd)`` pool,
with a per-row page list (``core.paging.PageTable``).  The XLA fallback
gathers every row's pages into a contiguous view first; this kernel is
gather-free, exactly like the tenant-routed adapter kernel
(``fused_adapter_tenants``): the page table rides as a **scalar-prefetch**
argument and drives the KV BlockSpec ``index_map``, so each grid step DMAs
one page straight from the pool — the ``(B, max_pages, page_size, ...)``
gather never materializes.

Grid: ``(B, max_pages)`` — row-major, pages of a row visited in order.
Online softmax (flash-decode style) accumulates across the page axis in VMEM
scratch: running row-max ``m``, normalizer ``l`` and the f32 output
accumulator; the normalized output is written once on a row's last page.
Pages beyond a row's length are masked token-wise (``pos >= length`` →
probability exactly 0 — masking is applied *after* the exp so an all-masked
page cannot pollute ``l`` through ``exp(-inf - (-inf)) = 1``).  Rows with
``length <= 0`` (parked serve slots) divide by a clamped normalizer and
output zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pages_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref):
    b = pl.program_id(0)
    p = pl.program_id(1)
    ps = k_ref.shape[1]

    @pl.when(p == 0)
    def _reset():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                       # (KV, G, hd)
    k = k_ref[0].astype(jnp.float32)                       # (ps, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    hd = q.shape[-1]
    # (KV, G, ps) scores for this page, one matmul per kv-head group
    s = jnp.einsum("kgh,skh->kgs", q, k,
                   preferred_element_type=jnp.float32) / \
        jnp.sqrt(jnp.float32(hd))

    valid = (p * ps + jax.lax.iota(jnp.int32, ps)) < len_ref[b]
    m_prev = m_ref[...]                                    # (KV, G)
    m_new = jnp.maximum(m_prev, jnp.max(
        jnp.where(valid[None, None, :], s, -1e30), axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    # mask AFTER the exp: an all-masked page keeps l/acc untouched
    pexp = jnp.where(valid[None, None, :],
                     jnp.exp(s - m_new[..., None]), 0.0)   # (KV, G, ps)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=-1)
    # (KV, G, hd) accumulator update: sum_s pexp[k,g,s] * v[s,k,h]
    pv = jnp.einsum("kgs,skh->kgh", pexp, v,
                    preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new

    @pl.when(p == pl.num_programs(1) - 1)
    def _write():
        l = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, pages, lengths, interpret=True):
    """Decode attention over paged KV.

    q: (B, KV, G, hd) — the current token's grouped query heads.
    k_pool / v_pool: (P, page_size, KV, hd) — the flat page pool.
    pages: (B, max_pages) int32 page ids, in token order; entries < 0 are
    unallocated (clamped here — the length mask hides them).
    lengths: (B,) int32 valid token counts (``idx + 1`` after the current
    token's KV write).  Returns (B, KV, G, hd) in q's dtype.
    """
    from jax.experimental.pallas import tpu as pltpu

    B, KV, G, hd = q.shape
    P, ps = k_pool.shape[0], k_pool.shape[1]
    mp = pages.shape[1]
    pages = jnp.clip(pages.astype(jnp.int32), 0, P - 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, mp),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, p, pg, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, ps, KV, hd),
                         lambda b, p, pg, ln: (pg[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, KV, hd),
                         lambda b, p, pg, ln: (pg[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, hd),
                               lambda b, p, pg, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),        # running max m
            pltpu.VMEM((KV, G), jnp.float32),        # normalizer l
            pltpu.VMEM((KV, G, hd), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(pages, lengths.astype(jnp.int32), q, k_pool, v_pool)
