"""Fused bottleneck adapter kernel — the paper's core primitive (Eq. 1):

    out = h + f(h · W_down) · W_up

CHAINFED executes adapters pervasively (every window layer + the whole GPO
auxiliary branch), so on TPU we fuse both projections, the activation and the
residual add into one VMEM pass: the hidden-state tile is read from HBM once
and written once, instead of 3 reads + 2 writes for the unfused sequence.

Tiling: grid over row blocks of the flattened (T, d) hidden state; both
bottleneck weights stay VMEM-resident (r ≤ 128 ⇒ ≤ 2·d·r·2B ≈ 4 MB at
d = 8192, bf16).  Row block bm is chosen so  bm·d (in+out) + 2·d·r  fits the
~16 MB v5e VMEM; all matmul dims are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTS = {"gelu": jax.nn.gelu, "relu": jax.nn.relu, "silu": jax.nn.silu}


def _kernel(h_ref, wd_ref, wu_ref, o_ref, *, activation):
    h = h_ref[...].astype(jnp.float32)
    z = _ACTS[activation](jnp.dot(h, wd_ref[...].astype(jnp.float32),
                                  preferred_element_type=jnp.float32))
    o_ref[...] = (h + jnp.dot(z, wu_ref[...].astype(jnp.float32),
                              preferred_element_type=jnp.float32)
                  ).astype(o_ref.dtype)


def row_block(d: int, dtype_bytes: int, rank: int = 128,
              vmem_budget: int = 12 * 2 ** 20) -> int:
    """Largest 8-multiple row block whose in+out tiles fit the VMEM budget
    *after* subtracting the resident bottleneck weights (2·d·rank·bytes —
    both projections stay VMEM-resident across the whole grid).
    ``dtype_bytes`` is the actual element size of the hidden-state dtype
    (2 for bf16, 4 for f32) — callers pass ``h.dtype.itemsize``."""
    resident = 2 * d * rank * dtype_bytes
    avail = max(0, vmem_budget - resident)
    bm = avail // max(1, 2 * d * dtype_bytes)
    return max(8, min(512, (bm // 8) * 8))


@functools.partial(jax.jit, static_argnames=("activation", "interpret", "bm"))
def fused_adapter(h, w_down, w_up, activation="gelu", interpret=True, bm=None):
    """h: (T, d) or (..., d) — leading dims flattened; returns same shape."""
    shape = h.shape
    d = shape[-1]
    h2 = h.reshape(-1, d)
    T = h2.shape[0]
    bm = bm or row_block(d, h2.dtype.itemsize, rank=w_down.shape[1])
    bm = min(bm, T)
    pad = (-T) % bm
    if pad:
        h2 = jnp.pad(h2, ((0, pad), (0, 0)))
    grid = (h2.shape[0] // bm,)
    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d, w_down.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((w_up.shape[0], d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(h2.shape, h.dtype),
        interpret=interpret,
    )(h2, w_down, w_up)
    if pad:
        out = out[:T]
    return out.reshape(shape)


# ------------------------------------------------------------- serving path
def _tenant_kernel(ids_ref, h_ref, wd_ref, wu_ref, o_ref, *, activation):
    # wd/wu blocks were already routed to this row's tenant by the index_map;
    # ids_ref is only consumed there
    h = h_ref[0].astype(jnp.float32)
    z = _ACTS[activation](jnp.dot(h, wd_ref[0].astype(jnp.float32),
                                  preferred_element_type=jnp.float32))
    o_ref[0] = (h + jnp.dot(z, wu_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
                ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "interpret"))
def fused_adapter_tenants(h, tenant_ids, w_down, w_up, activation="gelu",
                          interpret=True):
    """Multi-tenant fused adapter: row b of ``h`` (B, S, d) runs tenant
    ``tenant_ids[b]``'s bottleneck from the stacked weights ``w_down``
    (T, d, r) / ``w_up`` (T, r, d).

    The grid is one program per batch row; ``tenant_ids`` is a
    scalar-prefetch argument, so each row's weight blocks are DMA'd straight
    from the library stack by the BlockSpec index_map — the per-row
    ``(B, d, r)`` weight gather that the XLA fallback materializes never
    exists here.  Tenant ids are data, not shapes: one compiled program
    serves every tenant mix of a batch."""
    from jax.experimental.pallas import tpu as pltpu

    B, S, d = h.shape
    r = w_down.shape[2]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S, d), lambda b, ids: (b, 0, 0)),
            pl.BlockSpec((1, d, r), lambda b, ids: (ids[b], 0, 0)),
            pl.BlockSpec((1, r, d), lambda b, ids: (ids[b], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, d), lambda b, ids: (b, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_tenant_kernel, activation=activation),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(h.shape, h.dtype),
        interpret=interpret,
    )(tenant_ids.astype(jnp.int32), h, w_down, w_up)


# -------------------------------------------------------------- training path
# pallas_call has no built-in reverse-mode rule, so the training forward uses
# a custom VJP: the fused kernel runs the forward (one HBM read + write of the
# hidden state), and backward recomputes the tiny bottleneck in plain XLA —
# standard dense math, cheap relative to the saved forward traffic.
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _fused_adapter_df(activation, interpret, bm, h, w_down, w_up):
    return fused_adapter(h, w_down, w_up, activation=activation,
                         interpret=interpret, bm=bm)


def _df_fwd(activation, interpret, bm, h, w_down, w_up):
    out = fused_adapter(h, w_down, w_up, activation=activation,
                        interpret=interpret, bm=bm)
    return out, (h, w_down, w_up)


def _df_bwd(activation, interpret, bm, res, g):
    h, wd, wu = res
    shape, d = h.shape, h.shape[-1]
    h2 = h.reshape(-1, d).astype(jnp.float32)
    g2 = g.reshape(-1, d).astype(jnp.float32)
    wd32, wu32 = wd.astype(jnp.float32), wu.astype(jnp.float32)
    z = h2 @ wd32
    a, act_vjp = jax.vjp(_ACTS[activation], z)
    gz = act_vjp(g2 @ wu32.T)[0]                       # (T, r)
    dh = (g2 + gz @ wd32.T).astype(h.dtype).reshape(shape)
    dwd = (h2.T @ gz).astype(wd.dtype)
    dwu = (a.T @ g2).astype(wu.dtype)
    return dh, dwd, dwu


_fused_adapter_df.defvjp(_df_fwd, _df_bwd)


def fused_adapter_grad(h, w_down, w_up, activation="gelu", interpret=True,
                       bm=None):
    """Differentiable fused adapter — the transformer forward's kernel path
    (``adapter_apply(use_kernel=True)``)."""
    return _fused_adapter_df(activation, interpret, bm, h, w_down, w_up)
