"""Activation-sharding hooks: a process-global policy consulted by the model
forward loop (models stay mesh-agnostic; the launch layer installs the
policy).  No-op by default — single-host tests and benchmarks never pay for
it."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

_POLICY: Optional["Policy"] = None


@dataclasses.dataclass
class Policy:
    mesh: object
    residual_spec_fn: object = None   # (ndim, seq_len) -> PartitionSpec
    logits_spec_fn: object = None     # (ndim,) -> PartitionSpec
    decode_q_spec_fn: object = None   # ((B,1,KV,G,hd)) -> PartitionSpec
    cache_entry_spec_fn: object = None  # ((B,S,KV,hd)) -> PartitionSpec

    def _apply(self, x, spec):
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    def constrain_residual(self, x):
        if self.residual_spec_fn is None:
            return x
        return self._apply(x, self.residual_spec_fn(x.ndim, x.shape[-2]))

    def constrain_logits(self, x):
        if self.logits_spec_fn is None:
            return x
        return self._apply(x, self.logits_spec_fn(x.ndim))

    def constrain_decode_q(self, x):
        if self.decode_q_spec_fn is None:
            return x
        return self._apply(x, self.decode_q_spec_fn(x.shape))

    def constrain_cache_entry(self, x):
        if self.cache_entry_spec_fn is None:
            return x
        return self._apply(x, self.cache_entry_spec_fn(x.shape))


def set_policy(policy: Optional[Policy]):
    global _POLICY
    _POLICY = policy


def constrain_residual(x):
    if _POLICY is None:
        return x
    return _POLICY.constrain_residual(x)


def constrain_logits(x):
    if _POLICY is None:
        return x
    return _POLICY.constrain_logits(x)


def constrain_decode_q(x):
    if _POLICY is None:
        return x
    return _POLICY.constrain_decode_q(x)


def constrain_cache_entry(x):
    if _POLICY is None:
        return x
    return _POLICY.constrain_cache_entry(x)
