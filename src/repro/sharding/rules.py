"""GSPMD partition rules for every pytree in the system.

Scheme (DESIGN §3): batch/cohorts on (pod, data); Megatron tensor parallel on
``model`` (attention head projections, d_ff, experts, mamba d_inner, vocab);
decode KV caches batch- + (KV-or-head_dim)-sharded; dims that don't divide
the axis fall back to replication (``maybe``).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from .hooks import set_policy, Policy  # noqa: F401  (re-export for launch)


def _maybe(dim: int, axis, axes_size: int):
    """Shard only when the dim divides the axis extent."""
    return axis if dim % axes_size == 0 and dim > 0 else None


class Ruleset:
    def __init__(self, mesh, cfg: ModelConfig, seq_shard: bool = False):
        self.mesh, self.cfg = mesh, cfg
        self.dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        self.tp = "model"
        self.dp_size = 1
        for a in self.dp:
            self.dp_size *= mesh.shape[a]
        self.tp_size = mesh.shape["model"]
        self.seq_shard = seq_shard

    # ------------------------------------------------------------ leaves
    def param_spec(self, path: str, shape) -> P:
        c, t = self.cfg, self.tp
        ts = self.tp_size
        stacked = path.startswith(("layers/", "enc_layers/"))
        lead = (None,) if stacked else ()
        parts = path.split("/")
        name = "/".join(parts[1:]) if stacked else path

        def spec(*dims):
            return P(*(lead + dims))

        if path == "embed/table":
            return P(_maybe(shape[0], t, ts), None)
        if "norm" in parts[-2] or parts[-1] in ("scale", "bias") and "norm" in path:
            return P(*((None,) * len(shape)))
        # attention projections
        if name in ("attn/q/w", "attn/k/w", "attn/v/w", "cross/q/w",
                    "cross/k/w", "cross/v/w"):
            return spec(None, _maybe(shape[-1], t, ts))
        if name in ("attn/q/b", "attn/k/b", "attn/v/b", "cross/q/b",
                    "cross/k/b", "cross/v/b"):
            return spec(_maybe(shape[-1], t, ts))
        if name in ("attn/o/w", "cross/o/w"):
            return spec(_maybe(shape[-2], t, ts), None)
        # dense mlp / shared experts
        if name.endswith(("ffn/up/w", "ffn/gate/w")) or "/shared/" in name and name.endswith(("up/w", "gate/w")):
            return spec(None, _maybe(shape[-1], t, ts))
        if name.endswith("ffn/down/w") or ("/shared/" in name and name.endswith("down/w")):
            return spec(_maybe(shape[-2], t, ts), None)
        # router / experts
        if name.endswith("router/w"):
            return spec(None, None)
        if "experts/" in name:   # (L, E, d, f) or (L, E, f, d)
            return spec(_maybe(shape[-3], t, ts), None, None)
        # mamba mixer (also hybrid 'ssm/')
        if name.endswith(("mixer/in_proj/w", "ssm/in_proj/w")):
            return spec(None, _maybe(shape[-1], t, ts))
        if name.endswith(("mixer/conv_w", "ssm/conv_w")):
            return spec(None, _maybe(shape[-1], t, ts))
        if name.endswith(("mixer/conv_b", "ssm/conv_b", "mixer/D", "ssm/D",
                          "mixer/dt_proj/b", "ssm/dt_proj/b")):
            return spec(_maybe(shape[-1], t, ts))
        if name.endswith(("mixer/x_proj/w", "ssm/x_proj/w", "mixer/out_proj/w",
                          "ssm/out_proj/w", "mixer/A_log", "ssm/A_log")):
            return spec(_maybe(shape[-2], t, ts), None)
        if name.endswith(("mixer/dt_proj/w", "ssm/dt_proj/w")):
            return spec(None, _maybe(shape[-1], t, ts))
        # fallback: replicate
        return P(*((None,) * len(shape)))

    def adapter_spec(self, path: str, shape) -> P:
        ts = self.tp_size
        if path.endswith("down"):       # (L, d, r)
            return P(None, _maybe(shape[1], self.tp, ts), None)
        return P(None, None, _maybe(shape[2], self.tp, ts))   # up (L, r, d)

    # ------------------------------------------------------------ trees
    def _tree_specs(self, tree, fn):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs = []
        for path, leaf in flat:
            p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            specs.append(fn(p, leaf.shape))
        return jax.tree_util.tree_unflatten(treedef, specs)

    def params(self, abstract_params):
        return self._tree_specs(abstract_params, self.param_spec)

    def adapters(self, abstract_adapters):
        return self._tree_specs(abstract_adapters, self.adapter_spec)

    # ------------------------------------------------------------ batches
    def batch_spec(self, shape, has_cohorts: bool) -> P:
        """tokens (C, ls, b, S) / (B, S) / embeds (+d) / positions (3, B, S)."""
        n = len(shape)
        lead = _maybe(shape[0], self.dp, self.dp_size)
        rest = (None,) * (n - 1)
        return P(lead, *rest)

    def train_batch(self, batch_tree):
        # all train-batch leaves lead with the cohort axis C (M-RoPE positions
        # use layout (C, ls, 3, b, S))
        return self._tree_specs(batch_tree,
                                lambda _p, shape: self.batch_spec(shape, True))

    # ------------------------------------------------------------ caches
    def cache_spec(self, path: str, shape) -> P:
        """Stacked decode caches:
        k/v (L, B, S, KV, hd): batch→dp, then KV→model if divisible else
        hd→model (the hd contraction becomes the flash-decode all-reduce);
        conv (L, B, W-1, di): di→model;  h (L, B, di, N): di→model."""
        ts = self.tp_size
        b_ax = _maybe(shape[1], self.dp, self.dp_size)
        leaf = path.split("/")[-1]
        if leaf in ("k", "v", "ck", "cv"):
            kv_ax = _maybe(shape[3], self.tp, ts)
            hd_ax = _maybe(shape[4], self.tp, ts) if kv_ax is None else None
            return P(None, b_ax, None, kv_ax, hd_ax)
        if leaf == "conv":
            return P(None, b_ax, None, _maybe(shape[3], self.tp, ts))
        if leaf == "h":
            return P(None, b_ax, _maybe(shape[2], self.tp, ts), None)
        return P(*((None,) * len(shape)))

    def cache(self, abstract_cache):
        return self._tree_specs(abstract_cache, self.cache_spec)

    # ------------------------------------------------------------ activations
    def residual_spec(self, ndim: int, seq_len: int = 0) -> P:
        """(B, S, d) or (C, b, S, d) residual-stream constraint between
        blocks.  seq_shard=True adds Megatron-style sequence parallelism."""
        seq_ax = (self.tp if (self.seq_shard and seq_len % self.tp_size == 0
                              and seq_len > 1) else None)
        if ndim == 3:
            return P(self.dp or None, seq_ax, None)
        return P(self.dp or None, None, seq_ax, None)

    def cache_entry_spec(self, shape) -> P:
        """Per-layer cache entry inside the decode layer-scan: (B, S, KV, hd)
        — same policy as cache_spec minus the stacked L dim."""
        ts = self.tp_size
        b_ax = _maybe(shape[0], self.dp, self.dp_size)
        if len(shape) == 4:
            kv_ax = _maybe(shape[2], self.tp, ts)
            hd_ax = _maybe(shape[3], self.tp, ts) if kv_ax is None else None
            return P(b_ax, None, kv_ax, hd_ax)
        return P(*((b_ax,) + (None,) * (len(shape) - 1)))

    def decode_q_spec(self, shape) -> P:
        """Decode query (B, 1, KV, G, hd): mirror the cache contraction layout
        so the scores dot is a partial-sum + all-reduce instead of a GSPMD
        'involuntary full rematerialization' of the cache (§Perf iteration)."""
        ts = self.tp_size
        b_ax = _maybe(shape[0], self.dp, self.dp_size)
        kv_ax = _maybe(shape[2], self.tp, ts)
        hd_ax = _maybe(shape[4], self.tp, ts) if kv_ax is None else None
        return P(b_ax, None, kv_ax, None, hd_ax)

    def logits_spec(self, ndim: int) -> P:
        """Vocab-sharded logits: only the trailing V dim is pinned to model —
        batch/seq dims inherit upstream sharding (the constraint is applied
        inside vmap'd cohort traces, where pinning batch dims would fight the
        cohort sharding).  GSPMD inserts the distributed-softmax reductions."""
        return P(*((None,) * (ndim - 1) + (self.tp,)))

    def named(self, spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))
