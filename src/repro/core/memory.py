"""Analytic peak-memory model (paper §3.2 Fig. 3, §5.4 Fig. 8, Table 3).

Reproduces the paper's memory accounting: base parameters dominate (>90%),
activations and adapter state are secondary; CHAINFED's chain paradigm bounds
the live set to [executed prefix streaming + DLCT window + adapter states of
the window].  Used by the memory-aware client sampler (the "memory wall" that
excludes low-end devices) and by the memory benchmarks.
"""
from __future__ import annotations

from ..models.config import ModelConfig

BYTES = {"float32": 4, "bfloat16": 2, "float16": 2}


def _b(cfg: ModelConfig) -> int:
    return BYTES[cfg.param_dtype]


def layer_param_count(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.head_dim_
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    glu = 3 if cfg.activation in ("swiglu", "geglu") else 2
    ffn = glu * d * cfg.d_ff
    norms = 2 * d
    if cfg.family == "ssm":
        di = cfg.d_inner
        mamba = (d * 2 * di + cfg.ssm_conv_width * di
                 + di * (cfg.dt_rank + 2 * cfg.ssm_state)
                 + cfg.dt_rank * di + di * cfg.ssm_state + di + di * d)
        return mamba + d
    if cfg.family == "hybrid":
        di = cfg.d_inner
        mamba = (d * 2 * di + cfg.ssm_conv_width * di
                 + di * (cfg.dt_rank + 2 * cfg.ssm_state)
                 + cfg.dt_rank * di + di * cfg.ssm_state + di + di * d)
        return attn + mamba + ffn + 4 * d
    if cfg.family == "moe":
        experts = cfg.n_experts * 3 * d * cfg.expert_d_ff
        shared = cfg.n_shared_experts * 3 * d * cfg.expert_d_ff
        router = d * cfg.n_experts
        return attn + experts + shared + router + norms
    if cfg.family == "encdec":
        return attn + ffn + norms  # decoder adds cross-attn, handled in total
    return attn + ffn + norms


def total_param_count(cfg: ModelConfig) -> int:
    emb = cfg.padded_vocab * cfg.d_model
    n = cfg.n_layers * layer_param_count(cfg)
    if cfg.is_encdec:
        d, hd = cfg.d_model, cfg.head_dim_
        cross = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads + cfg.n_heads)
        n += cfg.n_encoder_layers * layer_param_count(cfg) + cfg.n_layers * cross
    return emb + n + cfg.d_model


def adapter_param_count(cfg: ModelConfig, n_layers=None) -> int:
    n = n_layers if n_layers is not None else cfg.total_chain_layers
    return n * 2 * cfg.d_model * cfg.adapter.rank


def activation_bytes_per_layer(cfg: ModelConfig, batch: int, seq: int) -> int:
    """Saved-for-backward footprint per layer (inputs + attn/ffn intermediates
    under input-saving remat ≈ 4·B·S·d)."""
    return 4 * batch * seq * cfg.d_model * _b(cfg)


def optimizer_state_bytes(n_params: int, opt_bits: int = 32,
                          optimizer: str = "adamw", qblock: int = 128,
                          include_scales: bool = True) -> int:
    """Resident optimizer *moment* bytes for ``n_params`` trainable fp32
    parameters — the quantity ``opt_bits=8`` cuts 4×.

    fp32 AdamW holds two fp32 moment trees (8 B/param); the int8 path
    (``optim.quant``) holds two int8 trees — exactly 4× smaller — plus one
    fp32 scale per ``qblock``-element block (8 B per 128 params, ~3%;
    ``include_scales=False`` reports the payload alone).  SGD+momentum
    carries one moment tree, plain SGD none."""
    moments = {"adamw": 2, "sgd": 1}.get(optimizer, 2)
    if opt_bits == 32:
        return moments * 4 * n_params
    if opt_bits == 8:
        blocks = (n_params + qblock - 1) // qblock
        return moments * (n_params
                          + (4 * blocks if include_scales else 0))
    raise ValueError(f"opt_bits must be 32 or 8, got {opt_bits!r}")


def _opt_mult(opt_bits: int) -> float:
    """Trainable-state multiplier over the fp32 params themselves: grads
    (1×) + AdamW moments + fp32 master copy (1×).  Moments are 2× at fp32;
    at int8 they shrink to ``optimizer_state_bytes / (4·n)`` ≈ 0.53× — the
    resident-cohort ceiling the fused int8 kernel buys back."""
    if opt_bits == 32:
        return 4.0                   # the historical opt_mult
    return 2.0 + optimizer_state_bytes(128 * 1024, opt_bits) / (4.0
                                                                * 128 * 1024)


def peak_memory(cfg: ModelConfig, method: str, batch: int, seq: int,
                window: int = 3, l_start: int = 0, lora_rank: int = 8,
                layer_offload: bool = True, keep_layers: int = 0,
                opt_bits: int = 32) -> dict:
    """Returns {params, activations, adapter_state, total} bytes for a local
    client step under each method's execution model."""
    b = _b(cfg)
    L = cfg.total_chain_layers
    p_layer = layer_param_count(cfg) * b
    p_emb = (cfg.padded_vocab * cfg.d_model + cfg.d_model) * b
    p_all = total_param_count(cfg) * b
    a_layer = activation_bytes_per_layer(cfg, batch, seq)
    ad_layer = 2 * cfg.d_model * cfg.adapter.rank * b
    # grads + AdamW m/v + fp32 master ≈ 4× trainable params at fp32 moments;
    # opt_bits=8 shrinks the m/v share 4× (see optimizer_state_bytes)
    opt_mult = _opt_mult(opt_bits)

    if method in ("full_adapters", "fedadapter", "c2a", "flora"):
        rank = lora_rank if method == "flora" else cfg.adapter.rank
        ad = 2 * cfg.d_model * rank * b * L
        return _pack(p_all, a_layer * L, ad * (1 + opt_mult))
    if method == "linear_probing":
        # small task classifier (paper: output layer only), not the full
        # tied-vocab head
        head = 128 * cfg.d_model * b
        return _pack(p_all, a_layer, head * opt_mult)
    if method == "fedembed":
        # embedding tuning: backprop reaches the input embedding, so every
        # layer's activations are saved; optimizer state on the table
        return _pack(p_all, a_layer * L, p_emb * opt_mult)
    if method in ("fwdllm", "fedkseed"):
        # zeroth-order: no activation storage; FwdLLM perturbs adapters only
        extra = ad_layer * L * 2 if method == "fwdllm" else 0
        return _pack(p_all, a_layer, extra)
    if method == "fedra":
        # random subset of ~L/2 layers resident per client
        keep = max(1, L // 2)
        return _pack(p_emb + p_layer * keep, a_layer * keep,
                     ad_layer * keep * (1 + opt_mult))
    if method == "layer_pruning":
        # a fixed retained subset: pruned layers are gone for the whole run,
        # so neither their params nor activations are ever resident
        keep = keep_layers or max(1, L // 2)
        return _pack(p_emb + p_layer * keep, a_layer * keep,
                     ad_layer * keep * (1 + opt_mult))
    if method == "layer_dropout":
        # per-round random retain: the full stack must stay on device (any
        # layer can wake next round) but only the active subset trains
        keep = keep_layers or max(1, L // 2)
        return _pack(p_all, a_layer * keep,
                     ad_layer * keep * (1 + opt_mult))
    if method == "chainfed":
        # prefix streams through (offload: one transient layer resident),
        # window fully resident with adapter training state, suffix never
        # executed (GPO aux branch = adapters only)
        resident = window + (1 if layer_offload else max(l_start, 0))
        if not layer_offload:
            resident = l_start + window
        suffix_ad = ad_layer * max(0, L - l_start - window)
        return _pack(p_emb + p_layer * resident,
                     a_layer * window,
                     ad_layer * window * (1 + opt_mult) + ad_layer * l_start + suffix_ad)
    raise ValueError(method)


def _pack(params, acts, ad):
    return {"params": int(params), "activations": int(acts),
            "adapter_state": int(ad), "total": int(params + acts + ad)}


def round_flops(cfg: ModelConfig, method: str, batch: int, seq: int,
                local_steps: int = 1, window: int = 3, l_start: int = 0,
                n_samples: int = 4, kseeds: int = 8,
                lora_rank: int = 8, keep_layers: int = 0) -> float:
    """Analytic FLOPs for one client's local round under each method's
    execution model — the compute half of the event-driven runtime's
    virtual-clock cost (``repro.fed.runtime``; the communication half is
    ``Strategy.comm_bytes_per_round`` over ``DeviceProfile.bandwidth``).

    The estimate is the standard 2·params·tokens forward cost with a 2×
    forward surcharge for the layers backprop traverses; zeroth-order
    methods pay forward passes only (2 per perturbation/seed), and
    CHAINFED's chain execution pays forward for prefix+window but backward
    for the window alone (the suffix is never executed)."""
    L = cfg.total_chain_layers
    tokens = batch * seq
    f_layer = 2.0 * layer_param_count(cfg) * tokens
    f_emb = 2.0 * (cfg.padded_vocab * cfg.d_model + cfg.d_model) * tokens
    f_full = 2.0 * total_param_count(cfg) * tokens

    if method in ("full_adapters", "fedadapter", "c2a", "flora", "fedembed"):
        step = 3.0 * f_full                      # fwd + bwd through all layers
    elif method == "linear_probing":
        step = f_full + 2.0 * f_emb              # bwd touches the head only
    elif method == "fwdllm":
        step = 2.0 * max(1, n_samples) * f_full  # antithetic forwards only
    elif method == "fedkseed":
        step = 2.0 * max(1, kseeds) * f_full     # 2 forwards per seed
    elif method == "fedra":
        keep = max(1, L // 2)
        step = 3.0 * (f_emb + keep * f_layer)    # resident half-chain fwd+bwd
    elif method in ("layer_pruning", "layer_dropout"):
        # dropped/pruned layers are skipped outright (residual passthrough):
        # forward + backward through the retained subset only
        keep = keep_layers or max(1, L // 2)
        step = 3.0 * (f_emb + keep * f_layer)
    elif method == "chainfed":
        run = min(L, max(0, l_start) + max(1, window))
        step = (f_emb + run * f_layer            # prefix+window forward
                + 2.0 * max(1, window) * f_layer)  # window-only backward
    else:
        raise ValueError(method)
    return float(step) * max(1, local_steps)


def comm_bytes_per_round(cfg: ModelConfig, method: str, window: int = 3,
                         l_start: int = 0, lora_rank: int = 8, kseeds: int = 0,
                         keep_layers: int = 0) -> int:
    """Uplink bytes per client per round (paper §H.2 communication claim).
    Payload only — the privacy machinery's overhead (secure-agg key
    agreement, DP metadata) is ``privacy_comm_overhead`` and composes in
    ``Strategy.comm_bytes_per_round``."""
    b = _b(cfg)
    L = cfg.total_chain_layers
    ad_layer = 2 * cfg.d_model * cfg.adapter.rank * b
    if method == "chainfed":
        return ad_layer * window
    if method == "fedkseed":
        return max(1, kseeds) * 8
    if method == "flora":
        return 2 * cfg.d_model * lora_rank * b * L
    if method == "linear_probing":
        return cfg.padded_vocab * cfg.d_model * b
    if method == "fedembed":
        # embedding table only — the task head is excluded by convention,
        # as for every other head-training method above
        return cfg.padded_vocab * cfg.d_model * b
    if method == "fedra":
        return ad_layer * (L // 2)
    if method in ("layer_pruning", "layer_dropout"):
        return ad_layer * (keep_layers or max(1, L // 2))
    return ad_layer * L   # full adapters / fedadapter / c2a / fwdllm


def fedkseed_total_comm(kseeds: int) -> int:
    """FedKSeed round-trip bytes per client per round: K fp64 coefficients
    up, the K-scalar aggregated coefficient history delta down — the model
    itself never crosses the link (``FedKSeed.replay`` reconstructs it from
    seeds + history).  The paper's "18 KB total communication" is this at
    K=1152: 16·1152 = 18432 B = 18 KiB exactly."""
    return 2 * max(1, kseeds) * 8


# ----------------------------------------------------------- serving memory
def _cb(cfg: ModelConfig) -> int:
    """Bytes per element of serve-time cache/activation state (KV lives in
    the compute dtype, not the param dtype)."""
    return BYTES[cfg.compute_dtype]


def serve_kv_bytes(cfg: ModelConfig, slots: int, horizon: int) -> int:
    """Dense slot-cache KV footprint of a ``serve()`` run: every slot pays
    the full decode horizon, whatever its request actually stores.
    Attention-free families (ssm) hold no KV."""
    if cfg.family == "ssm":
        return 0
    kv = 2 * cfg.n_kv_heads * cfg.head_dim_
    return cfg.n_layers * slots * horizon * kv * _cb(cfg)


def paged_kv_bytes(cfg: ModelConfig, n_pages: int, page_size: int) -> int:
    """Paged-pool KV footprint (``init_paged_cache``): the pool is sized by
    allocated pages, not ``slots × horizon`` — a long-tail request mix
    shrinks ``n_pages`` far below the dense worst case.  Pass the
    ``PageTable``'s ``peak_in_use`` for the high-water footprint actually
    touched by a run."""
    if cfg.family == "ssm":
        return 0
    kv = 2 * cfg.n_kv_heads * cfg.head_dim_
    return cfg.n_layers * n_pages * page_size * kv * _cb(cfg)


def resident_library_bytes(cfg: ModelConfig, n_resident: int) -> int:
    """Device bytes of the adapter library's resident set: ``n_resident``
    stacks (``AdapterLibrary.resident_capacity``, or the full tenant count
    without a host tier) of ``L`` bottleneck adapters each."""
    return n_resident * adapter_param_count(cfg) * _b(cfg)


def hierarchy_comm_bytes(payload: int, cohort: int, n_silos: int = 1) -> dict:
    """Per-commit traffic split across aggregation tiers (ISSUE 8).

    ``payload`` is one client's uplink bytes (``comm_bytes_per_round``).  In
    the flat topology every update crosses the WAN to the server; with
    ``n_silos`` edge aggregators each update only crosses the cheap edge
    link, and the WAN carries one pre-aggregated partial sum per silo that
    contributed to the commit — the backhaul shrinks from ``cohort`` to
    ``min(cohort, n_silos)`` payloads.  Returns ``{edge, silo, total}``
    bytes; ``edge`` is 0 in the flat topology (clients upload straight to
    the server, accounted under ``silo``/WAN).  The hierarchical case
    matches the scheduler's live ``tier_bytes`` accounting; the flat case
    is the WAN baseline it is compared against."""
    cohort = max(0, cohort)
    if n_silos <= 1:
        return {"edge": 0, "silo": payload * cohort, "total": payload * cohort}
    wan = payload * min(cohort, n_silos)
    return {"edge": payload * cohort, "silo": wan,
            "total": payload * cohort + wan}


def privacy_comm_overhead(cohort: int, secure: bool = False,
                          dp: bool = False, key_bytes: int = 32) -> int:
    """Per-client per-round uplink overhead of the privacy machinery.

    Secure aggregation (Bonawitz et al.): each client exchanges a DH public
    key and an encrypted pairwise-seed share with every other roster member
    at session setup, plus one secret share per peer for dropout recovery —
    ≈ 3 · (cohort − 1) · key_bytes.  DP adds a constant metadata record
    (clip bound + noise seed commitment, 16 B)."""
    total = 0
    if secure:
        total += max(0, cohort - 1) * 3 * key_bytes
    if dp:
        total += 16
    return total
