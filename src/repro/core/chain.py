"""CHAINFED chain-optimization core (paper §4, Algorithm 1).

Glues FOAT (boundary), DLCT (window schedule) and GPO (dual loss) into
jit-compiled stage steps.  Used by the single-host federated simulation
(benchmarks/examples) and mirrored by the pjit multi-pod step in
``repro/train/steps.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models.config import ChainConfig, ModelConfig
from ..models.transformer import ChainSegments, forward_chain, forward_full
from ..optim.base import make_optimizer
from ..train.losses import cross_entropy, gpo_loss, moe_penalty
from .dlct import ChainSchedule, make_schedule, window_scatter, window_slice


class ChainStage:
    """One chain stage = (window offset k, size Q): builds the jitted GPO
    local-update step.  Stages are cached per offset — the DLCT cyclic window
    reuses ≤ L compilations."""

    def __init__(self, cfg: ModelConfig, chain: ChainConfig, seg: ChainSegments):
        self.cfg, self.chain, self.seg = cfg, chain, seg
        self.final_stage = seg.prefix + seg.window >= cfg.total_chain_layers
        self.opt = make_optimizer(chain.optimizer, chain.lr)
        cfg_, lam, final = cfg, chain.lam, self.final_stage

        def loss_fn(trainable, params, full_ad, batch):
            # trainable = {"window": Q adapters, ["head": task head]}
            p = params if "head" not in trainable else {**params,
                                                        "cls_head": trainable["head"]}
            out = forward_chain(p, trainable["window"], full_ad, batch, cfg_, seg)
            loss, parts = gpo_loss(out, batch["labels"], cfg_, lam, final)
            return loss, parts

        @jax.jit
        def local_step(trainable, opt_state, params, full_ad, batch):
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                trainable, params, full_ad, batch)
            trainable, opt_state = self.opt.step(trainable, grads, opt_state)
            return trainable, opt_state, loss, parts

        self.local_step = local_step

    def init_opt(self, trainable):
        return self.opt.init(trainable)


class ChainFedTrainer:
    """Host-side CHAINFED driver: FOAT setup then staged federated rounds.

    The per-stage jit cache means window advances don't recompile once every
    offset has been visited (DESIGN §4)."""

    def __init__(self, cfg: ModelConfig, chain: ChainConfig, params, adapters):
        self.cfg, self.chain = cfg, chain
        self.params, self.adapters = params, adapters
        from ..models.transformer import init_cls_head
        self.head = init_cls_head(params) if chain.train_head else None
        self.l_start = 0
        self.schedule: ChainSchedule = make_schedule(cfg, 0, chain.window)
        self._stages = {}

    @property
    def eval_params(self):
        if self.head is None:
            return self.params
        return {**self.params, "cls_head": self.head}

    def set_params(self, params):
        """Swap in a (pretrained) base; re-derives the task head."""
        from ..models.transformer import init_cls_head
        self.params = params
        if self.head is not None:
            self.head = init_cls_head(params)

    # ---- Phase 1: pre-training setup (Algorithm 1, lines 1-3) ----
    def setup_foat(self, client_batches, weights=None):
        from .foat import run_foat
        self.l_start, scores = run_foat(self.params, self.adapters,
                                        client_batches, self.cfg,
                                        self.chain.foat_threshold, weights)
        self.schedule = make_schedule(self.cfg, self.l_start, self.chain.window)
        return self.l_start, scores

    def stage(self, round_idx: int) -> ChainStage:
        seg = self.schedule.segments(round_idx, self.chain.advance_every)
        if seg.prefix not in self._stages:
            self._stages[seg.prefix] = ChainStage(self.cfg, self.chain, seg)
        return self._stages[seg.prefix]

    # ---- Phase 2: one client's local update (Algorithm 1, lines 7-9) ----
    def client_update(self, round_idx: int, batches):
        stage = self.stage(round_idx)
        seg = stage.seg
        trainable0 = {"window": window_slice(self.adapters, seg)}
        if self.head is not None:
            trainable0["head"] = self.head
        trainable = trainable0
        opt_state = stage.init_opt(trainable)
        loss = parts = None
        for batch in batches:
            trainable, opt_state, loss, parts = stage.local_step(
                trainable, opt_state, self.params, self.adapters, batch)
        delta = jax.tree_util.tree_map(lambda w, w0: w - w0, trainable,
                                       trainable0)
        return delta, float(loss), parts

    # ---- server aggregation (Algorithm 1, line 11) ----
    def aggregate(self, round_idx: int, deltas, weights):
        seg = self.stage(round_idx).seg
        w = jnp.asarray(weights, jnp.float32)
        w = w / jnp.sum(w)
        agg = jax.tree_util.tree_map(
            lambda *ds: sum(wi * d for wi, d in zip(w, ds)), *deltas)
        window = jax.tree_util.tree_map(
            lambda full, d: full + d.astype(full.dtype),
            window_slice(self.adapters, seg), agg["window"])
        self.adapters = window_scatter(self.adapters, window, seg)
        if self.head is not None and "head" in agg:
            self.head = jax.tree_util.tree_map(
                lambda h, d: (h + d).astype(h.dtype), self.head, agg["head"])

    # ---- evaluation: end-to-end forward with all adapters ----
    @functools.cached_property
    def _eval_fn(self):
        cfg = self.cfg

        @jax.jit
        def ev(params, adapters, batch):
            logits, aux = forward_full(params, adapters, batch, cfg, remat=False)
            loss = cross_entropy(logits, batch["labels"]) + moe_penalty(aux, cfg)
            from ..train.losses import accuracy
            return loss, accuracy(logits, batch["labels"],
                                  batch.get("class_tokens"))

        return ev

    def evaluate(self, batch):
        loss, acc = self._eval_fn(self.eval_params, self.adapters, batch)
        return float(loss), float(acc)
