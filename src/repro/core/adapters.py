"""Bottleneck adapters (paper §3.1, Eq. 1) and LoRA (for the FLoRA baseline).

Adapters are kept in their own stacked pytree, separate from the base model:
the chain optimizer slices this stack into frozen-prefix / trainable-window /
aux-suffix segments (DLCT + GPO), and FedAvg communicates only these leaves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.module import ACTIVATIONS, normal_init


def adapter_init(key, cfg: ModelConfig):
    """One bottleneck adapter: h + f(h·W_down)·W_up, W_up zero-init so the
    adapter starts as the identity (residual-safe insertion)."""
    r = cfg.adapter.rank
    dt = cfg.pdtype()
    return {
        "down": normal_init(key, (cfg.d_model, r), dt, stddev=0.02),
        "up": jnp.zeros((r, cfg.d_model), dt),
    }


def adapter_stack_init(key, cfg: ModelConfig, n_layers=None):
    """Stacked adapters (L, ...) for scan-over-layers / chain slicing."""
    n = n_layers if n_layers is not None else cfg.total_chain_layers
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: adapter_init(k, cfg))(keys)


def adapter_apply(p, h, cfg: ModelConfig, use_kernel: bool = False):
    """h: (..., d_model)."""
    if use_kernel:
        from ..kernels import ops as kops
        return kops.fused_adapter(h, p["down"], p["up"], activation=cfg.adapter.activation)
    act = ACTIVATIONS[cfg.adapter.activation]
    z = act(h @ p["down"].astype(h.dtype))
    return h + z @ p["up"].astype(h.dtype)


def adapter_chain_apply(stack, h, cfg: ModelConfig):
    """Apply a stacked slice of adapters sequentially (the GPO auxiliary
    branch: 'subsequent adapters as low-rank approximations of their layers',
    paper §4.3).  stack leaves: (L, ...)."""
    L = jax.tree_util.tree_leaves(stack)[0].shape[0]
    if L == 0:
        return h

    def step(x, p):
        return adapter_apply(p, x, cfg), None

    from ..models.transformer import _unroll
    h, _ = jax.lax.scan(step, h, stack, unroll=_unroll())
    return h


# ------------------------------------------------------------------ LoRA
def lora_init(key, d_in, d_out, rank, dtype):
    ka, _ = jax.random.split(key)
    return {"a": normal_init(ka, (d_in, rank), dtype, stddev=0.02),
            "b": jnp.zeros((rank, d_out), dtype)}


def lora_apply(p, x, scale=1.0):
    return scale * ((x @ p["a"].astype(x.dtype)) @ p["b"].astype(x.dtype))
