"""Bottleneck adapters (paper §3.1, Eq. 1), LoRA (for the FLoRA baseline),
and the ``ActiveAdapters`` composition spec.

Adapters are kept in their own stacked pytree, separate from the base model:
the chain optimizer slices this stack into frozen-prefix / trainable-window /
aux-suffix segments (DLCT + GPO), and FedAvg communicates only these leaves.
Which slice plays which role is described declaratively by ``ActiveAdapters``
(adapter-hub's ``active_adapters`` idea, specialized to stacked pytrees):
forward passes and the federated plan engine select sub-stacks by spec,
never by ad-hoc positional slicing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.module import ACTIVATIONS, normal_init

# segment roles
FROZEN = "frozen"    # run in inference mode; never receives gradient
TRAIN = "train"      # the trainable sub-stack (grads + optimizer state)
AUX = "aux"          # GPO auxiliary branch (adapters-as-layer-approximations)


@dataclasses.dataclass(frozen=True)
class AdapterSegment:
    """Half-open layer range [start, stop) with a name and a role."""
    name: str
    start: int
    stop: int
    role: str = TRAIN

    @property
    def size(self) -> int:
        return self.stop - self.start


def _seg_slice(stack, seg: AdapterSegment):
    return jax.tree_util.tree_map(lambda x: x[seg.start:seg.stop], stack)


@dataclasses.dataclass(frozen=True)
class ActiveAdapters:
    """Declarative activation/composition spec over a stacked (L, ...) adapter
    pytree — the single place that says which layers' adapters are trainable,
    which provide frozen context, and which feed the GPO auxiliary branch.

    Hashable (tuple of frozen segments), so it doubles as a jit-cache key:
    one compiled step per distinct spec — the DLCT cyclic window reuses ≤ L
    compilations exactly as the per-offset stage cache did.
    """
    n_layers: int
    segments: Tuple[AdapterSegment, ...]

    # ------------------------------------------------------------ builders
    @classmethod
    def full(cls, n_layers: int) -> "ActiveAdapters":
        """Every adapter active and trainable (Full Adapters† / baselines)."""
        return cls(n_layers, (AdapterSegment("all", 0, n_layers, TRAIN),))

    @classmethod
    def window(cls, n_layers: int, prefix: int, size: int) -> "ActiveAdapters":
        """CHAINFED stage geometry: frozen [0, prefix) → trainable
        [prefix, prefix+size) → aux [prefix+size, L).  Empty prefix/suffix
        segments are kept so lookups by name are total."""
        prefix = max(0, min(prefix, n_layers - 1))
        size = max(1, min(size, n_layers - prefix))
        return cls(n_layers, (
            AdapterSegment("prefix", 0, prefix, FROZEN),
            AdapterSegment("window", prefix, prefix + size, TRAIN),
            AdapterSegment("suffix", prefix + size, n_layers, AUX),
        ))

    # ------------------------------------------------------------- queries
    def segment(self, name: str) -> AdapterSegment:
        for s in self.segments:
            if s.name == name:
                return s
        raise KeyError(f"no segment {name!r} in {self.segments}")

    def by_role(self, role: str) -> Tuple[AdapterSegment, ...]:
        return tuple(s for s in self.segments if s.role == role)

    @property
    def train_span(self) -> Tuple[int, int]:
        """(start, stop) of the trainable range (contiguous by construction)."""
        segs = self.by_role(TRAIN)
        if not segs:
            return (0, 0)
        return (min(s.start for s in segs), max(s.stop for s in segs))

    @property
    def is_full(self) -> bool:
        a, b = self.train_span
        return a == 0 and b == self.n_layers

    def trainable_mask(self) -> jnp.ndarray:
        """(L,) float mask over layers — 1 where the adapter is trainable."""
        m = jnp.zeros((self.n_layers,), jnp.float32)
        for s in self.by_role(TRAIN):
            m = m.at[s.start:s.stop].set(1.0)
        return m

    # ----------------------------------------------------------- selection
    def _covers_all(self, seg: AdapterSegment) -> bool:
        return seg.start == 0 and seg.stop == self.n_layers

    def select(self, stack, name: str):
        """Sub-stack of a named segment (possibly empty: leaves (0, ...))."""
        seg = self.segment(name)
        if self._covers_all(seg):   # no device copy for the full stack
            return stack
        return _seg_slice(stack, seg)

    def select_role(self, stack, role: str):
        """Concatenated sub-stack of all segments with the given role
        (an empty (0, ...) sub-stack when no segment has the role)."""
        segs = self.by_role(role)
        if not segs:
            return jax.tree_util.tree_map(lambda x: x[0:0], stack)
        if len(segs) == 1:
            if self._covers_all(segs[0]):
                return stack
            return _seg_slice(stack, segs[0])
        parts = [_seg_slice(stack, s) for s in segs]
        return jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *parts)

    def train_slice(self, stack):
        return self.select_role(stack, TRAIN)

    def scatter_train(self, stack, value):
        """Write an updated trainable sub-stack back into the full stack."""
        a, b = self.train_span
        return jax.tree_util.tree_map(
            lambda full, w: jnp.concatenate(
                [full[:a], w.astype(full.dtype), full[b:]], axis=0),
            stack, value)


class AdapterLibrary:
    """Named adapter stacks + an active composition — the adapter-hub
    ``add_adapter`` / ``active_adapters`` surface, kept as the seam for
    multi-task adapter fusion and per-tenant serving (each tenant loads its
    stack once; ``resolve``/``fuse`` pick what a forward pass sees)."""

    def __init__(self):
        self._stacks: Dict[str, object] = {}
        self._active: Tuple[str, ...] = ()

    def add(self, name: str, stack) -> None:
        self._stacks[name] = stack

    def names(self):
        return tuple(sorted(self._stacks))

    @property
    def active_adapters(self) -> Tuple[str, ...]:
        return self._active

    def set_active(self, *names: str) -> None:
        missing = [n for n in names if n not in self._stacks]
        if missing:
            raise KeyError(f"unknown adapters {missing}; have {self.names()}")
        self._active = tuple(names)

    def resolve(self, name: str | None = None):
        """The stack a forward pass should use: a single named stack, or the
        (uniform) fusion of the active composition."""
        if name is not None:
            return self._stacks[name]
        if not self._active:
            raise ValueError("no active adapters; call set_active() first")
        if len(self._active) == 1:
            return self._stacks[self._active[0]]
        return self.fuse()

    def fuse(self, weights=None):
        """AdapterFusion-style linear fusion of the active stacks."""
        names = self._active
        if not names:
            raise ValueError("no active adapters; call set_active() first")
        if weights is None:
            weights = [1.0 / len(names)] * len(names)
        if len(weights) != len(names):
            raise ValueError(f"{len(weights)} weights for {len(names)} "
                             f"active adapters {names}")
        parts = [self._stacks[n] for n in names]
        return jax.tree_util.tree_map(
            lambda *xs: sum(w * x for w, x in zip(weights, xs)), *parts)


def adapter_init(key, cfg: ModelConfig):
    """One bottleneck adapter: h + f(h·W_down)·W_up, W_up zero-init so the
    adapter starts as the identity (residual-safe insertion)."""
    r = cfg.adapter.rank
    dt = cfg.pdtype()
    return {
        "down": normal_init(key, (cfg.d_model, r), dt, stddev=0.02),
        "up": jnp.zeros((r, cfg.d_model), dt),
    }


def adapter_stack_init(key, cfg: ModelConfig, n_layers=None):
    """Stacked adapters (L, ...) for scan-over-layers / chain slicing."""
    n = n_layers if n_layers is not None else cfg.total_chain_layers
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: adapter_init(k, cfg))(keys)


def adapter_apply(p, h, cfg: ModelConfig, use_kernel=None):
    """h: (..., d_model).  Kernel dispatch: ``use_kernel`` overrides
    ``cfg.adapter.fused``; when both are None the backend decides — the fused
    Pallas kernel on TPU (one VMEM pass for both projections + activation +
    residual, differentiable via its custom VJP), the plain XLA sequence
    elsewhere.  Adapters run in every window layer and the whole GPO
    auxiliary branch, so this is the forward's hottest primitive."""
    use = use_kernel if use_kernel is not None else cfg.adapter.fused
    if use is None:
        use = jax.default_backend() == "tpu"
    if use:
        from ..kernels.fused_adapter import _ACTS
        if cfg.adapter.activation in _ACTS:
            from ..kernels import ops as kops
            return kops.fused_adapter_grad(h, p["down"], p["up"],
                                           activation=cfg.adapter.activation)
        # activations the kernel doesn't implement fall back to plain XLA
    act = ACTIVATIONS[cfg.adapter.activation]
    z = act(h @ p["down"].astype(h.dtype))
    return h + z @ p["up"].astype(h.dtype)


def adapter_chain_apply(stack, h, cfg: ModelConfig):
    """Apply a stacked slice of adapters sequentially (the GPO auxiliary
    branch: 'subsequent adapters as low-rank approximations of their layers',
    paper §4.3).  stack leaves: (L, ...)."""
    L = jax.tree_util.tree_leaves(stack)[0].shape[0]
    if L == 0:
        return h

    def step(x, p):
        return adapter_apply(p, x, cfg), None

    from ..models.transformer import _unroll
    h, _ = jax.lax.scan(step, h, stack, unroll=_unroll())
    return h


# ------------------------------------------------------------------ LoRA
def lora_init(key, d_in, d_out, rank, dtype):
    ka, _ = jax.random.split(key)
    return {"a": normal_init(ka, (d_in, rank), dtype, stddev=0.02),
            "b": jnp.zeros((rank, d_out), dtype)}


def lora_apply(p, x, scale=1.0):
    return scale * ((x @ p["a"].astype(x.dtype)) @ p["b"].astype(x.dtype))
