"""Bottleneck adapters (paper §3.1, Eq. 1), LoRA (for the FLoRA baseline),
and the ``ActiveAdapters`` composition spec.

Adapters are kept in their own stacked pytree, separate from the base model:
the chain optimizer slices this stack into frozen-prefix / trainable-window /
aux-suffix segments (DLCT + GPO), and FedAvg communicates only these leaves.
Which slice plays which role is described declaratively by ``ActiveAdapters``
(adapter-hub's ``active_adapters`` idea, specialized to stacked pytrees):
forward passes and the federated plan engine select sub-stacks by spec,
never by ad-hoc positional slicing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.module import ACTIVATIONS, normal_init

# segment roles
FROZEN = "frozen"    # run in inference mode; never receives gradient
TRAIN = "train"      # the trainable sub-stack (grads + optimizer state)
AUX = "aux"          # GPO auxiliary branch (adapters-as-layer-approximations)


@dataclasses.dataclass(frozen=True)
class AdapterSegment:
    """Half-open layer range [start, stop) with a name and a role."""
    name: str
    start: int
    stop: int
    role: str = TRAIN

    @property
    def size(self) -> int:
        return self.stop - self.start


def _seg_slice(stack, seg: AdapterSegment):
    return jax.tree_util.tree_map(lambda x: x[seg.start:seg.stop], stack)


@dataclasses.dataclass(frozen=True)
class ActiveAdapters:
    """Declarative activation/composition spec over a stacked (L, ...) adapter
    pytree — the single place that says which layers' adapters are trainable,
    which provide frozen context, and which feed the GPO auxiliary branch.

    Hashable (tuple of frozen segments), so it doubles as a jit-cache key:
    one compiled step per distinct spec — the DLCT cyclic window reuses ≤ L
    compilations exactly as the per-offset stage cache did.
    """
    n_layers: int
    segments: Tuple[AdapterSegment, ...]

    # ------------------------------------------------------------ builders
    @classmethod
    def full(cls, n_layers: int) -> "ActiveAdapters":
        """Every adapter active and trainable (Full Adapters† / baselines)."""
        return cls(n_layers, (AdapterSegment("all", 0, n_layers, TRAIN),))

    @classmethod
    def window(cls, n_layers: int, prefix: int, size: int) -> "ActiveAdapters":
        """CHAINFED stage geometry: frozen [0, prefix) → trainable
        [prefix, prefix+size) → aux [prefix+size, L).  Empty prefix/suffix
        segments are kept so lookups by name are total."""
        prefix = max(0, min(prefix, n_layers - 1))
        size = max(1, min(size, n_layers - prefix))
        return cls(n_layers, (
            AdapterSegment("prefix", 0, prefix, FROZEN),
            AdapterSegment("window", prefix, prefix + size, TRAIN),
            AdapterSegment("suffix", prefix + size, n_layers, AUX),
        ))

    # ------------------------------------------------------------- queries
    def segment(self, name: str) -> AdapterSegment:
        for s in self.segments:
            if s.name == name:
                return s
        raise KeyError(f"no segment {name!r} in {self.segments}")

    def by_role(self, role: str) -> Tuple[AdapterSegment, ...]:
        return tuple(s for s in self.segments if s.role == role)

    @property
    def train_span(self) -> Tuple[int, int]:
        """(start, stop) of the trainable range (contiguous by construction)."""
        segs = self.by_role(TRAIN)
        if not segs:
            return (0, 0)
        return (min(s.start for s in segs), max(s.stop for s in segs))

    @property
    def is_full(self) -> bool:
        a, b = self.train_span
        return a == 0 and b == self.n_layers

    def trainable_mask(self) -> jnp.ndarray:
        """(L,) float mask over layers — 1 where the adapter is trainable."""
        m = jnp.zeros((self.n_layers,), jnp.float32)
        for s in self.by_role(TRAIN):
            m = m.at[s.start:s.stop].set(1.0)
        return m

    # ----------------------------------------------------------- selection
    def _covers_all(self, seg: AdapterSegment) -> bool:
        return seg.start == 0 and seg.stop == self.n_layers

    def select(self, stack, name: str):
        """Sub-stack of a named segment (possibly empty: leaves (0, ...))."""
        seg = self.segment(name)
        if self._covers_all(seg):   # no device copy for the full stack
            return stack
        return _seg_slice(stack, seg)

    def select_role(self, stack, role: str):
        """Concatenated sub-stack of all segments with the given role
        (an empty (0, ...) sub-stack when no segment has the role)."""
        segs = self.by_role(role)
        if not segs:
            return jax.tree_util.tree_map(lambda x: x[0:0], stack)
        if len(segs) == 1:
            if self._covers_all(segs[0]):
                return stack
            return _seg_slice(stack, segs[0])
        parts = [_seg_slice(stack, s) for s in segs]
        return jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *parts)

    def train_slice(self, stack):
        return self.select_role(stack, TRAIN)

    def scatter_train(self, stack, value):
        """Write an updated trainable sub-stack back into the full stack.
        A full-span spec returns ``value`` itself — ``stack`` is never read,
        so a donated round-start stack stays legal to commit."""
        a, b = self.train_span
        if a == 0 and b == self.n_layers:
            return value
        return jax.tree_util.tree_map(
            lambda full, w: jnp.concatenate(
                [full[:a], w.astype(full.dtype), full[b:]], axis=0),
            stack, value)


class AdapterLibrary:
    """Named adapter stacks + an active composition — the adapter-hub
    ``add_adapter`` / ``active_adapters`` surface, and the tenant registry of
    the multi-tenant serving engine (``repro.launch.serve``).

    Each registered stack owns a stable integer **slot** (registration
    order); ``stacked()`` packs all stacks into one ``(T, L, ...)`` pytree
    and ``tenant_ids`` maps names to slot indices — together they are the
    gather table a single compiled mixed-tenant forward routes batch rows
    through.  Chain-tuned *partial* stacks (a DLCT window checkpoint)
    register through an ``ActiveAdapters`` spec: the window is scattered
    into the library's base stack, so partial and full tenants serve through
    the same ``(T, L, ...)`` layout.  ``fuse`` composes stacks
    AdapterFusion-style and can register the result as a synthetic tenant.

    **Host tier** (``resident_capacity=R``): registered stacks live in host
    memory and only an LRU *resident set* of ``R`` stacks occupies the
    device slab.  The slab keeps the fixed scan layout ``(L, R, ...)`` —
    compiled shapes depend on ``R``, never on the library size ``T`` — and
    ``route_ids`` is the admission point: routing a non-resident tenant
    uploads its stack into a free (or LRU-evicted) slab row and returns
    resident-row indices instead of registration slots.  Rows named in
    ``pin`` (tenants live in serve slots mid-flight) are never evicted.
    Without a capacity the library is fully resident and byte-identical to
    the original behavior.
    """

    def __init__(self, base=None, resident_capacity: int | None = None):
        self._stacks: Dict[str, object] = {}
        self._active: Tuple[str, ...] = ()
        self._order: list = []          # registration order == tenant slots
        self._base = base               # template for partial-chain tenants
        self._stacked = None            # (T, L, ...) cache
        self._scan = None               # (L, T, ...) scan-layout cache
        if resident_capacity is not None and resident_capacity < 1:
            raise ValueError(f"resident_capacity must be >= 1, "
                             f"got {resident_capacity}")
        self._capacity = resident_capacity
        self._resident: Dict[str, int] = {}   # name -> slab row
        self._lru: list = []                  # LRU order, front = coldest
        self._slab = None                     # (L, R, ...) device slab
        self.stats = {"hits": 0, "misses": 0, "evictions": 0, "uploads": 0}

    @staticmethod
    def _host_put(stack):
        """Pin a stack in host memory (the cold tier).  On a CPU-only host
        this is the same device — the tiering logic is still exercised; on an
        accelerator it keeps cold tenants out of device HBM."""
        try:
            cpu = jax.devices("cpu")[0]
        except RuntimeError:
            return stack
        return jax.device_put(stack, cpu)

    def add(self, name: str, stack, spec: "ActiveAdapters | None" = None) -> None:
        """Register a stack.  With ``spec``, ``stack`` holds only the spec's
        trainable span (a chain-tuned window); it is scattered into the
        library's base stack so the tenant serves a full chain."""
        if spec is not None:
            if self._base is None:
                raise ValueError("partial-chain registration needs a library "
                                 "base stack (AdapterLibrary(base=...))")
            stack = spec.scatter_train(self._base, stack)
        if self._capacity is not None:
            stack = self._host_put(stack)
            if name in self._resident:      # re-registration: stale on device
                self._lru.remove(name)
                del self._resident[name]
        self._stacks[name] = stack
        if name not in self._order:
            self._order.append(name)
        self._stacked = self._scan = None

    def names(self):
        return tuple(sorted(self._stacks))

    def __len__(self):
        return len(self._order)

    def __contains__(self, name: str) -> bool:
        return name in self._stacks

    # --------------------------------------------------------- tenant slots
    def tenant_id(self, name: str) -> int:
        """Stable slot of a registered stack in the ``(T, L, ...)`` layout."""
        try:
            return self._order.index(name)
        except ValueError:
            raise KeyError(f"unknown tenant {name!r}; have "
                           f"{tuple(self._order)}") from None

    def tenant_ids(self, names) -> jnp.ndarray:
        """(B,) int32 row-routing vector for a batch of tenant names."""
        return jnp.asarray([self.tenant_id(n) for n in names], jnp.int32)

    # ------------------------------------------------------- host/LRU tier
    @property
    def resident_capacity(self) -> "int | None":
        return self._capacity

    @property
    def resident(self) -> Tuple[str, ...]:
        """Currently device-resident tenants, coldest first."""
        return tuple(self._lru)

    @property
    def hit_rate(self) -> float:
        n = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / n if n else 1.0

    def _slab_init(self, template):
        """Zero ``(L, R, ...)`` device slab shaped like one stack."""
        R = self._capacity
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((x.shape[0], R) + x.shape[1:], x.dtype),
            template)

    @staticmethod
    @jax.jit
    def _upload(slab, stack, row):
        """Write one host stack into slab row ``row`` (axis 1 of every
        ``(L, R, ...)`` leaf).  Jitted: steady-state tenant swaps are one
        compiled donate-free dynamic-update, not a per-leaf re-stack."""
        return jax.tree_util.tree_map(
            lambda s, x: jax.lax.dynamic_update_index_in_dim(
                s, x.astype(s.dtype), row, axis=1), slab, stack)

    def _ensure_resident(self, name: str, protect) -> int:
        """Return ``name``'s slab row, uploading + LRU-evicting on a miss.
        Rows of tenants in ``protect`` are never evicted."""
        if name not in self._stacks:
            raise KeyError(f"unknown tenant {name!r}; have "
                           f"{tuple(self._order)}")
        if name in self._resident:
            self.stats["hits"] += 1
            self._lru.remove(name)
            self._lru.append(name)          # most recently used
            return self._resident[name]
        self.stats["misses"] += 1
        if self._slab is None:
            self._slab = self._slab_init(self._stacks[name])
        if len(self._resident) < self._capacity:
            used = set(self._resident.values())
            row = next(r for r in range(self._capacity) if r not in used)
        else:
            victim = next((n for n in self._lru if n not in protect), None)
            if victim is None:
                raise RuntimeError(
                    f"adapter resident set exhausted: all "
                    f"{self._capacity} rows are pinned ({sorted(protect)}); "
                    f"raise resident_capacity or shrink the live batch")
            row = self._resident.pop(victim)
            self._lru.remove(victim)
            self.stats["evictions"] += 1
        self._slab = self._upload(self._slab, self._stacks[name], row)
        self.stats["uploads"] += 1
        self._resident[name] = row
        self._lru.append(name)
        return row

    def route_ids(self, names, pin=()) -> jnp.ndarray:
        """(B,) int32 row-routing vector for a batch of tenant names —
        the host-tier admission point.  Without a resident capacity this is
        exactly ``tenant_ids``.  With one, every distinct name is made
        device-resident first (async upload into a free or LRU-evicted slab
        row), and the returned ids index the **resident slab**, not the
        registration order.  ``pin`` lists tenants that must stay resident
        (rows still live in serve slots) even when not in this batch."""
        if self._capacity is None:
            return self.tenant_ids(names)
        distinct = list(dict.fromkeys(names))
        protect = set(distinct) | set(pin)
        needed = len(protect & set(self._stacks))
        if needed > self._capacity:
            raise RuntimeError(
                f"batch needs {needed} distinct resident tenants but "
                f"resident_capacity={self._capacity}; shrink the batch or "
                f"raise the capacity")
        for n in distinct:
            self._ensure_resident(n, protect)
        return jnp.asarray([self._resident[n] for n in names], jnp.int32)

    def stacked(self):
        """All registered stacks packed as one ``(T, L, ...)`` pytree in slot
        order — the gather table of the mixed-tenant forward.  Cached until
        the next registration (tenant onboarding re-stacks once, not per
        batch)."""
        if not self._order:
            raise ValueError("empty library; add() at least one stack")
        if self._capacity is not None:
            return jax.tree_util.tree_map(
                lambda x: jnp.swapaxes(x, 0, 1), self.stacked_scan())
        if self._stacked is None:
            parts = [self._stacks[n] for n in self._order]
            self._stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=0), *parts)
        return self._stacked

    def stacked_scan(self):
        """``stacked()`` transposed to the scan layout ``(L, T, ...)`` the
        multi-tenant forwards consume (one ``(T, ...)`` slab per layer-scan
        step).  Cached on the host like ``stacked()`` — transposing here,
        once per registration change, keeps the full-library copy out of the
        compiled per-token decode.  Under a resident capacity this is the
        ``(L, R, ...)`` device slab itself: its shape is fixed by ``R``, so
        compiled decode never re-specializes as tenants onboard."""
        if self._capacity is not None:
            if not self._order:
                raise ValueError("empty library; add() at least one stack")
            if self._slab is None:
                self._slab = self._slab_init(self._stacks[self._order[0]])
            return self._slab
        if self._scan is None:
            self._scan = jax.tree_util.tree_map(
                lambda x: jnp.swapaxes(x, 0, 1), self.stacked())
        return self._scan

    @property
    def active_adapters(self) -> Tuple[str, ...]:
        return self._active

    def set_active(self, *names: str) -> None:
        missing = [n for n in names if n not in self._stacks]
        if missing:
            raise KeyError(f"unknown adapters {missing}; have {self.names()}")
        self._active = tuple(names)

    def resolve(self, name: str | None = None):
        """The stack a forward pass should use: a single named stack, or the
        (uniform) fusion of the active composition."""
        if name is not None:
            if name not in self._stacks:
                raise KeyError(f"unknown tenant {name!r}; have "
                               f"{tuple(self._order)}")
            return self._stacks[name]
        if not self._active:
            raise ValueError("no active adapters; call set_active() first")
        if len(self._active) == 1:
            return self._stacks[self._active[0]]
        return self.fuse()

    def fuse(self, weights=None, names=None, into: str | None = None):
        """AdapterFusion-style linear fusion of ``names`` (default: the
        active composition).  ``into`` registers the fused stack as a
        synthetic tenant, so a weighted multi-task composition serves through
        the same row-routing path as any single-task stack."""
        names = tuple(names) if names is not None else self._active
        if not names:
            raise ValueError("no active adapters; call set_active() first")
        missing = [n for n in names if n not in self._stacks]
        if missing:
            raise KeyError(f"unknown adapters {missing}; have {self.names()}")
        if weights is None:
            weights = [1.0 / len(names)] * len(names)
        if len(weights) != len(names):
            raise ValueError(f"{len(weights)} weights for {len(names)} "
                             f"active adapters {names}")
        parts = [self._stacks[n] for n in names]
        fused = jax.tree_util.tree_map(
            lambda *xs: sum(w * x for w, x in zip(weights, xs)), *parts)
        if into is not None:
            self.add(into, fused)
        return fused


def adapter_init(key, cfg: ModelConfig):
    """One bottleneck adapter: h + f(h·W_down)·W_up, W_up zero-init so the
    adapter starts as the identity (residual-safe insertion)."""
    r = cfg.adapter.rank
    dt = cfg.pdtype()
    return {
        "down": normal_init(key, (cfg.d_model, r), dt, stddev=0.02),
        "up": jnp.zeros((r, cfg.d_model), dt),
    }


def adapter_stack_init(key, cfg: ModelConfig, n_layers=None):
    """Stacked adapters (L, ...) for scan-over-layers / chain slicing."""
    n = n_layers if n_layers is not None else cfg.total_chain_layers
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: adapter_init(k, cfg))(keys)


def adapter_apply(p, h, cfg: ModelConfig, use_kernel=None):
    """h: (..., d_model).  Kernel dispatch: ``use_kernel`` overrides
    ``cfg.adapter.fused``; when both are None the backend decides — the fused
    Pallas kernel on TPU (one VMEM pass for both projections + activation +
    residual, differentiable via its custom VJP), the plain XLA sequence
    elsewhere.  Adapters run in every window layer and the whole GPO
    auxiliary branch, so this is the forward's hottest primitive."""
    use = use_kernel if use_kernel is not None else cfg.adapter.fused
    if use is None:
        use = jax.default_backend() == "tpu"
    if use:
        from ..kernels.fused_adapter import _ACTS
        if cfg.adapter.activation in _ACTS:
            from ..kernels import ops as kops
            return kops.fused_adapter_grad(h, p["down"], p["up"],
                                           activation=cfg.adapter.activation)
        # activations the kernel doesn't implement fall back to plain XLA
    act = ACTIVATIONS[cfg.adapter.activation]
    z = act(h @ p["down"].astype(h.dtype))
    return h + z @ p["up"].astype(h.dtype)


def adapter_apply_routed(p, h, tenant_ids, cfg: ModelConfig, use_kernel=None):
    """Multi-tenant adapter apply: each batch row runs *its own tenant's*
    adapter.  ``p`` leaves are ``(T, ...)`` (one layer of the library's
    ``(T, L, ...)`` stack), ``h`` is ``(B, S, d)``, ``tenant_ids`` ``(B,)``.
    Tenant ids are traced data, so one compiled program serves any tenant
    mix.  Kernel dispatch mirrors ``adapter_apply``: the tenant-routed Pallas
    kernel (scalar-prefetched ids pick each row block's weights — the gather
    never materializes) where supported, a gather + batched einsum in XLA
    elsewhere."""
    use = use_kernel if use_kernel is not None else cfg.adapter.fused
    if use is None:
        use = jax.default_backend() == "tpu"
    if use:
        from ..kernels.fused_adapter import _ACTS
        if cfg.adapter.activation in _ACTS and h.ndim == 3:
            from ..kernels import ops as kops
            return kops.fused_adapter_tenants(
                h, tenant_ids, p["down"], p["up"],
                activation=cfg.adapter.activation)
    act = ACTIVATIONS[cfg.adapter.activation]
    down = p["down"][tenant_ids].astype(h.dtype)       # (B, d, r)
    up = p["up"][tenant_ids].astype(h.dtype)           # (B, r, d)
    z = act(jnp.einsum("bsd,bdr->bsr", h, down))
    return h + jnp.einsum("bsr,brd->bsd", z, up)


def adapter_chain_apply(stack, h, cfg: ModelConfig):
    """Apply a stacked slice of adapters sequentially (the GPO auxiliary
    branch: 'subsequent adapters as low-rank approximations of their layers',
    paper §4.3).  stack leaves: (L, ...)."""
    L = jax.tree_util.tree_leaves(stack)[0].shape[0]
    if L == 0:
        return h

    def step(x, p):
        return adapter_apply(p, x, cfg), None

    from ..models.transformer import _unroll
    h, _ = jax.lax.scan(step, h, stack, unroll=_unroll())
    return h


# ------------------------------------------------------------------ LoRA
def lora_init(key, d_in, d_out, rank, dtype):
    ka, _ = jax.random.split(key)
    return {"a": normal_init(ka, (d_in, rank), dtype, stddev=0.02),
            "b": jnp.zeros((rank, d_out), dtype)}


def lora_apply(p, x, scale=1.0):
    return scale * ((x @ p["a"].astype(x.dtype)) @ p["b"].astype(x.dtype))
