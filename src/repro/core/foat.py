"""FOAT — Function-Oriented Adaptive Tuning (paper §4.4, Eq. 3, App. A).

Layer functionality is quantified by CKA similarity between each layer's
(pooled) representation and the initial embedding; the server aggregates
client scores and picks the first layer whose CKA drops below threshold T as
the chain's starting point ``L_start``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _center(X):
    return X - jnp.mean(X, axis=0, keepdims=True)


def linear_hsic(X, Y):
    """Biased HSIC with linear kernels = ||Yᵀ X||_F² (Gram-free form).
    X: (n, d1), Y: (n, d2), columns centered."""
    return jnp.sum(jnp.square(X.T @ Y))


def linear_cka(X, Y, use_kernel: bool = False):
    """CKA(Z_i, Z_j) = HSIC(X,Y) / sqrt(HSIC(X,X)·HSIC(Y,Y))  (Eq. 3)."""
    X = _center(X.astype(jnp.float32))
    Y = _center(Y.astype(jnp.float32))
    if use_kernel:
        from ..kernels import ops as kops
        hxy, hxx, hyy = kops.cka_gram(X, Y)
    else:
        hxy, hxx, hyy = linear_hsic(X, Y), linear_hsic(X, X), linear_hsic(Y, Y)
    return hxy / jnp.sqrt(hxx * hyy + 1e-12)


def foat_scores(layer_outputs, use_kernel: bool = False):
    """layer_outputs: (L+1, B, d) pooled activations, Z_0 first.
    Returns (L,) CKA(Z_i, Z_0) for i = 1..L."""
    z0 = layer_outputs[0]
    return jnp.stack([linear_cka(layer_outputs[i], z0, use_kernel)
                      for i in range(1, layer_outputs.shape[0])])


def aggregate_scores(client_scores, weights=None):
    """Server aggregation of per-client CKA vectors (Fig. 7: upload + mean).
    Accepts a list of (L,) vectors or one stacked (n_clients, L) array."""
    S = jnp.asarray(client_scores)                     # (n_clients, L)
    if weights is None:
        return jnp.mean(S, axis=0)
    w = jnp.asarray(weights, jnp.float32)
    return jnp.sum(S * w[:, None], axis=0) / jnp.sum(w)


def select_start_layer(agg_scores, threshold: float) -> int:
    """First layer whose aggregated CKA falls below T; all layers before it
    are considered general-purpose and stay frozen (no adapters tuned)."""
    scores = jax.device_get(agg_scores)
    for i, s in enumerate(scores):
        if float(s) < threshold:
            return i
    return max(0, len(scores) - 1)


def run_foat(params, adapters, client_batches, cfg, threshold: float,
             weights=None, use_kernel: bool = False):
    """Phase-1 setup (Algorithm 1, lines 1-2): each client one forward pass,
    CKA scores, server aggregation, boundary selection.

    ``client_batches`` — one stacked batch dict with ``(C, b, ...)`` leaves,
    or a list of per-client batch dicts (stacked host-side when shapes
    agree).  Either way the setup pass is ONE jitted evaluation: ``vmap``
    over the client axis replaces the legacy per-client dispatch loop, so C
    clients cost one compilation and one dispatch."""
    import numpy as np

    from ..models.transformer import collect_layer_outputs

    def client_scores(batch):
        outs = collect_layer_outputs(params, adapters, batch, cfg)
        return foat_scores(outs, use_kernel)

    if isinstance(client_batches, (list, tuple)):
        client_batches = {
            k: jnp.asarray(np.stack([np.asarray(b[k]) for b in client_batches]))
            for k in client_batches[0]}
    scores = jax.jit(jax.vmap(client_scores))(client_batches)   # (C, L)
    agg = aggregate_scores(scores, weights)
    return select_start_layer(agg, threshold), agg
