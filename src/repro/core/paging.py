"""Block-granular KV-cache paging for the serve path (ISSUE 9 tentpole).

The dense slot cache allocates ``(L, slots, horizon, ...)`` — every request
pays the full decode horizon whatever its actual length, and a long-tail
request mix wastes most of it.  ``PageTable`` is the host-side allocator of
the paged alternative: KV lives in a flat pool of fixed-size **pages**
(``(L, n_pages, page_size, KV, hd)``, see ``transformer.init_paged_cache``)
and each serve slot owns an ordered page list covering exactly the tokens it
will write.  Admission allocates from a free list, drain releases back to
it, and **shared prefix pages** (a tenant's common system prompt) are
refcounted so the prefix KV is stored once however many concurrent requests
carry it.

The table itself is plain numpy — the device only ever sees the packed
``(slots, max_pages)`` int32 page-id array (``rows()``), which rides into
the jitted paged decode as *traced data*: admissions, drains and prefix
sharing never change a compiled shape.  Unallocated entries are ``-1``
(readers clamp; the attention mask hides them) and writers route parked /
shared pages to the out-of-range sentinel ``n_pages`` so scatter-``drop``
semantics skip them.

Copy-on-write semantics for shared prefixes are write-time-trivial by
construction: only *whole* pages of the prefix are shared, so a slot's
private tokens (the partial tail page, the rest of the prompt, every decoded
token) always land in private pages — the "copy" of the divergent page is
simply that slot's own prefill write.  Shared pages are read-only for their
whole lifetime.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


class PageTable:
    """Free-list page allocator + per-slot page lists for a paged KV pool.

    ``n_pages``  — pool capacity (pages); ``page_size`` — tokens per page;
    ``slots``    — serve-loop batch rows; ``max_pages`` — page-list length
    per slot (``ceil(horizon / page_size)``, fixes the device-side shape).
    """

    def __init__(self, n_pages: int, page_size: int, slots: int,
                 max_pages: int):
        if n_pages < 1 or page_size < 1 or slots < 1 or max_pages < 1:
            raise ValueError(f"PageTable: all sizes must be >= 1, got "
                             f"n_pages={n_pages} page_size={page_size} "
                             f"slots={slots} max_pages={max_pages}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.slots = int(slots)
        self.max_pages = int(max_pages)
        # LIFO free list: a drained slot's pages are the next allocated —
        # re-admission reuses released pages (asserted in tests)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._rows = np.full((self.slots, self.max_pages), -1, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(self.slots)]
        self._refs = np.zeros(self.n_pages, np.int64)
        self._shared: Dict[object, List[int]] = {}
        self.peak_in_use = 0
        self.prefix_hits = 0
        self.prefix_misses = 0

    # ------------------------------------------------------------- queries
    def pages_for(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` (at least one for any live slot)."""
        return max(1, -(-int(n_tokens) // self.page_size))

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self._free)

    def can_admit(self, n_tokens: int, shared: Sequence[int] = ()) -> bool:
        return self.pages_for(n_tokens) - len(shared) <= len(self._free)

    def rows(self) -> np.ndarray:
        """The device-facing ``(slots, max_pages)`` int32 page-id array."""
        return self._rows

    # ---------------------------------------------------------- allocation
    def _take(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {n} pages, {len(self._free)} "
                f"free of {self.n_pages} (page_size={self.page_size}); "
                f"grow n_pages or drain slots first")
        got = [self._free.pop() for _ in range(n)]
        for g in got:
            self._refs[g] += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return got

    def admit(self, slot: int, n_tokens: int,
              shared: Sequence[int] = ()) -> np.ndarray:
        """Allocate ``slot``'s page list for a request storing ``n_tokens``
        tokens total.  ``shared`` — already-populated prefix pages the slot
        references (refcounted) instead of allocating; they must be whole
        leading pages.  Returns the slot's page-id vector (``max_pages``,
        ``-1``-padded)."""
        if self._owned[slot] or (self._rows[slot] >= 0).any():
            raise RuntimeError(f"slot {slot} already holds pages; "
                               f"release() before re-admission")
        need = self.pages_for(n_tokens)
        if len(shared) > need:
            raise ValueError(f"{len(shared)} shared pages exceed the "
                             f"{need}-page request")
        if need > self.max_pages:
            raise ValueError(f"request needs {need} pages > max_pages="
                             f"{self.max_pages} (horizon overflow)")
        for pg in shared:
            self._refs[pg] += 1
        fresh = self._take(need - len(shared))
        pages = list(shared) + fresh
        self._owned[slot] = pages
        self._rows[slot, :] = -1
        self._rows[slot, :need] = np.asarray(pages, np.int32)
        return self._rows[slot]

    def release(self, slot: int) -> None:
        """Drain: drop the slot's references; pages whose refcount reaches
        zero return to the free list (shared prefix pages stay while their
        registration pin — see ``share_prefix`` — or other slots hold them)."""
        for pg in self._owned[slot]:
            self._refs[pg] -= 1
            if self._refs[pg] == 0:
                self._free.append(pg)
        self._owned[slot] = []
        self._rows[slot, :] = -1

    # ------------------------------------------------------- prefix sharing
    def has_prefix(self, key) -> bool:
        """True if ``key``'s prefix pages are already registered (a lookup
        via ``share_prefix`` would be allocation-free)."""
        return key in self._shared

    def share_prefix(self, key, n_tokens: int) -> Tuple[List[int], bool]:
        """Pages for a shared prefix of ``n_tokens`` (must be page-aligned —
        callers share only whole pages).  Returns ``(pages, fresh)``:
        ``fresh`` means the caller must populate them (first admission);
        later lookups return the same pages storage-free.  The registration
        itself holds one pin so the prefix survives every referencing slot
        draining; ``drop_prefixes()`` releases the pins."""
        if n_tokens % self.page_size:
            raise ValueError(f"shared prefix must be page-aligned: "
                             f"{n_tokens} tokens, page_size={self.page_size}")
        if key in self._shared:
            self.prefix_hits += 1
            return list(self._shared[key]), False
        self.prefix_misses += 1
        pages = self._take(n_tokens // self.page_size)
        self._shared[key] = pages
        return list(pages), True

    def drop_prefixes(self) -> None:
        """Release the registration pins of every shared prefix (end of a
        serve run); pages still referenced by live slots stay allocated."""
        for pages in self._shared.values():
            for pg in pages:
                self._refs[pg] -= 1
                if self._refs[pg] == 0:
                    self._free.append(pg)
        self._shared.clear()

    # ------------------------------------------------------------ reporting
    def stats(self) -> dict:
        return {"n_pages": self.n_pages, "page_size": self.page_size,
                "in_use": self.in_use, "peak_in_use": self.peak_in_use,
                "shared_prefixes": len(self._shared),
                "prefix_hits": self.prefix_hits,
                "prefix_misses": self.prefix_misses}
