"""DLCT — Dynamic Layer Co-Tuning (paper §4.2).

A sliding window of Q adapters is co-tuned each round; the window advances by
one layer per round (overlap Q−1), cycling over the chain [L_start, L) for
multiple holistic passes.  For encoder-decoder models the window never
straddles the encoder/decoder boundary (DESIGN §6).
"""
from __future__ import annotations

import dataclasses
from typing import List

from ..models.config import ModelConfig
from ..models.transformer import ChainSegments


@dataclasses.dataclass(frozen=True)
class ChainSchedule:
    """Round → ChainSegments mapping; precomputed static window offsets."""
    offsets: tuple          # valid window start offsets, in visit order
    window: int

    def segments(self, round_idx: int, advance_every: int = 1) -> ChainSegments:
        i = (round_idx // max(1, advance_every)) % len(self.offsets)
        return ChainSegments(self.offsets[i], self.window)

    @property
    def n_stages(self) -> int:
        return len(self.offsets)


def make_schedule(cfg: ModelConfig, l_start: int, window: int) -> ChainSchedule:
    """Enumerate the chain's window start offsets.

    Dense/MoE/SSM/hybrid/VLM: k ∈ [l_start, L−Q] stepping by 1.
    Enc-dec: same, but windows are clipped to live entirely inside one stack;
    offsets that would straddle the boundary are snapped to the decoder start.
    """
    L = cfg.total_chain_layers
    Q = max(1, min(window, L - min(l_start, L - 1)))
    E = cfg.n_encoder_layers
    offsets: List[int] = []
    k = min(l_start, L - Q)
    last = L - Q
    while k <= last:
        if E and k < E and k + Q > E:        # straddling → snap to decoder
            if E not in offsets and E <= last:
                offsets.append(E)
            k += 1
            continue
        if k not in offsets:
            offsets.append(k)
        k += 1
    if not offsets:
        offsets = [max(0, L - Q)]
    return ChainSchedule(tuple(offsets), Q)


def _spec_for(adapters, seg: ChainSegments):
    import jax
    from .adapters import ActiveAdapters
    L = jax.tree_util.tree_leaves(adapters)[0].shape[0]
    return ActiveAdapters.window(L, seg.prefix, seg.window)


def window_slice(adapters, seg: ChainSegments):
    """Extract the trainable window from the stacked adapter pytree."""
    return _spec_for(adapters, seg).select(adapters, "window")


def window_scatter(adapters, window, seg: ChainSegments):
    """Write an updated window back into the full stack."""
    return _spec_for(adapters, seg).scatter_train(adapters, window)
