"""Hymba 1.5B [arXiv:2411.13676]: 32L, d_model=1600, 25H GQA kv=5, d_ff=5504,
vocab 32001 (padded to 32128), parallel attn+mamba heads, ssm_state=16."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b", family="hybrid", source="arXiv:2411.13676",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, activation="swiglu", qkv_bias=False,
    ssm_state=16, ssm_expand=2, rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    sliding_window=4096,  # Hymba interleaves SWA attention in most layers
)
SMOKE = CONFIG.reduced()
