"""Paper-side reproduction config: BERT-class bidirectional encoder
classifier (the paper's text-classification testbed, scaled to CPU).
Classification is cast as label-token prediction at the final position."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="bert-tiny", family="dense", source="paper §5.1 (DistilBERT/BERT family)",
    n_layers=6, d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=1024, activation="gelu", qkv_bias=True, norm="layernorm",
    causal=False, param_dtype="float32", compute_dtype="float32",
)
SMOKE = CONFIG.reduced()
