"""Gemma 2B [arXiv:2403.08295]: 18L, d_model=2048, 8 heads with head_dim=256,
MQA (1 KV head), GeGLU d_ff=16384, vocab 256000."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma-2b", family="dense", source="arXiv:2403.08295",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000, activation="geglu", qkv_bias=False,
    rope_theta=10000.0, param_dtype="bfloat16", compute_dtype="bfloat16",
    sliding_window=4096,  # SWA variant enables the long_500k decode shape
)
SMOKE = CONFIG.reduced()
