"""DeepSeekMoE 16B [arXiv:2401.06066]: 28L, d_model=2048, 16H GQA kv=16,
fine-grained MoE: 2 shared + 64 routed experts top-6, expert d_ff=1408,
vocab 102400.  (Deviation noted in DESIGN: the published model uses a dense
FFN in layer 0; we keep a homogeneous MoE stack for scan-over-layers.)"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b", family="moe", source="arXiv:2401.06066",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400, activation="swiglu", qkv_bias=False,
    n_experts=64, n_shared_experts=2, top_k=6, expert_d_ff=1408,
    capacity_factor=1.25, rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    sliding_window=4096,
)
SMOKE = CONFIG.reduced()
