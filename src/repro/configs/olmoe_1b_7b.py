"""OLMoE-1B-7B [arXiv:2409.02060]: 16L, d_model=2048, 16H GQA kv=16,
MoE 64 experts top-8, expert d_ff=1024, vocab 50304."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b", family="moe", source="arXiv:2409.02060",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab_size=50304, activation="swiglu", qkv_bias=False,
    n_experts=64, top_k=8, expert_d_ff=1024, capacity_factor=1.25,
    rope_theta=10000.0, param_dtype="bfloat16", compute_dtype="bfloat16",
    sliding_window=4096,
)
SMOKE = CONFIG.reduced()
