"""Qwen2 0.5B [arXiv:2407.10671]: 24L, d_model=896, 14H GQA kv=2, d_ff=4864,
vocab 151936, QKV bias."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-0.5b", family="dense", source="arXiv:2407.10671",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936, activation="swiglu", qkv_bias=True,
    rope_theta=1000000.0, param_dtype="bfloat16", compute_dtype="bfloat16",
    sliding_window=4096,
)
SMOKE = CONFIG.reduced()
