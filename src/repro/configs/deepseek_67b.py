"""DeepSeek 67B [arXiv:2401.02954]: llama-arch, 95L, d_model=8192, 64H GQA
kv=8, d_ff=22016, vocab 102400."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-67b", family="dense", source="arXiv:2401.02954",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=102400, activation="swiglu", qkv_bias=False,
    rope_theta=10000.0, param_dtype="bfloat16", compute_dtype="bfloat16",
    sliding_window=4096,
)
SMOKE = CONFIG.reduced()
