"""FalconMamba 7B [arXiv:2410.05355]: mamba-1 arch, attention-free, 64L,
d_model=4096, ssm_state=16, vocab 65024."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b", family="ssm", source="arXiv:2410.05355",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=65024, activation="swiglu", qkv_bias=False,
    ssm_state=16, ssm_expand=2,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
SMOKE = CONFIG.reduced()
