"""SeamlessM4T-large v2 [arXiv:2308.11596] — transformer BACKBONE only:
24L encoder + 24L decoder, d_model=1024, 16H kv=16, d_ff=8192, vocab 256206
(padded to 256256).  The mel-spectrogram/conv audio frontend is a STUB per
spec: input_specs provide precomputed frame embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2", family="encdec", source="arXiv:2308.11596",
    n_layers=24, n_encoder_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, activation="gelu", qkv_bias=True,
    norm="layernorm", frontend="audio_stub",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
SMOKE = CONFIG.reduced()
