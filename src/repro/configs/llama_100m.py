"""Paper-side reproduction config: ~100M-param LLaMA-class causal LM for the
end-to-end instruction-tuning driver (paper §5.7 scaled to CPU)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-100m", family="dense", source="paper §5.7 (LLaMA family, scaled)",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=2048,
    vocab_size=8192, activation="swiglu", qkv_bias=False,
    param_dtype="float32", compute_dtype="float32",
)
SMOKE = CONFIG.reduced()
