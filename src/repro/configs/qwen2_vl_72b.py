"""Qwen2-VL 72B [arXiv:2409.12191] — language decoder backbone: 80L,
d_model=8192, 64H GQA kv=8, d_ff=29568, vocab 152064, M-RoPE, dynamic
resolution.  The ViT vision encoder + projector is a STUB per spec:
input_specs provide pre-projected patch embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-72b", family="vlm", source="arXiv:2409.12191",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, activation="swiglu", qkv_bias=True,
    mrope=True, mrope_sections=(16, 24, 24), rope_theta=1000000.0,
    frontend="vision_stub",
    param_dtype="bfloat16", compute_dtype="bfloat16",
    sliding_window=4096,
)
SMOKE = CONFIG.reduced()
