"""Architecture registry: every assigned arch is a selectable config
(``--arch <id>``); each file cites its source."""
from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCH_IDS = [
    "gemma_2b", "olmoe_1b_7b", "deepseek_67b", "qwen2_0_5b",
    "deepseek_moe_16b", "hymba_1_5b", "qwen2_1_5b", "falcon_mamba_7b",
    "seamless_m4t_large_v2", "qwen2_vl_72b",
    # paper-side reproduction configs (BERT-class + LLaMA-class)
    "bert_tiny", "llama_100m",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({"qwen2-0.5b": "qwen2_0_5b", "qwen2-1.5b": "qwen2_1_5b",
                 "olmoe-1b-7b": "olmoe_1b_7b", "deepseek-moe-16b": "deepseek_moe_16b",
                 "hymba-1.5b": "hymba_1_5b", "seamless-m4t-large-v2": "seamless_m4t_large_v2",
                 "qwen2-vl-72b": "qwen2_vl_72b"})

ASSIGNED = ARCH_IDS[:10]


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f".{arch}", __name__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f".{arch}", __name__)
    return mod.SMOKE
