"""Pod-scale step builders (pure functions for pjit):

* ``make_fed_train_step``  — one CHAINFED federated round: client cohorts are
  a leading axis sharded on (pod, data); each cohort runs ``local_steps`` GPO
  steps on its DLCT window; FedAvg is the mean over the cohort axis (lowers
  to the all-reduce that *is* the paper's round communication).
* ``make_e2e_train_step``  — Full Adapters† upper bound (end-to-end), for the
  memory comparison in §Dry-run.
* ``make_prefill_step`` / ``make_decode_step`` — serving entry points.

Both train steps are constructed from a ``TrainablePlan`` and share
``make_client_update`` with the single-host ``PlanEngine.cohort_step`` —
one implementation of the scan×vmap client cohort, two execution scales.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.adapters import ActiveAdapters
from ..fed.strategies import (GRAD_PROGRAMS, TrainablePlan, cohort_fedavg,
                              make_client_update)
from ..models.config import ChainConfig, ModelConfig
from ..models.transformer import ChainSegments, decode_step, prefill
from ..optim.base import make_optimizer


def _make_plan_train_step(cfg: ModelConfig, chain: ChainConfig,
                          plan: TrainablePlan):
    """step(params, adapters, batch, key=None) -> (adapters', metrics) for
    any plan — the plan's gradient program (``grad=``) dispatches exactly as
    on the single-host cohort path.

    batch leaves: (C, local_steps, b, ...) — client cohorts × local steps ×
    per-step microbatch; vmap strips C, scan strips ls.  M-RoPE ``positions``
    carry their 3-axis after the cohort axes: (C, ls, 3, b, S).  FedAvg is
    the uniform mean over the cohort axis — under pjit it lowers to the
    cross-replica all-reduce that *is* the paper's round communication.
    Stochastic programs (``"spsa"``) take a PRNG ``key``, folded per cohort
    row then per local step (same derivation as the federated engine).
    """
    if GRAD_PROGRAMS[plan.grad].whole_client:
        raise ValueError(
            f"grad program {plan.grad!r} returns a program-defined upload, "
            "not an adapter delta — the pod step's FedAvg + scatter commit "
            "cannot consume it (use the federated engine's cohort path)")
    opt = make_optimizer(chain.optimizer, chain.lr,
                         opt_bits=(plan.opt_bits if plan.opt_bits is not None
                                   else getattr(chain, "opt_bits", 32)),
                         fused=getattr(chain, "fused_optim", None))
    client_update = make_client_update(cfg, chain, plan, opt)

    def step(params, adapters, batch, key=None):
        if key is None and GRAD_PROGRAMS[plan.grad].needs_rng:
            raise ValueError(
                f"grad program {plan.grad!r} is stochastic: pass a PRNG key "
                "to the train step (step(params, adapters, batch, key))")
        trainable0 = {"adapters": plan.adapters.train_slice(adapters)}
        C = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if key is None:
            updates, losses = jax.vmap(
                lambda cb: client_update(trainable0, params, adapters, cb,
                                         {}))(batch)
        else:
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
                jnp.arange(C))
            updates, losses = jax.vmap(
                lambda cb, k: client_update(trainable0, params, adapters, cb,
                                            {"grad_key": k}))(batch, keys)
        new = cohort_fedavg(trainable0, updates, jnp.ones((C,), jnp.float32),
                            {})
        adapters = plan.adapters.scatter_train(adapters, new["adapters"])
        return adapters, {"loss": jnp.mean(losses)}

    return step


def make_fed_train_step(cfg: ModelConfig, chain: ChainConfig,
                        seg: ChainSegments, gpo_sequential: bool = False):
    """One CHAINFED federated round on the DLCT window ``seg`` (GPO loss)."""
    spec = ActiveAdapters.window(cfg.total_chain_layers, seg.prefix,
                                 seg.window)
    loss = "gpo_seq" if gpo_sequential and not cfg.is_encdec else "gpo"
    plan = TrainablePlan(adapters=spec, train_head=False, loss=loss,
                         lam=chain.lam, remat=True)
    return _make_plan_train_step(cfg, chain, plan)


def make_e2e_train_step(cfg: ModelConfig, chain: ChainConfig,
                        grad: str = "ad", grad_cfg: tuple = ()):
    """Full Adapters† — end-to-end update of every adapter (the paper's
    memory-unconstrained upper bound).  Same batch layout as the fed step.
    ``grad``/``grad_cfg`` select the gradient program (``"spsa"`` gives the
    pod-scale backprop-free variant; pass the step a PRNG ``key``)."""
    plan = TrainablePlan(adapters=ActiveAdapters.full(cfg.total_chain_layers),
                         train_head=False, loss="ce", remat=True,
                         grad=grad, grad_cfg=tuple(grad_cfg))
    return _make_plan_train_step(cfg, chain, plan)


# ------------------------------------------------------------------ serving
def make_prefill_step(cfg: ModelConfig):
    def step(params, adapters, batch):
        logits, cache, n = prefill(params, adapters, batch, cfg)
        return logits, cache

    return step


def make_decode_step(cfg: ModelConfig, enc_len=None):
    def step(params, adapters, token, cache, idx, embeds=None):
        logits, cache, idx = decode_step(params, adapters, token, cache, idx,
                                         cfg, enc_len=enc_len, embeds=embeds)
        return jnp.argmax(logits, axis=-1), logits, cache, idx

    return step
