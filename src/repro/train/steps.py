"""Pod-scale step builders (pure functions for pjit):

* ``make_fed_train_step``  — one CHAINFED federated round: client cohorts are
  a leading axis sharded on (pod, data); each cohort runs ``local_steps`` GPO
  steps on its DLCT window; FedAvg is the mean over the cohort axis (lowers
  to the all-reduce that *is* the paper's round communication).
* ``make_e2e_train_step``  — Full Adapters† upper bound (end-to-end), for the
  memory comparison in §Dry-run.
* ``make_prefill_step`` / ``make_decode_step`` — serving entry points.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.dlct import window_scatter, window_slice
from ..models.config import ChainConfig, ModelConfig
from ..models.transformer import (ChainSegments, decode_step, forward_chain,
                                  forward_full, prefill)
from ..optim.base import make_optimizer
from ..train.losses import cross_entropy, gpo_loss, moe_penalty
from ..utils.tree import tree_map


def make_fed_train_step(cfg: ModelConfig, chain: ChainConfig,
                        seg: ChainSegments, gpo_sequential: bool = False):
    """Returns step(params, adapters, batch) -> (adapters', metrics).

    batch leaves: (C, local_steps, b, ...) — client cohorts × local steps ×
    per-step microbatch.  ``positions`` (M-RoPE) carries its 3-axis first:
    (3, C, ls, b, S).
    """
    opt = make_optimizer(chain.optimizer, chain.lr)
    final = seg.prefix + seg.window >= cfg.total_chain_layers

    def cohort_update(params, adapters, cohort_batch):
        """One client cohort's local training on the window (GPO loss)."""
        window0 = window_slice(adapters, seg)

        def loss_fn(window, mb):
            if gpo_sequential and not cfg.is_encdec:
                out = forward_chain(params, window, adapters, mb, cfg, seg,
                                    loss_ctx=(mb["labels"], chain.lam, final))
                from ..train.losses import moe_penalty
                loss = out["loss"] + moe_penalty(out["aux"], cfg)
                return loss, {"local": out["local"], "global": out["global"]}
            out = forward_chain(params, window, adapters, mb, cfg, seg)
            loss, parts = gpo_loss(out, mb["labels"], cfg, chain.lam, final)
            return loss, parts

        def one_step(carry, mb):
            window, opt_state = carry
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                window, mb)
            window, opt_state = opt.step(window, grads, opt_state)
            return (window, opt_state), loss

        (window, _), losses = jax.lax.scan(
            one_step, (window0, opt.init(window0)), cohort_batch)
        delta = tree_map(lambda a, b: a - b, window, window0)
        return delta, jnp.mean(losses)

    def step(params, adapters, batch):
        # batch leaves (C, ls, ...): vmap strips C, scan strips ls.  M-RoPE
        # positions use layout (C, ls, 3, b, S) so each microbatch sees (3,b,S).
        deltas, losses = jax.vmap(
            lambda cb: cohort_update(params, adapters, cb))(batch)
        # FedAvg: uniform-weighted mean over cohorts  ≡ cross-replica all-reduce
        delta = tree_map(lambda d: jnp.mean(d, axis=0), deltas)
        window = tree_map(lambda w, d: (w + d).astype(w.dtype),
                          window_slice(adapters, seg), delta)
        adapters = window_scatter(adapters, window, seg)
        return adapters, {"loss": jnp.mean(losses)}

    return step


def make_e2e_train_step(cfg: ModelConfig, chain: ChainConfig):
    """Full Adapters† — end-to-end update of every adapter (the paper's
    memory-unconstrained upper bound).  Same batch layout as the fed step."""
    opt = make_optimizer(chain.optimizer, chain.lr)

    def cohort_update(params, adapters, cohort_batch):
        def loss_fn(ad, mb):
            logits, aux = forward_full(params, ad, mb, cfg, remat=True)
            return cross_entropy(logits, mb["labels"]) + moe_penalty(aux, cfg)

        def one_step(carry, mb):
            ad, opt_state = carry
            loss, grads = jax.value_and_grad(loss_fn)(ad, mb)
            ad, opt_state = opt.step(ad, grads, opt_state)
            return (ad, opt_state), loss

        (ad, _), losses = jax.lax.scan(one_step, (adapters, opt.init(adapters)),
                                       cohort_batch)
        return tree_map(lambda a, b: a - b, ad, adapters), jnp.mean(losses)

    def step(params, adapters, batch):
        deltas, losses = jax.vmap(
            lambda cb: cohort_update(params, adapters, cb))(batch)
        delta = tree_map(lambda d: jnp.mean(d, axis=0), deltas)
        adapters = tree_map(lambda a, d: (a + d).astype(a.dtype), adapters, delta)
        return adapters, {"loss": jnp.mean(losses)}

    return step


# ------------------------------------------------------------------ serving
def make_prefill_step(cfg: ModelConfig):
    def step(params, adapters, batch):
        logits, cache, n = prefill(params, adapters, batch, cfg)
        return logits, cache

    return step


def make_decode_step(cfg: ModelConfig, enc_len=None):
    def step(params, adapters, token, cache, idx, embeds=None):
        logits, cache, idx = decode_step(params, adapters, token, cache, idx,
                                         cfg, enc_len=enc_len, embeds=embeds)
        return jnp.argmax(logits, axis=-1), logits, cache, idx

    return step
