"""Pod-scale step builders (pure functions for pjit):

* ``make_fed_train_step``  — one CHAINFED federated round: client cohorts are
  a leading axis sharded on (pod, data); each cohort runs ``local_steps`` GPO
  steps on its DLCT window; FedAvg is the mean over the cohort axis (lowers
  to the all-reduce that *is* the paper's round communication).
* ``make_e2e_train_step``  — Full Adapters† upper bound (end-to-end), for the
  memory comparison in §Dry-run.
* ``make_prefill_step`` / ``make_decode_step`` — serving entry points.

Both train steps are constructed from a ``TrainablePlan`` and share
``make_client_update`` with the single-host ``PlanEngine.cohort_step`` —
one implementation of the scan×vmap client cohort, two execution scales.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.adapters import ActiveAdapters
from ..fed.strategies import TrainablePlan, cohort_fedavg, make_client_update
from ..models.config import ChainConfig, ModelConfig
from ..models.transformer import ChainSegments, decode_step, prefill
from ..optim.base import make_optimizer
from ..utils.tree import tree_map


def _make_plan_train_step(cfg: ModelConfig, chain: ChainConfig,
                          plan: TrainablePlan):
    """step(params, adapters, batch) -> (adapters', metrics) for any plan.

    batch leaves: (C, local_steps, b, ...) — client cohorts × local steps ×
    per-step microbatch; vmap strips C, scan strips ls.  M-RoPE ``positions``
    carry their 3-axis after the cohort axes: (C, ls, 3, b, S).  FedAvg is
    the uniform mean over the cohort axis — under pjit it lowers to the
    cross-replica all-reduce that *is* the paper's round communication.
    """
    opt = make_optimizer(chain.optimizer, chain.lr)
    client_update = make_client_update(cfg, chain, plan, opt)

    def step(params, adapters, batch):
        trainable0 = {"adapters": plan.adapters.train_slice(adapters)}
        finals, losses = jax.vmap(
            lambda cb: client_update(trainable0, params, adapters, cb, {}))(
                batch)
        deltas = tree_map(lambda f, t0: f - t0, finals, trainable0)
        C = jax.tree_util.tree_leaves(batch)[0].shape[0]
        new = cohort_fedavg(trainable0, deltas, jnp.ones((C,), jnp.float32),
                            {})
        adapters = plan.adapters.scatter_train(adapters, new["adapters"])
        return adapters, {"loss": jnp.mean(losses)}

    return step


def make_fed_train_step(cfg: ModelConfig, chain: ChainConfig,
                        seg: ChainSegments, gpo_sequential: bool = False):
    """One CHAINFED federated round on the DLCT window ``seg`` (GPO loss)."""
    spec = ActiveAdapters.window(cfg.total_chain_layers, seg.prefix,
                                 seg.window)
    loss = "gpo_seq" if gpo_sequential and not cfg.is_encdec else "gpo"
    plan = TrainablePlan(adapters=spec, train_head=False, loss=loss,
                         lam=chain.lam, remat=True)
    return _make_plan_train_step(cfg, chain, plan)


def make_e2e_train_step(cfg: ModelConfig, chain: ChainConfig):
    """Full Adapters† — end-to-end update of every adapter (the paper's
    memory-unconstrained upper bound).  Same batch layout as the fed step."""
    plan = TrainablePlan(adapters=ActiveAdapters.full(cfg.total_chain_layers),
                         train_head=False, loss="ce", remat=True)
    return _make_plan_train_step(cfg, chain, plan)


# ------------------------------------------------------------------ serving
def make_prefill_step(cfg: ModelConfig):
    def step(params, adapters, batch):
        logits, cache, n = prefill(params, adapters, batch, cfg)
        return logits, cache

    return step


def make_decode_step(cfg: ModelConfig, enc_len=None):
    def step(params, adapters, token, cache, idx, embeds=None):
        logits, cache, idx = decode_step(params, adapters, token, cache, idx,
                                         cfg, enc_len=enc_len, embeds=embeds)
        return jnp.argmax(logits, axis=-1), logits, cache, idx

    return step
