"""Losses: masked LM cross-entropy and the GPO dual objective (paper Eq. 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE = -100


def cross_entropy(logits, labels):
    """logits: (..., V); labels int32 with IGNORE masking.  Mean over valid."""
    V = logits.shape[-1]
    mask = (labels != IGNORE).astype(jnp.float32)
    safe = jnp.where(labels == IGNORE, 0, labels)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / jnp.maximum(1.0, jnp.sum(mask))


def accuracy(logits, labels, class_tokens=None):
    """Token accuracy at supervised positions.  ``class_tokens`` restricts
    the argmax to the label-token set (classification over classes, as the
    paper's classifier heads do — untrained models then score chance level,
    matching the paper's No-FT rows, instead of 0 over the full vocab)."""
    mask = labels != IGNORE
    if class_tokens is not None:
        sel = logits[..., class_tokens]                  # (..., n_classes)
        pred = class_tokens[jnp.argmax(sel, axis=-1)]
    else:
        pred = jnp.argmax(logits, axis=-1)
    return jnp.sum((pred == labels) & mask) / jnp.maximum(1, jnp.sum(mask))


def moe_penalty(aux, cfg):
    return (cfg.router_aux_weight * aux.get("load_balance", 0.0)
            + cfg.router_z_weight * aux.get("router_z", 0.0))


def gpo_loss(chain_out, labels, cfg, lam: float, final_stage: bool):
    """Loss_m = LocalLoss + λ·GlobalLoss  (Eq. 2); the final stage uses only
    the end-to-end loss (paper §4.3)."""
    local = cross_entropy(chain_out["local_logits"], labels)
    penalty = moe_penalty(chain_out["aux"], cfg)
    if final_stage:
        # window reaches the last layer: local head IS the end-to-end output
        return local + penalty, {"local": local, "global": local}
    glob = cross_entropy(chain_out["global_logits"], labels)
    return local + lam * glob + penalty, {"local": local, "global": glob}
