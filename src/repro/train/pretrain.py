"""Centralized base-model pretraining — the stand-in for "download a
pretrained checkpoint" in this offline environment.

The paper fine-tunes pretrained backbones (BERT/LLaMA); CHAINFED's premises
(general-purpose lower layers, adapters as low-rank layer approximations)
assume feature structure already exists.  We create it by next-token LM
pretraining on the synthetic corpus *bodies* (no label supervision — the
classification task itself stays unseen, so No-FT stays at chance while
features become linearly separable).

Results are cached to .ckpt files keyed by (arch, corpus, steps).
"""
from __future__ import annotations

import hashlib
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.io import load_pytree, save_pytree
from ..models import transformer as T
from ..models.config import ModelConfig
from ..optim.base import adamw, cosine_schedule
from ..train.losses import cross_entropy

CACHE = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "pretrained"


def lm_pretrain(params, cfg: ModelConfig, tokens: np.ndarray, steps: int = 300,
                batch: int = 32, lr: float = 3e-3, seed: int = 0,
                verbose: bool = False):
    """Next-token LM training of the full base model (adapters untouched)."""
    opt = adamw(cosine_schedule(lr, steps // 10, steps), clip=1.0)
    state = opt.init(params)
    rng = np.random.default_rng(seed)
    # identity adapters as constants: pretraining is adapter-free
    adapters = T.init_adapters(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def step(params, state, toks):
        def loss_fn(p):
            batch_ = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            logits, aux = T.forward_full(p, adapters, batch_, cfg, remat=False)
            from ..train.losses import moe_penalty
            return cross_entropy(logits, batch_["labels"]) + moe_penalty(aux, cfg)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.step(params, grads, state)
        return params, state, loss

    loss = None
    for i in range(steps):
        idx = rng.integers(0, len(tokens), batch)
        params, state, loss = step(params, state, jnp.asarray(tokens[idx]))
        if verbose and (i + 1) % max(1, steps // 10) == 0:
            print(f"  pretrain step {i+1}/{steps} loss={float(loss):.4f}")
    return params, float(loss)


def pretrained_base(cfg: ModelConfig, tokens: np.ndarray, steps: int = 300,
                    seed: int = 0, verbose: bool = False):
    """Cached pretrained params for (cfg, corpus, steps)."""
    key = hashlib.md5(
        f"{cfg.arch_id}-{cfg.n_layers}-{cfg.d_model}-{cfg.vocab_size}-"
        f"{len(tokens)}-{tokens[:4].sum()}-{steps}-{seed}".encode()).hexdigest()[:12]
    path = CACHE / f"{cfg.arch_id}_{key}.msgpack"
    params = T.init_lm(jax.random.PRNGKey(seed), cfg)
    if path.exists():
        params, _ = load_pytree(path, params)
        return params
    params, loss = lm_pretrain(params, cfg, tokens, steps=steps, seed=seed,
                               verbose=verbose)
    save_pytree(path, params, meta={"loss": loss, "steps": steps})
    return params
