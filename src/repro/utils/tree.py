"""Pytree utilities used across the framework (no flax/optax in env)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_map(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def tree_zeros_like(tree):
    return tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return tree_map(lambda x, y: x + y, a, b)


def tree_sub(a, b):
    return tree_map(lambda x, y: x - y, a, b)


def tree_scale(tree, s):
    return tree_map(lambda x: x * s, tree)


def tree_axpy(a, x, y):
    """a*x + y elementwise over pytrees."""
    return tree_map(lambda u, v: a * u + v, x, y)


def tree_dot(a, b):
    """Sum of elementwise products across two pytrees (global inner product)."""
    leaves = tree_map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree_util.tree_reduce(lambda acc, x: acc + x, leaves, jnp.float32(0.0))


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.float32(0.0)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    return tree_map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_paths(tree):
    """List of (path_string, leaf) pairs."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append(("/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path), leaf))
    return out


def has_nan(tree) -> jax.Array:
    leaves = [jnp.any(jnp.isnan(x)) for x in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return jnp.array(False)
    return jnp.any(jnp.stack(leaves))


def slice_stacked(tree, start: int, stop: int):
    """Slice a stack of per-layer params [L, ...] along axis 0 with static bounds."""
    return tree_map(lambda x: x[start:stop], tree)


def concat_stacked(trees):
    return tree_map(lambda *xs: jnp.concatenate(xs, axis=0), *trees)
