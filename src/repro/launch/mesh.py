"""Production mesh builder.

Defined as a FUNCTION (never a module-level constant) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod slice: 16×16 = 256 chips per pod; 2 pods = 512 chips.

    axes: ``data``   — client cohorts / batch (FedAvg all-reduces here)
          ``model``  — tensor/expert/sequence parallel
          ``pod``    — cross-pod data parallel (multi-pod only)
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (CPU tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    s = 1
    for n in names:
        s *= mesh.shape[n]
    return s
