"""Training launcher.

Two modes:
* host  — single-host federated simulation (CPU-friendly): full CHAINFED
          protocol with FOAT setup, DLCT window advance, baselines, eval.
* pod   — pjit fed-round step on a device mesh (the production path the
          dry-run lowers; runs for real when devices exist).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch bert_tiny \
        --dataset agnews --rounds 30 --method chainfed
    PYTHONPATH=src python -m repro.launch.train --arch llama_100m \
        --task instruction --rounds 50 --method chainfed --window 3
"""
from __future__ import annotations

import argparse
import json
import time

from ..data.synthetic import DATASETS
from ..fed.registry import (available_strategies, describe_strategy,
                            list_strategies, run_experiment)
from ..fed.spec import (ExperimentSpec, FaultSpec, PrivacySpec, RunSpec,
                        ScheduleSpec, TopologySpec, build_configs,
                        freeze_opts)


def spec_from_args(args) -> ExperimentSpec:
    """The declarative spec equivalent of this flag invocation — by
    construction, ``--config <dump>`` reproduces the flag run exactly."""
    agg_opts = {}
    if args.aggregator == "trimmed_mean":
        agg_opts = {"trim": args.trim_frac}
    elif args.aggregator == "krum":
        agg_opts = {"f": args.krum_f}
    elif args.aggregator == "multi_krum":
        agg_opts = {"f": args.krum_f, "m": args.krum_m}
    return ExperimentSpec(
        run=RunSpec(
            strategy=args.method, arch=args.arch, smoke=args.smoke,
            task=args.task, dataset=args.dataset,
            batch_size=args.batch_size, rounds=args.rounds,
            eval_every=args.eval_every, seed=args.seed,
            memory_constrained=not args.unconstrained_memory,
            window=args.window, lam=args.lam,
            foat_threshold=args.threshold, local_steps=args.local_steps,
            lr=args.lr, optimizer=args.optimizer,
            opt_bits=args.opt_bits, fused_optim=args.fused_optim,
            compress=args.compress,
            compress_opts=freeze_opts(
                {} if args.compress is None else
                {"ratio": args.compress_ratio} if args.compress == "topk"
                else {}),
            n_clients=args.clients,
            clients_per_round=args.clients_per_round,
            dirichlet_alpha=args.alpha, iid=args.iid,
            lazy=args.lazy_pool, shard_size=args.shard_size),
        schedule=ScheduleSpec(
            mode=args.mode, concurrency=args.concurrency,
            buffer_size=args.buffer_size,
            deadline_quantile=args.deadline_quantile,
            straggler=args.straggler, pad_policy=args.pad_policy,
            backoff_base=args.backoff_base, backoff_cap=args.backoff_cap),
        privacy=PrivacySpec(
            clip=args.dp_clip, noise_multiplier=args.dp_noise,
            delta=args.dp_delta, adaptive_clip=args.adaptive_clip,
            target_quantile=args.clip_quantile, clip_lr=args.clip_lr,
            secure_agg=args.secure_agg),
        faults=FaultSpec(
            dropout_prob=args.dropout_prob,
            byzantine_frac=args.byzantine_frac,
            byzantine_scale=args.byzantine_scale, attack=args.attack,
            replace_boost=args.replace_boost,
            straggler_prob=args.straggler_prob,
            trace=args.trace, trace_period=args.trace_period,
            trace_uptime=args.trace_uptime,
            aggregator=args.aggregator,
            aggregator_opts=freeze_opts(agg_opts)),
        topology=TopologySpec(
            n_silos=args.silos, assign=args.silo_assign,
            aggregator=args.silo_aggregator, trace=args.silo_trace,
            trace_period=args.trace_period,
            trace_uptime=args.trace_uptime))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="SPEC_JSON",
                    help="load the full ExperimentSpec from a JSON file "
                         "(see --dump-config); config flags are ignored, "
                         "invocation flags (--resume, --save, ...) still "
                         "apply")
    ap.add_argument("--dump-config", default=None, metavar="PATH",
                    help="write this invocation's ExperimentSpec as JSON "
                         "and exit (round-trips through --config)")
    ap.add_argument("--list-strategies", action="store_true",
                    help="print the strategy registry (name, grad programs, "
                         "accepted options) and exit")
    ap.add_argument("--describe", default=None, metavar="STRATEGY",
                    help="print one strategy's spec knobs and exit")
    ap.add_argument("--arch", default="bert_tiny")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config of --arch")
    ap.add_argument("--task", default="classification",
                    choices=["classification", "instruction"])
    ap.add_argument("--dataset", default="agnews", choices=list(DATASETS))
    ap.add_argument("--method", default="chainfed",
                    choices=available_strategies())
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "semisync", "async"],
                    help="event-driven runtime aggregation mode (sync = "
                         "legacy lockstep rounds; async counts --rounds as "
                         "server commits)")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="async: completions per server commit (FedBuff "
                         "buffer; default = concurrency)")
    ap.add_argument("--concurrency", type=int, default=None,
                    help="async: clients in flight (default clients/round)")
    ap.add_argument("--deadline-quantile", type=float, default=0.75,
                    help="semisync: cohort fraction the server waits for")
    ap.add_argument("--straggler", default="drop", choices=["drop", "carry"],
                    help="semisync: drop stragglers or commit them late "
                         "with a staleness-discounted weight")
    ap.add_argument("--dp-clip", type=float, default=None,
                    help="enable client-level DP: per-client L2 clip bound")
    ap.add_argument("--dp-noise", type=float, default=1.0,
                    help="DP noise multiplier σ (with --dp-clip)")
    ap.add_argument("--dp-delta", type=float, default=1e-5,
                    help="DP target δ for the ε report")
    ap.add_argument("--secure-agg", action="store_true",
                    help="pairwise-masked secure aggregation (sync/semisync)")
    ap.add_argument("--aggregator", default=None,
                    choices=["fedavg", "trimmed_mean", "median", "norm_clip",
                             "krum", "multi_krum"],
                    help="robust server aggregation (default: strategy's own)")
    ap.add_argument("--trim-frac", type=float, default=0.2,
                    help="per-side trim fraction for --aggregator "
                         "trimmed_mean")
    ap.add_argument("--krum-f", type=int, default=0,
                    help="krum/multi_krum: byzantine bound f (0 = auto)")
    ap.add_argument("--krum-m", type=int, default=0,
                    help="multi_krum: selection size m (0 = auto)")
    ap.add_argument("--adaptive-clip", action="store_true",
                    help="DP: adapt the clip bound toward the "
                         "--clip-quantile of observed update norms")
    ap.add_argument("--clip-quantile", type=float, default=0.5,
                    help="adaptive clipping target quantile")
    ap.add_argument("--clip-lr", type=float, default=0.2,
                    help="adaptive clipping geometric step size")
    ap.add_argument("--dropout-prob", type=float, default=0.0,
                    help="fault injection: per-dispatch client dropout "
                         "probability (semisync/async)")
    ap.add_argument("--byzantine-frac", type=float, default=0.0,
                    help="fault injection: fraction of clients sending "
                         "corrupted updates")
    ap.add_argument("--byzantine-scale", type=float, default=-10.0,
                    help="corruption factor (negative = sign flip)")
    ap.add_argument("--attack", default="scaling",
                    choices=["scaling", "replacement"],
                    help="byzantine payload: update scaling or targeted "
                         "model replacement")
    ap.add_argument("--replace-boost", type=float, default=4.0,
                    help="replacement attack boost factor")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="fault injection: per-dispatch slowdown probability")
    ap.add_argument("--trace", default=None, choices=["diurnal", "flaky"],
                    help="trace-driven client availability (semisync/async); "
                         "replaces Bernoulli dropout with replayable "
                         "availability windows")
    ap.add_argument("--trace-period", type=float, default=1000.0,
                    help="availability trace period (virtual seconds)")
    ap.add_argument("--trace-uptime", type=float, default=0.45,
                    help="diurnal trace: mean duty cycle")
    ap.add_argument("--backoff-base", type=float, default=1.0,
                    help="dispatch retry backoff base delay (with --trace)")
    ap.add_argument("--backoff-cap", type=float, default=60.0,
                    help="dispatch retry backoff delay cap")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="save the full run state every N rounds/commits")
    ap.add_argument("--checkpoint-path", default=None,
                    help="run-state checkpoint file (with --checkpoint-every)")
    ap.add_argument("--resume", default=None,
                    help="restore a run-state checkpoint and continue "
                         "(pass the same --rounds as the original run)")
    ap.add_argument("--halt-after", type=int, default=None,
                    help="stop after this round/commit (crash simulation "
                         "for the resume-equality smoke)")
    ap.add_argument("--silos", type=int, default=1,
                    help="cross-silo aggregation tier: number of silos "
                         "(1 = flat cohort)")
    ap.add_argument("--silo-assign", default="block",
                    choices=["block", "mod"],
                    help="client → silo assignment policy")
    ap.add_argument("--silo-aggregator", default="fedavg",
                    choices=["fedavg", "trimmed_mean", "median", "norm_clip",
                             "krum", "multi_krum"],
                    help="silo-tier aggregation (robust entries filter "
                         "byzantine members inside their silo)")
    ap.add_argument("--silo-trace", default=None,
                    choices=["diurnal", "flaky"],
                    help="per-silo availability trace (a silo going dark "
                         "takes its members offline)")
    ap.add_argument("--pad-policy", default="fixed",
                    choices=["fixed", "pow2"],
                    help="dispatch-bucket pad targets: fixed bucket_pad or "
                         "powers of two (per-completion async)")
    ap.add_argument("--lazy-pool", action="store_true",
                    help="lazy ClientPool population: clients synthesized "
                         "from (seed, cid) at dispatch, O(active cohort) "
                         "resident state — enables planet-scale --clients")
    ap.add_argument("--shard-size", type=int, default=None,
                    help="examples per lazy client shard")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", "--population", type=int, default=16,
                    dest="clients", help="population size")
    ap.add_argument("--clients-per-round", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--window", type=int, default=3)
    ap.add_argument("--lam", type=float, default=0.2)
    ap.add_argument("--threshold", type=float, default=0.8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--opt-bits", type=int, default=32, choices=[32, 8],
                    help="optimizer-state precision: 8 = block-wise int8 "
                         "moments, 4× less resident state per client")
    ap.add_argument("--fused-optim", default=None,
                    type=lambda s: {"true": True, "false": False}[s.lower()],
                    choices=[True, False], metavar="{true,false}",
                    help="force (true) or disable (false) the single-pass "
                         "fused optimizer step; default is backend-aware")
    ap.add_argument("--compress", default=None, choices=["topk", "qsgd"],
                    help="lossy uplink compression with error feedback "
                         "(fed.compress)")
    ap.add_argument("--compress-ratio", type=float, default=0.05,
                    help="top-k: fraction of update entries kept")
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--unconstrained-memory", action="store_true",
                    help="idealized setting (no memory wall)")
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--save", default=None, help="checkpoint path")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    if args.list_strategies:
        for d in list_strategies():
            opts = ", ".join(f"{k}={v!r}" for k, v in d["options"].items())
            print(f"{d['name']:22s} grad={'/'.join(d['grad_programs'])} "
                  f"mem={d['memory_method']}"
                  + (f"  options: {opts}" if opts else ""))
        return []
    if args.describe is not None:
        print(json.dumps(describe_strategy(args.describe), indent=1,
                         default=str))
        return []

    if args.config is not None:
        with open(args.config) as f:
            spec = ExperimentSpec.from_json(f.read())
    else:
        spec = spec_from_args(args)
    if args.dump_config is not None:
        with open(args.dump_config, "w") as f:
            f.write(spec.to_json())
        print("spec:", args.dump_config)
        return []

    cfg, _, _ = build_configs(spec)
    r = spec.run
    print(f"== {r.strategy} on {cfg.arch_id} ({r.task}/{r.dataset}) "
          f"mode={spec.schedule.mode} rounds={r.rounds} Q={r.window} "
          f"λ={r.lam} T={r.foat_threshold}"
          + (f" silos={spec.topology.n_silos}"
             if spec.topology.n_silos > 1 else "")
          + (" lazy-pool" if r.lazy else ""))
    t0 = time.time()
    result = run_experiment(
        spec=spec, verbose=True,
        checkpoint_every=args.checkpoint_every,
        checkpoint_path=args.checkpoint_path, resume=args.resume,
        halt_after=args.halt_after)
    strat, hist = result.strategy, result.history
    dt = time.time() - t0
    final = hist[-1] if hist else None
    print(f"== done in {dt:.1f}s  final acc="
          f"{final.acc if final else float('nan'):.4f}  virtual wallclock="
          f"{final.wallclock if final else 0.0:.1f}s")
    if spec.privacy.clip is not None and final is not None:
        print(f"== privacy spend: ε={final.dp_epsilon:.2f} at "
              f"δ={spec.privacy.delta:g}")
    s = result.scheduler
    if s is not None:
        if s.faults is not None or s.topology is not None:
            print(f"== churn: fault_dropouts={s.fault_dropouts} "
                  f"trace_dropouts={s.trace_dropouts} "
                  f"silo_dropouts={s.silo_dropouts} "
                  f"redispatches={s.redispatches} "
                  f"backoff_retries={s.backoff_retries}")
        if s.topology is not None and s.topology.n_silos > 1:
            print(f"== hierarchy: silos={s.topology.n_silos} "
                  f"edge_bytes={s.tier_bytes['edge']} "
                  f"silo_bytes={s.tier_bytes['silo']}")
        if r.lazy:
            print(f"== lazy pool: resident={result.sim.pool.resident} "
                  f"max_resident={result.sim.pool.max_resident} "
                  f"max_resident_bytes={result.sim.pool.max_resident_bytes}")
        if args.checkpoint_every or args.resume:
            # the crash-resume smoke parses this line: every compiled cohort
            # fn must hold exactly one cache entry (no resume recompiles)
            sizes = [f._cache_size()
                     for cache in (strat.engine._cohort,
                                   strat.engine._cohort_updates)
                     for f in cache.values() if hasattr(f, "_cache_size")]
            print(f"== jit-cache: fns={len(sizes)} sizes={sizes}")

    if args.save and hasattr(strat, "params"):
        from ..ckpt.io import save_train_state
        p = save_train_state(args.save, strat.params, strat.adapters,
                             args.rounds, {"method": args.method})
        print("checkpoint:", p)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump([m.__dict__ for m in hist], f, indent=1)
    return hist


if __name__ == "__main__":
    main()
