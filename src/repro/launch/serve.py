"""Serving launcher: batched prefill + greedy decode with the KV/SSM cache.

Host-scale demo (reduced configs) — the pod-scale variants of these exact
step functions are what the dry-run lowers for prefill_32k / decode_32k /
long_500k.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models import transformer as T


def generate(params, adapters, cfg, prompt_tokens, max_new: int,
             enc_embeds=None):
    """Greedy generation for a batch of equal-length prompts."""
    B, S = prompt_tokens.shape
    total = S + max_new
    enc_len = enc_embeds.shape[1] if enc_embeds is not None else None
    batch = {"tokens": prompt_tokens}
    if enc_embeds is not None:
        batch["enc_embeds"] = enc_embeds

    logits, pcache, n = T.prefill(params, adapters, batch, cfg)

    # grow the prefill cache to the full decode horizon
    def pad(x):
        if x.ndim >= 3 and x.shape[2] == S and x.shape[1] == B:
            w = [(0, 0)] * x.ndim
            w[2] = (0, total - S)
            return jnp.pad(x, w)
        return x

    cache = jax.tree_util.tree_map(pad, pcache)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    idx = S

    decode = jax.jit(
        lambda p, a, t, c, i: T.decode_step(p, a, t, c, i, cfg,
                                            enc_len=enc_len))
    for _ in range(max_new - 1):
        lg, cache, idx = decode(params, adapters, tok, cache, idx)
        tok = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    adapters = T.init_adapters(key, cfg)
    if args.ckpt:
        from ..ckpt.io import load_train_state
        params, adapters, _ = load_train_state(args.ckpt, params, adapters)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 4,
                                 cfg.vocab_size)
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(key, (args.batch, 32, cfg.d_model)) * 0.02

    t0 = time.time()
    toks = generate(params, adapters, cfg, prompts, args.gen, enc_embeds=enc)
    dt = time.time() - t0
    print(f"arch={cfg.arch_id} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}  wall={dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample token ids:", toks[0][:12].tolist())
    return toks


if __name__ == "__main__":
    main()
