"""Multi-tenant serving engine: one resident base model, per-tenant
chain-tuned adapter stacks, mixed-tenant batches in ONE compiled program.

ChainFed's end state is a library of frozen adapter stacks (one per task /
tenant); serving them is the other half of the train→serve story.  The
``ServeEngine`` keeps the base model resident and routes every batch row
through its own tenant's adapters:

* tenants register stacks with the ``AdapterLibrary`` (full ``(L, ...)``
  stacks, chain-tuned *window* checkpoints scattered through an
  ``ActiveAdapters`` spec, or ``ckpt.io`` files) — the library packs them
  into one ``(T, L, ...)`` pytree;
* each batch row carries a tenant id; ``adapter_apply_routed`` gathers the
  row's stack *inside* the jitted prefill/decode, so a mixed-tenant batch
  runs the exact program a single-tenant batch compiled — no per-tenant
  recompiles, no per-tenant dispatch;
* ``fuse_tenants`` registers an AdapterFusion-style weighted composition as
  a synthetic tenant — multi-task serving through the same routing path;
* ``serve`` wraps the decode loop in slot-based **continuous batching**:
  finished rows are replaced from a request queue by a jitted cache splice
  (per-row decode depths via vector ``idx``), never re-jitting.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
        --tenants 3 --batch 6 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.adapters import ActiveAdapters, AdapterLibrary
from ..core.paging import PageTable
from ..models import transformer as T


# Module-level jitted entry points, keyed on the (hashable) ModelConfig —
# repeated generate()/serve() calls across engines and benchmark iterations
# reuse one compiled program per (cfg, shapes, tenant-count) instead of
# re-tracing through per-call lambdas.
@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill_jit(params, adapters, batch, cfg, tenant_ids=None):
    return T.prefill(params, adapters, batch, cfg, tenant_ids=tenant_ids)


@functools.partial(jax.jit, static_argnames=("cfg", "enc_len"))
def _decode_jit(params, adapters, tok, cache, idx, cfg, enc_len=None,
                tenant_ids=None):
    return T.decode_step(params, adapters, tok, cache, idx, cfg,
                         enc_len=enc_len, tenant_ids=tenant_ids)


@jax.jit
def _sample_jit(logits, temps, topks, topps, key):
    """Per-row sampling: each batch row carries its own (traced) temperature,
    top-k and top-p — routed per row exactly like tenant ids, so one compiled
    sampler serves any tenant mix and re-registering sampling params never
    re-jits.  ``temps <= 0`` rows are greedy (bit-identical to the old
    ``argmax`` path); ``topks <= 0`` disables the top-k cut; ``topps`` outside
    (0, 1) disables the nucleus cut.  The nucleus is computed on the raw
    logits' softmax (same basis as top-k): the smallest descending-order set
    whose probability mass reaches ``top_p``.  Both cuts intersect; sampling
    uses the Gumbel-max trick on the masked, temperature-scaled logits."""
    V = logits.shape[-1]
    # top_k ≤ 0 or ≥ V both mean "no cut" — clamp so an over-large k never
    # wraps the kth-largest index negative (which would *tighten* the cut)
    k = jnp.where(topks <= 0, V, jnp.minimum(topks, V)).astype(jnp.int32)
    srt = jnp.sort(logits, axis=-1)                       # ascending
    kth = jnp.take_along_axis(srt, (V - k)[:, None], axis=-1)
    masked = jnp.where(logits >= kth, logits, -jnp.inf)
    # nucleus cut, in descending-sorted space: keep tokens whose *exclusive*
    # cumulative mass is < p (the top token always survives), then threshold
    # the raw logits at the last kept value.  p outside (0, 1) maps to an
    # always-true predicate, so "off" leaves ``masked`` bit-identical.
    p_keep = jnp.where((topps <= 0.0) | (topps >= 1.0), 2.0,
                       topps).astype(jnp.float32)[:, None]
    desc = srt[:, ::-1]
    probs = jax.nn.softmax(desc.astype(jnp.float32), axis=-1)
    cum = jnp.cumsum(probs, axis=-1) - probs              # exclusive
    n_keep = jnp.sum((cum < p_keep).astype(jnp.int32), axis=-1)
    pth = jnp.take_along_axis(desc, (n_keep - 1)[:, None], axis=-1)
    masked = jnp.where(logits >= pth, masked, -jnp.inf)
    g = -jnp.log(-jnp.log(
        jax.random.uniform(key, logits.shape) + 1e-20) + 1e-20)
    z = masked / jnp.maximum(temps, 1e-6)[:, None] + g
    return jnp.where(temps > 0, jnp.argmax(z, axis=-1),
                     jnp.argmax(logits, axis=-1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _decode_paged_jit(params, adapters, tok, cache, pages, idx, cfg,
                      tenant_ids=None):
    return T.decode_step_paged(params, adapters, tok, cache, pages, idx, cfg,
                               tenant_ids=tenant_ids)


@jax.jit
def _paged_splice_kv_jit(pool, small, pages):
    """Write a single-row prefill KV (``(L, 1, S, KV, hd)`` leaves) into the
    paged pool (``(L, P, page_size, KV, hd)`` leaves) at the row's ``pages``
    (``(ceil(S / page_size),)`` int32) — the paged admission step.  Page ids
    are traced, so admissions never recompile; entries set to the sentinel
    ``P`` (already-populated shared prefix pages) are skipped via
    scatter-``drop``."""
    def leaf(p, s):
        L, S = s.shape[0], s.shape[2]
        ps = p.shape[2]
        npp = pages.shape[0]
        pad = [(0, 0)] * (s.ndim - 1)
        pad[1] = (0, npp * ps - S)
        blk = jnp.pad(s[:, 0], pad).reshape((L, npp, ps) + s.shape[3:])
        return p.at[:, pages].set(blk.astype(p.dtype), mode="drop")
    return jax.tree_util.tree_map(leaf, pool, small)


def _claim_slot(live, slot, rid):
    """Admission guard: a busy slot must never be clobbered by a new
    request.  (The serve loop only admits into drained slots, but any future
    external admission path hits this check first.)"""
    if live[slot] is not None:
        raise RuntimeError(
            f"no free slots: slot {slot} is busy with request "
            f"{live[slot][0]!r}; admitting {rid!r} would clobber a live row "
            f"— wait for a drain or serve with more slots")


@jax.jit
def _splice_jit(big, small, slot):
    """Write a single-row prefill cache (padded to the decode horizon) into
    row ``slot`` of the serve loop's batch cache — the continuous-batching
    admission step.  ``slot`` is traced, so admissions never recompile.

    The sequence axis is found *structurally*: the one axis (besides batch
    axis 1) where the single-row leaf is shorter than the batch cache leaf.
    State leaves with no sequence axis (SSM conv/state) match the batch
    cache exactly and splice as-is — no shape coincidences with the prompt
    length can misfire."""
    def leaf(b, s):
        diff = [a for a in range(s.ndim)
                if a != 1 and s.shape[a] != b.shape[a]]
        if diff:
            assert len(diff) == 1, (s.shape, b.shape)
            w = [(0, 0)] * s.ndim
            w[diff[0]] = (0, b.shape[diff[0]] - s.shape[diff[0]])
            s = jnp.pad(s, w)
        return jax.lax.dynamic_update_index_in_dim(b, s[:, 0], slot, axis=1)
    return jax.tree_util.tree_map(leaf, big, small)


def generate(params, adapters, cfg, prompt_tokens, max_new: int,
             enc_embeds=None, tenant_ids=None):
    """Greedy generation for a batch of equal-length prompts.

    ``tenant_ids`` (B,) switches multi-tenant routing on — ``adapters`` is
    then the tenant library in scan layout (L, T, ...)
    (``AdapterLibrary.stacked_scan()``)."""
    B, S = prompt_tokens.shape
    total = S + max_new
    enc_len = enc_embeds.shape[1] if enc_embeds is not None else None
    batch = {"tokens": prompt_tokens}
    if enc_embeds is not None:
        batch["enc_embeds"] = enc_embeds

    logits, pcache, n = _prefill_jit(params, adapters, batch, cfg=cfg,
                                     tenant_ids=tenant_ids)

    # grow the prefill cache to the full decode horizon
    def pad(x):
        if x.ndim >= 3 and x.shape[2] == S and x.shape[1] == B:
            w = [(0, 0)] * x.ndim
            w[2] = (0, total - S)
            return jnp.pad(x, w)
        return x

    cache = jax.tree_util.tree_map(pad, pcache)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    idx = S

    for _ in range(max_new - 1):
        lg, cache, idx = _decode_jit(params, adapters, tok, cache, idx,
                                     cfg=cfg, enc_len=enc_len,
                                     tenant_ids=tenant_ids)
        tok = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-tenant decode-time sampling configuration.  ``temperature <= 0``
    means greedy; ``top_k <= 0`` means no top-k cut; ``top_p`` outside
    (0, 1) means no nucleus cut (so the default 1.0 is off)."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0


@dataclasses.dataclass
class Request:
    """One queued generation request (prompt already padded to the serve
    loop's fixed prompt length)."""
    rid: int
    tokens: np.ndarray          # (prompt_len,) int32
    tenant: str
    max_new: int


class ServeEngine:
    """Multi-tenant adapter serving on top of ``AdapterLibrary``.

    One engine = one resident base model + one tenant library.  Batch
    methods (``generate``, ``serve``) take per-row tenant *names* and route
    through the library's ``(T, L, ...)`` stack; registration invalidates
    the stacked cache but never the compiled programs (tenant ids are traced
    data — only a change of T, i.e. onboarding, triggers a recompile).
    """

    def __init__(self, params, cfg, base_adapters, resident_capacity=None):
        self.params, self.cfg = params, cfg
        self.library = AdapterLibrary(base=base_adapters,
                                      resident_capacity=resident_capacity)
        self._sampling = {}         # tenant name -> SamplingParams
        self.last_serve_stats = {}  # filled by every serve() run

    # ------------------------------------------------------------- tenants
    def register_tenant(self, name, stack=None, ckpt=None,
                        spec: ActiveAdapters | None = None,
                        sampling: SamplingParams | None = None):
        """Register a tenant's chain-tuned stack.

        ``stack`` — a full ``(L, ...)`` stack, or (with ``spec``) only the
        spec's trainable window, scattered into the library base.
        ``ckpt`` — a ``ckpt.io.save_adapter_stack`` file loaded into the
        matching structure instead of an in-memory stack.
        ``sampling`` — this tenant's decode-time ``SamplingParams``
        (default greedy)."""
        if (stack is None) == (ckpt is None):
            raise ValueError("register_tenant: exactly one of stack / ckpt")
        if ckpt is not None:
            from ..ckpt.io import load_adapter_stack
            base = self.library._base
            like = spec.train_slice(base) if spec is not None else base
            stack, _meta = load_adapter_stack(ckpt, like)
        self.library.add(name, stack, spec=spec)
        if sampling is not None:
            self._sampling[name] = sampling
        return name

    def set_sampling(self, name, temperature: float = 0.0, top_k: int = 0,
                     top_p: float = 1.0):
        """(Re)configure a tenant's decode-time sampling.  Params are traced
        per-row data in the serve loop — changing them never recompiles."""
        self.library.tenant_id(name)     # raises on unknown tenant
        self._sampling[name] = SamplingParams(temperature, top_k, top_p)

    def _tenant_sampling(self, name) -> SamplingParams:
        return self._sampling.get(name, SamplingParams())

    def fuse_tenants(self, name, parts, weights=None):
        """Serve a weighted multi-task composition as a synthetic tenant."""
        self.library.fuse(weights=weights, names=parts, into=name)
        return name

    # ------------------------------------------------------------ batching
    def generate(self, prompt_tokens, tenants, max_new: int):
        """Mixed-tenant batched generation: row i of ``prompt_tokens`` runs
        tenant ``tenants[i]``'s adapter stack.  Under a library resident
        capacity, ``route_ids`` first makes the batch's tenants device-
        resident (LRU upload/evict) and the ids index the resident slab."""
        ids = self.library.route_ids(tenants)
        return generate(self.params, self.library.stacked_scan(), self.cfg,
                        prompt_tokens, max_new, tenant_ids=ids)

    # ------------------------------------------- continuous (slot) batching
    def serve(self, requests, slots: int = 4, prompt_len: int = 16,
              max_new_cap: int = 16, sample_seed: int = 0,
              paged: bool = False, page_size: int = 8,
              n_pages: int | None = None,
              shared_prefix_len: int | None = None):
        """Slot-based continuous batching over a request queue.

        A fixed ``(slots,)``-row decode program runs every step; each row
        carries its own decode depth (vector ``idx``), tenant id **and the
        tenant's sampling params** (temperature / top-k / top-p — per-row
        traced data through ``_sample_jit``, exactly like tenant routing, so
        mixed greedy/sampling batches never re-jit).  When a row finishes,
        the next queued request is admitted by a single-row jitted prefill +
        a jitted cache splice — the decode program never re-jits, whatever
        the admission pattern.  Drained slots park at an out-of-range
        ``idx`` (their cache writes scatter to nothing) until the queue
        refills them.

        ``paged=True`` serves over the **paged KV pool** instead of the
        dense ``(L, slots, horizon, ...)`` slot cache: a ``PageTable``
        allocates each request exactly ``ceil((prompt_len + max_new - 1) /
        page_size)`` pages at admission and releases them at drain, so a
        long-tail request mix pays its actual token footprint, not the
        horizon.  ``n_pages`` sizes the pool (default: worst case,
        ``slots * ceil(horizon / page_size)``); when the pool is exhausted
        admission backpressures (the request waits for a drain).
        ``shared_prefix_len`` (page-aligned, ≤ prompt_len) refcount-shares
        each tenant's leading prompt pages across concurrent requests — the
        common-system-prompt KV is stored once per tenant.  Page tables ride
        into the jitted decode as traced data: the paged program compiles
        once, whatever the admission/drain pattern.

        Sampling is reproducible: row randomness derives from
        ``sample_seed`` folded with the decode-step / admission counters.
        Tenants without registered ``SamplingParams`` decode greedily —
        bit-identical to the pre-sampling serve loop.

        Rows are independent through attention/SSM state, so outputs equal
        the static-batch path row-for-row on dense/ssm/hybrid families
        (MoE capacity routing is batch-composition-dependent — same caveat
        as the decode exactness tests), and the paged path equals the dense
        path token-for-token.  Returns {rid: np.ndarray tokens}; per-run
        counters land in ``self.last_serve_stats``.
        """
        cfg = self.cfg
        requests = list(requests)
        if slots < 1:
            raise ValueError(f"serve needs slots >= 1, got {slots}")
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            dup = sorted({r for r in rids if rids.count(r) > 1})
            raise ValueError(f"duplicate request ids {dup}: outputs are "
                             f"keyed by rid")
        for r in requests:
            if len(r.tokens) != prompt_len:
                raise ValueError(
                    f"request {r.rid!r}: prompt has {len(r.tokens)} tokens "
                    f"but the serve loop is fixed at prompt_len={prompt_len}")
        # independent streams for the decode loop and admissions, each
        # folded with its own counter — replays are bit-identical
        step_key, admit_key = jax.random.split(jax.random.PRNGKey(sample_seed))
        total = prompt_len + max_new_cap
        if cfg.sliding_window is not None and total > cfg.sliding_window:
            raise NotImplementedError(
                f"continuous batching beyond the sliding window "
                f"(horizon {total} > window {cfg.sliding_window}): the ring "
                f"buffer would wrap mid-request; cap max_new_cap or serve "
                f"with full attention")

        table = None
        if paged:
            if shared_prefix_len is not None:
                if shared_prefix_len % page_size:
                    raise ValueError(
                        f"shared_prefix_len={shared_prefix_len} must be a "
                        f"multiple of page_size={page_size} (only whole "
                        f"pages are shared)")
                if shared_prefix_len > prompt_len:
                    raise ValueError(f"shared_prefix_len={shared_prefix_len}"
                                     f" > prompt_len={prompt_len}")
            mp = -(-total // page_size)
            if n_pages is None:
                n_pages = slots * mp
            table = PageTable(n_pages, page_size, slots, mp)
            cache = T.init_paged_cache(cfg, slots, n_pages, page_size)
            pages_np = table.rows()       # live view, refreshed in place
            park = mp * page_size         # past every page: writes drop
        else:
            cache = T.init_cache(cfg, slots, total)
            park = total                  # one-hot OOB: parked rows write nothing

        queue = collections.deque(requests)
        tok = np.zeros((slots, 1), np.int32)
        idx = np.full((slots,), park, np.int32)
        tids = np.zeros((slots,), np.int32)
        temps = np.zeros((slots,), np.float32)    # per-row sampling params,
        topks = np.zeros((slots,), np.int32)      # refreshed at admission
        topps = np.ones((slots,), np.float32)
        live = [None] * slots             # per-slot [rid, remaining, tenant]
        out = {r.rid: [] for r in requests}
        n_admits = 0
        n_steps = 0

        def admit(slot, req):
            """Admit ``req`` into ``slot``; False = backpressure (page pool
            exhausted — the request waits for a drain)."""
            nonlocal cache, n_admits
            _claim_slot(live, slot, req.rid)
            n_store = prompt_len + req.max_new - 1   # tokens this slot writes
            shared, fresh = (), False
            if paged:
                if shared_prefix_len:
                    pkey = (req.tenant,
                            np.asarray(req.tokens[:shared_prefix_len],
                                       np.int32).tobytes())
                    # a fresh registration takes pages itself — only
                    # register when the whole request fits
                    if table.has_prefix(pkey) or table.can_admit(n_store):
                        shared, fresh = table.share_prefix(
                            pkey, shared_prefix_len)
                if not table.can_admit(n_store, shared=shared):
                    return False
                row_pages = table.admit(slot, n_store, shared=shared)
            # pin live tenants: their resident-slab rows are mid-flight
            pin = tuple(l[2] for l in live if l is not None)
            tid = self.library.route_ids([req.tenant], pin=pin)
            lib = self.library.stacked_scan()
            sp = self._tenant_sampling(req.tenant)
            lg, pcache, _ = _prefill_jit(self.params, lib,
                                         {"tokens": jnp.asarray(req.tokens)[None]},
                                         cfg=cfg, tenant_ids=tid)
            if paged:
                if cache["kv"]:
                    npp = -(-prompt_len // page_size)
                    wp = [int(p) for p in row_pages[:npp]]
                    if shared and not fresh:     # already populated: skip
                        for i in range(min(len(shared), npp)):
                            wp[i] = table.n_pages
                    kv_small = {k: pcache[k] for k in ("k", "v")
                                if k in pcache}
                    cache["kv"] = _paged_splice_kv_jit(
                        cache["kv"], kv_small, jnp.asarray(wp, jnp.int32))
                if cache["state"]:
                    st_small = {k: pcache[k] for k in cache["state"]}
                    cache["state"] = _splice_jit(cache["state"], st_small,
                                                 slot)
            else:
                cache = _splice_jit(cache, pcache, slot)
            n_admits += 1
            first = int(_sample_jit(
                lg, jnp.asarray([sp.temperature], jnp.float32),
                jnp.asarray([sp.top_k], jnp.int32),
                jnp.asarray([sp.top_p], jnp.float32),
                jax.random.fold_in(admit_key, n_admits))[0])
            out[req.rid].append(first)
            tok[slot, 0] = first
            idx[slot] = prompt_len
            tids[slot] = int(tid[0])
            temps[slot] = sp.temperature
            topks[slot] = sp.top_k
            topps[slot] = sp.top_p
            live[slot] = [req.rid, req.max_new - 1, req.tenant]
            return True

        def drain(slot):
            live[slot] = None
            idx[slot] = park
            if paged:
                table.release(slot)

        while queue or any(live):
            stalled = False
            for s in range(slots):
                if live[s] is None and queue:
                    req = queue.popleft()
                    if not admit(s, req):
                        queue.appendleft(req)     # FIFO backpressure
                        stalled = True
                        break
                    if req.max_new <= 1:          # prefill already emitted it
                        drain(s)
            if not any(live):
                if stalled:
                    raise RuntimeError(
                        f"page pool too small: {table.n_pages} pages "
                        f"(page_size={table.page_size}) cannot admit even "
                        f"one queued request with every slot drained; grow "
                        f"n_pages")
                continue
            lib = self.library.stacked_scan()
            if paged:
                lg, cache, _ = _decode_paged_jit(
                    self.params, lib, jnp.asarray(tok), cache,
                    jnp.asarray(pages_np), jnp.asarray(idx), cfg=cfg,
                    tenant_ids=jnp.asarray(tids))
            else:
                lg, cache, _ = _decode_jit(self.params, lib, jnp.asarray(tok),
                                           cache, jnp.asarray(idx), cfg=cfg,
                                           tenant_ids=jnp.asarray(tids))
            n_steps += 1
            nxt = np.asarray(_sample_jit(lg, jnp.asarray(temps),
                                         jnp.asarray(topks),
                                         jnp.asarray(topps),
                                         jax.random.fold_in(step_key,
                                                            n_steps)),
                             np.int32)
            for s in range(slots):
                if live[s] is None:
                    continue
                out[live[s][0]].append(int(nxt[s]))
                tok[s, 0] = nxt[s]
                idx[s] += 1
                live[s][1] -= 1
                if live[s][1] <= 0:
                    drain(s)

        self.last_serve_stats = {
            "steps": n_steps, "admits": n_admits, "paged": bool(paged),
            "adapter": dict(self.library.stats),
            "adapter_hit_rate": self.library.hit_rate,
        }
        if paged:
            table.drop_prefixes()
            self.last_serve_stats["pages"] = table.stats()
        return {rid: np.asarray(toks, np.int32) for rid, toks in out.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--tenants", type=int, default=1,
                    help=">= 2 serves a mixed-tenant batch through the "
                         "ServeEngine (smoke mode also row-checks it against "
                         "per-tenant generation)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    adapters = T.init_adapters(key, cfg)
    if args.ckpt:
        from ..ckpt.io import load_train_state
        params, adapters, _ = load_train_state(args.ckpt, params, adapters)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 4,
                                 cfg.vocab_size)
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(key, (args.batch, 32, cfg.d_model)) * 0.02

    if args.tenants <= 1:
        t0 = time.time()
        toks = generate(params, adapters, cfg, prompts, args.gen,
                        enc_embeds=enc)
        dt = time.time() - t0
        print(f"arch={cfg.arch_id} batch={args.batch} "
              f"prompt={args.prompt_len} gen={args.gen}  wall={dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        print("sample token ids:", toks[0][:12].tolist())
        return toks

    # ---- multi-tenant path: N distinct tenants + a fused synthetic tenant
    engine = ServeEngine(params, cfg, adapters)
    names = []
    for i in range(args.tenants):
        k = jax.random.PRNGKey(100 + i)
        stack = jax.tree_util.tree_map(
            lambda x: x + 0.02 * jax.random.normal(k, x.shape, x.dtype),
            adapters)
        names.append(engine.register_tenant(f"tenant{i}", stack=stack))
    if len(names) >= 2:
        engine.fuse_tenants("fused", names[:2], weights=[0.5, 0.5])
        names.append("fused")
    row_tenants = [names[i % len(names)] for i in range(args.batch)]

    t0 = time.time()
    toks = engine.generate(prompts, row_tenants, args.gen)
    dt = time.time() - t0
    print(f"arch={cfg.arch_id} batch={args.batch} tenants={len(names)} "
          f"mix={row_tenants}  wall={dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")

    if args.smoke:
        # row-for-row: the mixed batch must equal per-tenant generation
        for name in sorted(set(row_tenants)):
            rows = jnp.asarray([i for i, t in enumerate(row_tenants)
                                if t == name])
            ref = generate(params, engine.library.resolve(name), cfg,
                           prompts[rows], args.gen)
            assert bool(jnp.all(toks[rows] == ref)), (
                f"mixed-tenant rows diverge from tenant {name!r}")
        print(f"# smoke OK: mixed-tenant batch == per-tenant generation "
              f"({len(names)} tenants incl. fused)")
    return toks


if __name__ == "__main__":
    main()
