"""Multi-tenant serving engine: one resident base model, per-tenant
chain-tuned adapter stacks, mixed-tenant batches in ONE compiled program.

ChainFed's end state is a library of frozen adapter stacks (one per task /
tenant); serving them is the other half of the train→serve story.  The
``ServeEngine`` keeps the base model resident and routes every batch row
through its own tenant's adapters:

* tenants register stacks with the ``AdapterLibrary`` (full ``(L, ...)``
  stacks, chain-tuned *window* checkpoints scattered through an
  ``ActiveAdapters`` spec, or ``ckpt.io`` files) — the library packs them
  into one ``(T, L, ...)`` pytree;
* each batch row carries a tenant id; ``adapter_apply_routed`` gathers the
  row's stack *inside* the jitted prefill/decode, so a mixed-tenant batch
  runs the exact program a single-tenant batch compiled — no per-tenant
  recompiles, no per-tenant dispatch;
* ``fuse_tenants`` registers an AdapterFusion-style weighted composition as
  a synthetic tenant — multi-task serving through the same routing path;
* ``serve`` wraps the decode loop in slot-based **continuous batching**:
  finished rows are replaced from a request queue by a jitted cache splice
  (per-row decode depths via vector ``idx``), never re-jitting.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
        --tenants 3 --batch 6 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.adapters import ActiveAdapters, AdapterLibrary
from ..models import transformer as T


# Module-level jitted entry points, keyed on the (hashable) ModelConfig —
# repeated generate()/serve() calls across engines and benchmark iterations
# reuse one compiled program per (cfg, shapes, tenant-count) instead of
# re-tracing through per-call lambdas.
@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill_jit(params, adapters, batch, cfg, tenant_ids=None):
    return T.prefill(params, adapters, batch, cfg, tenant_ids=tenant_ids)


@functools.partial(jax.jit, static_argnames=("cfg", "enc_len"))
def _decode_jit(params, adapters, tok, cache, idx, cfg, enc_len=None,
                tenant_ids=None):
    return T.decode_step(params, adapters, tok, cache, idx, cfg,
                         enc_len=enc_len, tenant_ids=tenant_ids)


@jax.jit
def _sample_jit(logits, temps, topks, key):
    """Per-row sampling: each batch row carries its own (traced) temperature
    and top-k — routed per row exactly like tenant ids, so one compiled
    sampler serves any tenant mix and re-registering sampling params never
    re-jits.  ``temps <= 0`` rows are greedy (bit-identical to the old
    ``argmax`` path); ``topks <= 0`` disables the top-k cut.  Sampling uses
    the Gumbel-max trick on the top-k-masked, temperature-scaled logits."""
    V = logits.shape[-1]
    # top_k ≤ 0 or ≥ V both mean "no cut" — clamp so an over-large k never
    # wraps the kth-largest index negative (which would *tighten* the cut)
    k = jnp.where(topks <= 0, V, jnp.minimum(topks, V)).astype(jnp.int32)
    srt = jnp.sort(logits, axis=-1)                       # ascending
    kth = jnp.take_along_axis(srt, (V - k)[:, None], axis=-1)
    masked = jnp.where(logits >= kth, logits, -jnp.inf)
    g = -jnp.log(-jnp.log(
        jax.random.uniform(key, logits.shape) + 1e-20) + 1e-20)
    z = masked / jnp.maximum(temps, 1e-6)[:, None] + g
    return jnp.where(temps > 0, jnp.argmax(z, axis=-1),
                     jnp.argmax(logits, axis=-1)).astype(jnp.int32)


@jax.jit
def _splice_jit(big, small, slot):
    """Write a single-row prefill cache (padded to the decode horizon) into
    row ``slot`` of the serve loop's batch cache — the continuous-batching
    admission step.  ``slot`` is traced, so admissions never recompile.

    The sequence axis is found *structurally*: the one axis (besides batch
    axis 1) where the single-row leaf is shorter than the batch cache leaf.
    State leaves with no sequence axis (SSM conv/state) match the batch
    cache exactly and splice as-is — no shape coincidences with the prompt
    length can misfire."""
    def leaf(b, s):
        diff = [a for a in range(s.ndim)
                if a != 1 and s.shape[a] != b.shape[a]]
        if diff:
            assert len(diff) == 1, (s.shape, b.shape)
            w = [(0, 0)] * s.ndim
            w[diff[0]] = (0, b.shape[diff[0]] - s.shape[diff[0]])
            s = jnp.pad(s, w)
        return jax.lax.dynamic_update_index_in_dim(b, s[:, 0], slot, axis=1)
    return jax.tree_util.tree_map(leaf, big, small)


def generate(params, adapters, cfg, prompt_tokens, max_new: int,
             enc_embeds=None, tenant_ids=None):
    """Greedy generation for a batch of equal-length prompts.

    ``tenant_ids`` (B,) switches multi-tenant routing on — ``adapters`` is
    then the tenant library in scan layout (L, T, ...)
    (``AdapterLibrary.stacked_scan()``)."""
    B, S = prompt_tokens.shape
    total = S + max_new
    enc_len = enc_embeds.shape[1] if enc_embeds is not None else None
    batch = {"tokens": prompt_tokens}
    if enc_embeds is not None:
        batch["enc_embeds"] = enc_embeds

    logits, pcache, n = _prefill_jit(params, adapters, batch, cfg=cfg,
                                     tenant_ids=tenant_ids)

    # grow the prefill cache to the full decode horizon
    def pad(x):
        if x.ndim >= 3 and x.shape[2] == S and x.shape[1] == B:
            w = [(0, 0)] * x.ndim
            w[2] = (0, total - S)
            return jnp.pad(x, w)
        return x

    cache = jax.tree_util.tree_map(pad, pcache)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    idx = S

    for _ in range(max_new - 1):
        lg, cache, idx = _decode_jit(params, adapters, tok, cache, idx,
                                     cfg=cfg, enc_len=enc_len,
                                     tenant_ids=tenant_ids)
        tok = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-tenant decode-time sampling configuration.  ``temperature <= 0``
    means greedy; ``top_k <= 0`` means no top-k cut."""
    temperature: float = 0.0
    top_k: int = 0


@dataclasses.dataclass
class Request:
    """One queued generation request (prompt already padded to the serve
    loop's fixed prompt length)."""
    rid: int
    tokens: np.ndarray          # (prompt_len,) int32
    tenant: str
    max_new: int


class ServeEngine:
    """Multi-tenant adapter serving on top of ``AdapterLibrary``.

    One engine = one resident base model + one tenant library.  Batch
    methods (``generate``, ``serve``) take per-row tenant *names* and route
    through the library's ``(T, L, ...)`` stack; registration invalidates
    the stacked cache but never the compiled programs (tenant ids are traced
    data — only a change of T, i.e. onboarding, triggers a recompile).
    """

    def __init__(self, params, cfg, base_adapters):
        self.params, self.cfg = params, cfg
        self.library = AdapterLibrary(base=base_adapters)
        self._sampling = {}         # tenant name -> SamplingParams

    # ------------------------------------------------------------- tenants
    def register_tenant(self, name, stack=None, ckpt=None,
                        spec: ActiveAdapters | None = None,
                        sampling: SamplingParams | None = None):
        """Register a tenant's chain-tuned stack.

        ``stack`` — a full ``(L, ...)`` stack, or (with ``spec``) only the
        spec's trainable window, scattered into the library base.
        ``ckpt`` — a ``ckpt.io.save_adapter_stack`` file loaded into the
        matching structure instead of an in-memory stack.
        ``sampling`` — this tenant's decode-time ``SamplingParams``
        (default greedy)."""
        if (stack is None) == (ckpt is None):
            raise ValueError("register_tenant: exactly one of stack / ckpt")
        if ckpt is not None:
            from ..ckpt.io import load_adapter_stack
            base = self.library._base
            like = spec.train_slice(base) if spec is not None else base
            stack, _meta = load_adapter_stack(ckpt, like)
        self.library.add(name, stack, spec=spec)
        if sampling is not None:
            self._sampling[name] = sampling
        return name

    def set_sampling(self, name, temperature: float = 0.0, top_k: int = 0):
        """(Re)configure a tenant's decode-time sampling.  Params are traced
        per-row data in the serve loop — changing them never recompiles."""
        self.library.tenant_id(name)     # raises on unknown tenant
        self._sampling[name] = SamplingParams(temperature, top_k)

    def _tenant_sampling(self, name) -> SamplingParams:
        return self._sampling.get(name, SamplingParams())

    def fuse_tenants(self, name, parts, weights=None):
        """Serve a weighted multi-task composition as a synthetic tenant."""
        self.library.fuse(weights=weights, names=parts, into=name)
        return name

    # ------------------------------------------------------------ batching
    def generate(self, prompt_tokens, tenants, max_new: int):
        """Mixed-tenant batched generation: row i of ``prompt_tokens`` runs
        tenant ``tenants[i]``'s adapter stack."""
        ids = self.library.tenant_ids(tenants)
        return generate(self.params, self.library.stacked_scan(), self.cfg,
                        prompt_tokens, max_new, tenant_ids=ids)

    # ------------------------------------------- continuous (slot) batching
    def serve(self, requests, slots: int = 4, prompt_len: int = 16,
              max_new_cap: int = 16, sample_seed: int = 0):
        """Slot-based continuous batching over a request queue.

        A fixed ``(slots,)``-row decode program runs every step; each row
        carries its own decode depth (vector ``idx``), tenant id **and the
        tenant's sampling params** (temperature / top-k — per-row traced
        data through ``_sample_jit``, exactly like tenant routing, so mixed
        greedy/sampling batches never re-jit).  When a row finishes, the
        next queued request is admitted by a single-row jitted prefill + a
        jitted cache splice — the decode program never re-jits, whatever
        the admission pattern.  Drained slots park at ``idx = horizon``
        (their cache writes one-hot to nothing) until the queue refills
        them.

        Sampling is reproducible: row randomness derives from
        ``sample_seed`` folded with the decode-step / admission counters.
        Tenants without registered ``SamplingParams`` decode greedily —
        bit-identical to the pre-sampling serve loop.

        Rows are independent through attention/SSM state, so outputs equal
        the static-batch path row-for-row on dense/ssm/hybrid families
        (MoE capacity routing is batch-composition-dependent — same caveat
        as the decode exactness tests).  Returns {rid: np.ndarray tokens}.
        """
        cfg = self.cfg
        lib = self.library.stacked_scan()
        # independent streams for the decode loop and admissions, each
        # folded with its own counter — replays are bit-identical
        step_key, admit_key = jax.random.split(jax.random.PRNGKey(sample_seed))
        total = prompt_len + max_new_cap
        if cfg.sliding_window is not None and total > cfg.sliding_window:
            raise NotImplementedError(
                f"continuous batching beyond the sliding window "
                f"(horizon {total} > window {cfg.sliding_window}): the ring "
                f"buffer would wrap mid-request; cap max_new_cap or serve "
                f"with full attention")
        park = total                      # one-hot OOB: parked rows write nothing

        queue = collections.deque(requests)
        cache = T.init_cache(cfg, slots, total)
        tok = np.zeros((slots, 1), np.int32)
        idx = np.full((slots,), park, np.int32)
        tids = np.zeros((slots,), np.int32)
        temps = np.zeros((slots,), np.float32)    # per-row sampling params,
        topks = np.zeros((slots,), np.int32)      # refreshed at admission
        live = [None] * slots             # per-slot (rid, remaining)
        out = {r.rid: [] for r in queue}
        n_admits = 0
        n_steps = 0

        def admit(slot, req):
            nonlocal cache, n_admits
            tid = self.library.tenant_ids([req.tenant])
            sp = self._tenant_sampling(req.tenant)
            lg, pcache, _ = _prefill_jit(self.params, lib,
                                         {"tokens": jnp.asarray(req.tokens)[None]},
                                         cfg=cfg, tenant_ids=tid)
            cache = _splice_jit(cache, pcache, slot)
            n_admits += 1
            first = int(_sample_jit(
                lg, jnp.asarray([sp.temperature], jnp.float32),
                jnp.asarray([sp.top_k], jnp.int32),
                jax.random.fold_in(admit_key, n_admits))[0])
            out[req.rid].append(first)
            tok[slot, 0] = first
            idx[slot] = prompt_len
            tids[slot] = int(tid[0])
            temps[slot] = sp.temperature
            topks[slot] = sp.top_k
            live[slot] = [req.rid, req.max_new - 1]

        while queue or any(live):
            for s in range(slots):
                if live[s] is None and queue:
                    req = queue.popleft()
                    admit(s, req)
                    if req.max_new <= 1:            # prefill already emitted it
                        idx[s] = park
                        live[s] = None
            if not any(live):
                continue
            lg, cache, _ = _decode_jit(self.params, lib, jnp.asarray(tok),
                                       cache, jnp.asarray(idx), cfg=cfg,
                                       tenant_ids=jnp.asarray(tids))
            n_steps += 1
            nxt = np.asarray(_sample_jit(lg, jnp.asarray(temps),
                                         jnp.asarray(topks),
                                         jax.random.fold_in(step_key,
                                                            n_steps)),
                             np.int32)
            for s in range(slots):
                if live[s] is None:
                    continue
                out[live[s][0]].append(int(nxt[s]))
                tok[s, 0] = nxt[s]
                idx[s] += 1
                live[s][1] -= 1
                if live[s][1] <= 0:
                    live[s] = None
                    idx[s] = park
        return {rid: np.asarray(toks, np.int32) for rid, toks in out.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--tenants", type=int, default=1,
                    help=">= 2 serves a mixed-tenant batch through the "
                         "ServeEngine (smoke mode also row-checks it against "
                         "per-tenant generation)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    adapters = T.init_adapters(key, cfg)
    if args.ckpt:
        from ..ckpt.io import load_train_state
        params, adapters, _ = load_train_state(args.ckpt, params, adapters)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 4,
                                 cfg.vocab_size)
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(key, (args.batch, 32, cfg.d_model)) * 0.02

    if args.tenants <= 1:
        t0 = time.time()
        toks = generate(params, adapters, cfg, prompts, args.gen,
                        enc_embeds=enc)
        dt = time.time() - t0
        print(f"arch={cfg.arch_id} batch={args.batch} "
              f"prompt={args.prompt_len} gen={args.gen}  wall={dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        print("sample token ids:", toks[0][:12].tolist())
        return toks

    # ---- multi-tenant path: N distinct tenants + a fused synthetic tenant
    engine = ServeEngine(params, cfg, adapters)
    names = []
    for i in range(args.tenants):
        k = jax.random.PRNGKey(100 + i)
        stack = jax.tree_util.tree_map(
            lambda x: x + 0.02 * jax.random.normal(k, x.shape, x.dtype),
            adapters)
        names.append(engine.register_tenant(f"tenant{i}", stack=stack))
    if len(names) >= 2:
        engine.fuse_tenants("fused", names[:2], weights=[0.5, 0.5])
        names.append("fused")
    row_tenants = [names[i % len(names)] for i in range(args.batch)]

    t0 = time.time()
    toks = engine.generate(prompts, row_tenants, args.gen)
    dt = time.time() - t0
    print(f"arch={cfg.arch_id} batch={args.batch} tenants={len(names)} "
          f"mix={row_tenants}  wall={dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")

    if args.smoke:
        # row-for-row: the mixed batch must equal per-tenant generation
        for name in sorted(set(row_tenants)):
            rows = jnp.asarray([i for i, t in enumerate(row_tenants)
                                if t == name])
            ref = generate(params, engine.library.resolve(name), cfg,
                           prompts[rows], args.gen)
            assert bool(jnp.all(toks[rows] == ref)), (
                f"mixed-tenant rows diverge from tenant {name!r}")
        print(f"# smoke OK: mixed-tenant batch == per-tenant generation "
              f"({len(names)} tenants incl. fused)")
    return toks


if __name__ == "__main__":
    main()
