import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): for every (architecture × input shape),
lower + compile the pjit step on the production mesh — 16×16 single pod and
2×16×16 multi-pod — and extract the roofline terms from the compiled
artifact.  No tensor is ever allocated: inputs are ShapeDtypeStructs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma_2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--seq-shard]
    PYTHONPATH=src python -m repro.launch.dryrun --all --step e2e   # Full Adapters† memory comparison

Outputs one JSON per case under experiments/dryrun/ (consumed by
benchmarks/roofline.py and EXPERIMENTS.md §Dry-run/§Roofline).
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax

from ..configs import ASSIGNED, get_config
from ..launch import input_specs as ispec
from ..launch.mesh import make_production_mesh
from ..models import transformer as T
from ..models.config import ChainConfig
from ..sharding import hooks
from ..sharding.rules import Ruleset
from ..train import steps as steps_mod

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
                "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip operand bytes of every collective in the partitioned HLO
    (methodology: sum of operand tensor sizes; ring all-reduce moves ≈2× this
    — recorded as-is and noted in EXPERIMENTS.md)."""
    out = {c: {"bytes": 0, "count": 0} for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        for c in COLLECTIVES:
            token = f" {c}("
            if token in line and "-start" not in line and "-done" not in line:
                lhs = line.split("=", 1)
                if len(lhs) != 2:
                    continue
                # operand types appear in the result type (collectives are
                # type-preserving modulo gather/scatter factor)
                types = _SHAPE_RE.findall(lhs[1].split(c + "(")[0])
                b = sum(_tensor_bytes(t, s) for t, s in types)
                out[c]["bytes"] += b
                out[c]["count"] += 1
            # async forms: count the -start op once
            token_s = f" {c}-start("
            if token_s in line:
                lhs = line.split("=", 1)
                if len(lhs) != 2:
                    continue
                types = _SHAPE_RE.findall(lhs[1].split(c + "-start(")[0])
                b = sum(_tensor_bytes(t, s) for t, s in types)
                out[c]["bytes"] += b
                out[c]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def build_case(arch: str, shape: str, mesh, chain_window=8, seq_shard=False,
               step_kind="chain", decode_align=False, gpo_seq=False):
    """Returns (jitted_fn, args, ruleset, cfg) ready to .lower()."""
    cfg0 = get_config(arch)
    if not ispec.supported(cfg0, shape):
        return None
    cfg, case, specs = ispec.input_specs(cfg0, shape)
    rules = Ruleset(mesh, cfg, seq_shard=seq_shard)
    hooks.set_policy(hooks.Policy(
        mesh,
        residual_spec_fn=rules.residual_spec if seq_shard else None,
        logits_spec_fn=rules.logits_spec,
        decode_q_spec_fn=rules.decode_q_spec if decode_align else None,
        cache_entry_spec_fn=rules.cache_entry_spec if decode_align else None))

    a_params = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    a_adapt = jax.eval_shape(lambda: T.init_adapters(jax.random.PRNGKey(0), cfg))
    p_shard = rules.named(rules.params(a_params))
    ad_shard = rules.named(rules.adapters(a_adapt))

    if case.kind == "train":
        L = cfg.total_chain_layers
        Q = min(chain_window, L)
        k = min(L // 3, L - Q)
        chain = ChainConfig(window=Q, lam=0.2, optimizer="sgd", lr=1e-3)
        if step_kind == "chain":
            seg = T.ChainSegments(k, Q)
            fn = steps_mod.make_fed_train_step(cfg, chain, seg,
                                               gpo_sequential=gpo_seq)
        else:
            fn = steps_mod.make_e2e_train_step(cfg, chain)
        b_shard = rules.named(rules.train_batch(specs))
        jf = jax.jit(fn, in_shardings=(p_shard, ad_shard, b_shard),
                     out_shardings=(ad_shard, None))
        args = (a_params, a_adapt, specs)
    elif case.kind == "prefill":
        fn = steps_mod.make_prefill_step(cfg)
        b_shard = rules.named(rules.train_batch(specs))
        jf = jax.jit(fn, in_shardings=(p_shard, ad_shard, b_shard))
        args = (a_params, a_adapt, specs)
    else:  # decode
        token, cache, idx, embeds, enc_len = specs
        fn = steps_mod.make_decode_step(cfg, enc_len=enc_len)
        c_shard = rules.named(rules.cache(cache))
        in_sh = [p_shard, ad_shard, None, c_shard, None]
        args = [a_params, a_adapt, token, cache, idx]
        if cfg.family == "vlm":
            in_sh.append(None)
            args.append(embeds)
        # donate the cache: the updated cache aliases the input buffer —
        # without this the decode step holds two full cache copies (§Perf)
        jf = jax.jit(fn, in_shardings=tuple(in_sh),
                     out_shardings=(None, None, c_shard, None),
                     donate_argnums=(3,))
        args = tuple(args)
    return jf, args, rules, cfg


def run_case(arch: str, shape: str, multi_pod=False, seq_shard=False,
             step_kind="chain", verbose=True, cost_unroll=False,
             ssm_ckpt=False, decode_align=False, gpo_seq=False):
    """cost_unroll: unroll every structural scan so cost_analysis /
    collective parsing carry true totals (XLA counts while bodies once);
    memory_analysis from these runs over-counts live buffers, so the default
    scan-mode run remains the memory source of truth."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    T.set_unroll(cost_unroll)
    from ..models import ssm as ssm_mod
    ssm_mod.set_ssm_chunk_ckpt(ssm_ckpt)
    built = build_case(arch, shape, mesh, seq_shard=seq_shard,
                       step_kind=step_kind, decode_align=decode_align,
                       gpo_seq=gpo_seq)
    if built is None:
        return {"arch": arch, "shape": shape, "skipped": True,
                "reason": "sub-quadratic decode unsupported for this family "
                          "(DESIGN §6)"}
    jf, args, rules, cfg = built
    t0 = time.time()
    lowered = jf.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    colls = collective_bytes(compiled.as_text())
    n_chips = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape, "step": step_kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": int(n_chips),
        "seq_shard": seq_shard,
        "cost_unroll": cost_unroll,
        "ssm_ckpt": ssm_ckpt, "decode_align": decode_align,
        "gpo_seq": gpo_seq,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_chip": (ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes),
        },
        "cost": {"flops_per_chip": ca.get("flops", 0.0),
                 "bytes_per_chip": ca.get("bytes accessed", 0.0)},
        "collectives": colls,
    }
    if verbose:
        m = rec["memory"]
        print(f"[{arch} × {shape} | {rec['mesh']} | {step_kind}"
              f"{' +seqshard' if seq_shard else ''}] "
              f"compile {rec['compile_s']}s  "
              f"args {m['argument_bytes']/2**30:.2f} GiB  "
              f"temp {m['temp_bytes']/2**30:.2f} GiB  "
              f"peak {m['peak_per_chip']/2**30:.2f} GiB/chip  "
              f"flops/chip {rec['cost']['flops_per_chip']:.3e}  "
              f"coll {colls['total_bytes']/2**20:.1f} MiB")
    return rec


def save(rec, tag=""):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec.get('mesh','skip')}"
    if rec.get("step", "chain") != "chain":
        name += f"_{rec['step']}"
    if rec.get("seq_shard"):
        name += "_seqshard"
    if rec.get("ssm_ckpt"):
        name += "_ssmckpt"
    if rec.get("gpo_seq"):
        name += "_gposeq"
    if rec.get("decode_align"):
        name += "_decalign"
    if tag:
        name += f"_{tag}"
    path = OUT_DIR / f"{name}.json"
    path.write_text(json.dumps(rec, indent=1))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(ispec.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--step", default="chain", choices=["chain", "e2e"])
    ap.add_argument("--gpo-seq", action="store_true",
                    help="perf lever: sequential checkpointed GPO dual loss")
    ap.add_argument("--ssm-ckpt", action="store_true",
                    help="perf lever: checkpoint SSM scan chunks")
    ap.add_argument("--decode-align", action="store_true",
                    help="perf lever: align decode q/cache shardings")
    ap.add_argument("--cost", action="store_true",
                    help="unrolled cost-accounting pass (true FLOP/collective "
                         "totals; slower compiles)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cases = []
    if args.all:
        for a in ASSIGNED:
            for s in ispec.SHAPES:
                cases.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cases = [(args.arch, args.shape)]

    failures = []
    for a, s in cases:
        try:
            rec = run_case(a, s, multi_pod=args.multi_pod,
                           seq_shard=args.seq_shard, step_kind=args.step,
                           cost_unroll=args.cost, ssm_ckpt=args.ssm_ckpt,
                           decode_align=args.decode_align,
                           gpo_seq=args.gpo_seq)
            save(rec, ("cost" if args.cost else "") + args.tag)
        except Exception as e:
            traceback.print_exc()
            failures.append((a, s, repr(e)))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print(f"dry-run OK: {len(cases)} case(s)")


if __name__ == "__main__":
    main()
