"""ShapeDtypeStruct stand-ins for every (architecture × input shape) pair —
weak-type-correct, shardable, zero allocation.  The four assigned shapes:

    train_4k     seq 4096,   global_batch 256   (training, fed round)
    prefill_32k  seq 32768,  global_batch 32    (inference prefill)
    decode_32k   seq 32768,  global_batch 128   (one token + 32k cache)
    long_500k    seq 524288, global_batch 1     (sub-quadratic decode)

Audio/VLM carve-out: the modality frontend is a stub — specs provide
precomputed frame/patch embeddings of the right shape.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models import transformer as T

S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}

# cohorts for train_4k: 256 = 32 cohorts × 8 per-client batch
TRAIN_COHORTS = 32
LOCAL_STEPS = 1
ENC_FRAC = 1          # encoder frames = seq_len for encdec
DEC_TRAIN_TOKENS = 1024   # decoder-side length for encdec training/prefill


def arch_shape_cfg(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Shape-dependent attention variant: full attention everywhere except
    long_500k, which requires the sub-quadratic SWA/SSM path (DESIGN §6)."""
    if shape == "long_500k":
        return cfg           # keep config SWA window (sub-quadratic variant)
    if cfg.sliding_window is not None and cfg.family != "hybrid":
        return cfg.replace(sliding_window=None)
    return cfg


def supported(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        # needs sub-quadratic decode: SSM state, hybrid, or SWA variant.
        # seamless (enc-dec speech) skipped — noted in DESIGN §6.
        if cfg.is_encdec:
            return False
        return cfg.family in ("ssm", "hybrid") or cfg.sliding_window is not None
    return True


def _i32(*shape):
    return S(shape, jnp.int32)


def _emb(cfg, *shape):
    return S(shape + (cfg.d_model,), cfg.cdtype())


def train_specs(cfg: ModelConfig, case: ShapeCase):
    C, ls = TRAIN_COHORTS, LOCAL_STEPS
    b = case.global_batch // C
    sl = case.seq_len
    if cfg.family == "vlm":
        batch = {"embeds": _emb(cfg, C, ls, b, sl),
                 "positions": S((C, ls, 3, b, sl), jnp.int32),
                 "labels": _i32(C, ls, b, sl)}
    elif cfg.is_encdec:
        batch = {"enc_embeds": _emb(cfg, C, ls, b, sl),
                 "tokens": _i32(C, ls, b, DEC_TRAIN_TOKENS),
                 "labels": _i32(C, ls, b, DEC_TRAIN_TOKENS)}
    else:
        batch = {"tokens": _i32(C, ls, b, sl), "labels": _i32(C, ls, b, sl)}
    return batch


def prefill_specs(cfg: ModelConfig, case: ShapeCase):
    B, sl = case.global_batch, case.seq_len
    if cfg.family == "vlm":
        return {"embeds": _emb(cfg, B, sl),
                "positions": S((3, B, sl), jnp.int32)}
    if cfg.is_encdec:
        return {"enc_embeds": _emb(cfg, B, sl),
                "tokens": _i32(B, DEC_TRAIN_TOKENS)}
    return {"tokens": _i32(B, sl)}


def decode_specs(cfg: ModelConfig, case: ShapeCase):
    """(token, cache, idx) shape structs; cache via eval_shape of init_cache."""
    B, sl = case.global_batch, case.seq_len
    enc_len = sl if cfg.is_encdec else None
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, B, sl, enc_len=enc_len))
    token = _i32(B, 1)
    embeds = _emb(cfg, B, 1) if cfg.family == "vlm" else None
    idx = S((), jnp.int32)
    return token, cache, idx, embeds, enc_len


def input_specs(cfg: ModelConfig, shape: str):
    """Unified entry: returns (kind, specs...)."""
    case = SHAPES[shape]
    cfg = arch_shape_cfg(cfg, shape)
    if case.kind == "train":
        return cfg, case, train_specs(cfg, case)
    if case.kind == "prefill":
        return cfg, case, prefill_specs(cfg, case)
    return cfg, case, decode_specs(cfg, case)
