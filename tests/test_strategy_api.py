"""Strategy-API tests: registry round-trip, TrainablePlan/ActiveAdapters
equivalence with the legacy slicing behavior, plan-masked steps, the
AdapterLibrary composition seam, and FedSim edge cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adapters import (ActiveAdapters, AdapterLibrary,
                                 adapter_stack_init)
from repro.core.dlct import window_scatter, window_slice
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import (available_strategies, make_strategy,
                                register_strategy, run_experiment)
from repro.fed.strategies import PlanEngine, Strategy, TrainablePlan
from repro.models.config import ChainConfig, FedConfig
from repro.models.transformer import ChainSegments

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=1, lr=1e-3)
KEY = jax.random.PRNGKey(0)

ALL_NAMES = ["full_adapters", "linear_probing", "fedadapter", "c2a",
             "fwdllm", "fedkseed", "flora", "fedra", "fedembed", "chainfed"]


# ---------------------------------------------------------------- registry
def test_registry_lists_all_builtins():
    avail = available_strategies()
    for name in ALL_NAMES:
        assert name in avail, name


@pytest.mark.parametrize("name", ALL_NAMES)
def test_make_strategy_round_trip(name):
    strat = make_strategy(name, CFG, CHAIN, KEY)
    assert strat.name == name
    plan = strat.plan(None, 0)
    assert isinstance(plan, TrainablePlan)
    hash(plan)   # plans must be hashable: they key the engine's jit cache


def test_unknown_strategy_lists_available():
    with pytest.raises(KeyError, match="chainfed"):
        make_strategy("nope", CFG, CHAIN, KEY)


def test_register_custom_strategy():
    from repro.fed import registry as reg
    try:
        @register_strategy("_test_custom")
        class Custom(Strategy):
            memory_method = "full_adapters"

        strat = make_strategy("_test_custom", CFG, CHAIN, KEY)
        assert strat.name == "_test_custom"
        assert "_test_custom" in available_strategies()
    finally:      # registry is process-global: keep the test re-runnable
        reg._REGISTRY.pop("_test_custom", None)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @register_strategy("chainfed")
        class Imposter(Strategy):
            pass


# ----------------------------------------------------- plan ↔ old behavior
def test_window_spec_matches_window_slice():
    ad = adapter_stack_init(KEY, CFG)
    seg = ChainSegments(1, 2)
    spec = ActiveAdapters.window(CFG.total_chain_layers, seg.prefix,
                                 seg.window)
    np.testing.assert_array_equal(
        np.asarray(spec.select(ad, "window")["down"]),
        np.asarray(window_slice(ad, seg)["down"]))
    # scatter round-trips exactly like the legacy window_scatter
    win = jax.tree_util.tree_map(lambda x: x + 1.0, spec.train_slice(ad))
    np.testing.assert_array_equal(
        np.asarray(spec.scatter_train(ad, win)["down"]),
        np.asarray(window_scatter(ad, win, seg)["down"]))


def test_window_spec_trainable_mask():
    spec = ActiveAdapters.window(6, 2, 3)
    np.testing.assert_array_equal(np.asarray(spec.trainable_mask()),
                                  [0, 0, 1, 1, 1, 0])
    assert spec.train_span == (2, 5)
    assert not spec.is_full
    assert ActiveAdapters.full(6).is_full


def test_layer_masked_step_confines_updates():
    """A plan-driven masked step must reproduce the old per-strategy
    behavior: masked-out layers' adapters stay exactly put."""
    # sgd: AdamW's decoupled weight decay would leak tiny deltas into
    # masked layers (same as the legacy path — see FedRA's aggregation note)
    strat = make_strategy("fedadapter", CFG,
                          CHAIN.replace(optimizer="sgd", lr=1e-2), KEY)
    plan = strat.plan(None, 0)
    mask = strat.plan_masks(None, None, 0)["layer_mask"]
    assert float(mask.sum()) < CFG.total_chain_layers  # partial at round 0
    batch = {"tokens": jnp.ones((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}
    tr0 = strat.engine.init_trainable(plan, strat.params, strat.adapters,
                                      strat.head)
    step = strat.engine.local_step(plan)
    tr, _, _, _ = step(tr0, strat.opt.init(tr0), strat.params, strat.adapters,
                       batch, {"layer_mask": mask})
    # measure on "up": "down" has zero grad at init (up is zero-init)
    delta = np.asarray(jnp.abs(tr["adapters"]["up"]
                               - tr0["adapters"]["up"]).sum(axis=(1, 2)))
    frozen = np.asarray(mask) == 0.0
    assert np.all(delta[frozen] == 0.0)
    assert np.all(delta[~frozen] > 0.0)


def test_chainfed_plan_jit_cache_per_offset():
    """The DLCT cyclic window reuses compiled steps: one cache entry per
    offset, revisits hit the cache.  Since ISSUE 5 the window advances on
    *commit events* (`_next_stage`), not the caller's round index — the
    per-offset cache survives the event-driven schedule."""
    strat = make_strategy("chainfed", CFG, CHAIN, KEY, use_foat=False)
    n_offsets = strat.schedule.n_stages
    plans = []
    for _ in range(2 * n_offsets):          # two full cycles of stage events
        plans.append(strat.plan(None, 0))
        strat._next_stage()
    for p in plans:
        strat.engine.local_step(p)
    assert len(strat.engine._steps) == n_offsets
    assert plans[0] == plans[n_offsets]     # cyclic
    # the round index is inert: plans depend only on committed stage events
    assert strat.plan(None, 0) == strat.plan(None, 123)


# ------------------------------------------------------------------ engine
def _tiny_sim(n_clients=4, memory_constrained=False, budget_range=(0.1, 1.3)):
    spec = DATASETS["agnews"]
    spec = spec.__class__(**{**spec.__dict__, "vocab": CFG.vocab_size,
                             "n_samples": 256})
    tokens, labels = make_classification(spec)
    fed = FedConfig(n_clients=n_clients, clients_per_round=2, iid=True)
    bf = lambda idx: {k: jnp.asarray(v) for k, v in
                      classification_batch(spec, tokens, labels, idx).items()}
    return FedSim(CFG, fed, tokens, labels, bf, batch_size=4,
                  memory_constrained=memory_constrained,
                  budget_range=budget_range)


def test_sample_clients_empty_eligible_pool():
    """When no client clears the memory wall, sampling returns [] and the
    round loop still evaluates without crashing."""
    sim = _tiny_sim(memory_constrained=True, budget_range=(1e-6, 2e-6))
    assert sim.eligible("full_adapters") == []
    assert sim.sample_clients("full_adapters") == []
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    hist = run_sync_rounds(sim, strat, rounds=1, eval_every=1)
    assert hist[-1].n_participants == 0
    assert np.isfinite(hist[-1].loss)


def test_run_experiment_entry_point():
    res = run_experiment("linear_probing", cfg=CFG, chain=CHAIN,
                         fed=FedConfig(n_clients=4, clients_per_round=2,
                                       iid=True),
                         sim=_tiny_sim(), rounds=1, eval_every=1)
    assert res.history and np.isfinite(res.history[-1].loss)
    assert res.strategy.name == "linear_probing"
    assert res.final_acc == res.history[-1].acc


# -------------------------------------------------------- adapter library
def test_adapter_library_composition():
    lib = AdapterLibrary()
    k1, k2 = jax.random.split(KEY)
    lib.add("tenant_a", adapter_stack_init(k1, CFG))
    lib.add("tenant_b", adapter_stack_init(k2, CFG))
    with pytest.raises(KeyError):
        lib.set_active("tenant_c")
    lib.set_active("tenant_a")
    assert lib.active_adapters == ("tenant_a",)
    np.testing.assert_array_equal(
        np.asarray(lib.resolve()["down"]),
        np.asarray(lib.resolve("tenant_a")["down"]))
    lib.set_active("tenant_a", "tenant_b")
    fused = lib.fuse([0.5, 0.5])
    expect = 0.5 * np.asarray(lib.resolve("tenant_a")["down"]) + \
        0.5 * np.asarray(lib.resolve("tenant_b")["down"])
    np.testing.assert_allclose(np.asarray(fused["down"]), expect, atol=1e-7)
