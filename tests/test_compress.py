"""Update compression on the cohort hot path (ISSUE 10): top-k / QSGD
leaf transforms, error-feedback convergence on a quadratic fixture,
``comm_bytes_per_round`` accounting, the composition guards in
``enable_compression``, and bit-identical kill/resume of the residual
state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.fed.compress import (CompressionConfig, enable_compression,
                                make_compress_fn)
from repro.fed.registry import make_strategy, run_experiment
from repro.models.config import ChainConfig, FedConfig

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=1, lr=3e-3)
KEY = jax.random.PRNGKey(0)


def _run_kw(**over):
    kw = dict(cfg=CFG, chain=CHAIN,
              fed=FedConfig(n_clients=6, clients_per_round=3, seed=3),
              batch_size=4, memory_constrained=False, rounds=3, eval_every=3)
    kw.update(over)
    return kw


# ================================================================ primitives
def test_topk_keeps_largest_per_row():
    fn = make_compress_fn(CompressionConfig(kind="topk", ratio=0.25,
                                            error_feedback=False))
    x = jnp.asarray([[1.0, -5.0, 0.1, 3.0, 0.0, -0.2, 2.0, 0.05]])
    updates = {"w": x}
    res = {"w": jnp.zeros((8,))}
    out, new_res = fn(updates, {"w": res["w"][None]}, jax.random.PRNGKey(0))
    got = np.asarray(out["w"][0])
    assert np.count_nonzero(got) == 2            # ceil(8 * 0.25)
    assert got[1] == -5.0 and got[3] == 3.0      # the two largest magnitudes
    # no EF → residuals stay zero
    assert np.all(np.asarray(new_res["w"]) == 0.0)


def test_error_feedback_carries_the_remainder():
    fn = make_compress_fn(CompressionConfig(kind="topk", ratio=0.5))
    x = jnp.asarray([[4.0, 1.0, -3.0, 0.5]])
    out, res = fn({"w": x}, {"w": jnp.zeros((1, 4))}, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(out["w"] + res["w"]),
                               np.asarray(x), atol=1e-7)


def test_qsgd_unbiased_and_bounded():
    fn = make_compress_fn(CompressionConfig(kind="qsgd", error_feedback=False))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 129)) * 2.0
    outs = [np.asarray(fn({"w": x}, {"w": jnp.zeros_like(x)},
                          jax.random.PRNGKey(s))[0]["w"])
            for s in range(24)]
    step = np.abs(np.asarray(x)).max(axis=1, keepdims=True) / 127.0
    for o in outs:                               # within one quantization step
        assert np.all(np.abs(o - np.asarray(x)) <= step + 1e-6)
    # stochastic rounding is unbiased: the mean over draws approaches x
    err = np.abs(np.mean(outs, axis=0) - np.asarray(x))
    assert err.mean() < 0.25 * step.mean()


def test_ef_compression_converges_on_quadratic():
    """Aggressive top-k (5%) diverges-or-stalls without error feedback on a
    rotated quadratic, converges with it — the EF-SGD headline property."""
    d = 64
    key = jax.random.PRNGKey(2)
    A = jax.random.normal(key, (d, d)) / jnp.sqrt(d)
    H = A @ A.T + 0.1 * jnp.eye(d)
    loss = lambda w: 0.5 * w @ H @ w
    gfn = jax.jit(jax.grad(loss))

    def run(error_feedback):
        fn = make_compress_fn(CompressionConfig(
            kind="topk", ratio=0.05, error_feedback=error_feedback))
        w = jnp.ones(d)
        res = {"g": jnp.zeros((1, d))}
        for i in range(300):
            g = {"g": gfn(w)[None]}
            comp, res = fn(g, res, jax.random.fold_in(key, i))
            if not error_feedback:
                res = {"g": jnp.zeros((1, d))}
            w = w - 0.1 * comp["g"][0]
        return float(loss(w))

    l0 = float(loss(jnp.ones(d)))
    with_ef, without_ef = run(True), run(False)
    assert with_ef < 1e-3 * l0
    assert with_ef < without_ef * 0.5


# ============================================================== byte account
def test_compressed_bytes_math():
    n = 1000
    fp32 = 4 * n
    topk = CompressionConfig(kind="topk", ratio=0.05)
    assert topk.compressed_bytes(fp32) == 50 * 8          # (value, index) pairs
    qsgd = CompressionConfig(kind="qsgd")
    assert qsgd.compressed_bytes(fp32) == n + 4           # int8 payload + scale


def test_comm_bytes_per_round_reflects_compression():
    dense = run_experiment("chainfed", **_run_kw())
    comp = run_experiment("chainfed", **_run_kw(
        compress={"kind": "qsgd", "error_feedback": False}))
    db = dense.history[-1].comm_bytes
    cb = comp.history[-1].comm_bytes
    assert 0 < cb < db
    # ~4x: fp32 → int8 payload (+1 fp32 scale per leaf)
    assert cb < db / 3


def test_qsgd_loss_close_to_dense():
    dense = run_experiment("chainfed", **_run_kw(rounds=4))
    comp = run_experiment("chainfed", **_run_kw(
        rounds=4, compress={"kind": "qsgd"}))
    assert abs(comp.history[-1].loss - dense.history[-1].loss) < 0.1


# ==================================================================== guards
def test_config_validation():
    with pytest.raises(ValueError):
        CompressionConfig(kind="nope")
    with pytest.raises(ValueError):
        CompressionConfig(kind="topk", ratio=0.0)
    with pytest.raises(ValueError):
        CompressionConfig(kind="qsgd", bits=4)


def test_enable_after_compile_refused():
    strat = make_strategy("chainfed", CFG, CHAIN, KEY, use_foat=False)
    strat.engine._cohort["sentinel"] = lambda: None
    with pytest.raises(RuntimeError, match="compil"):
        enable_compression(strat)


def test_enable_with_secure_agg_refused():
    from repro.fed.privacy import SecureAggConfig, enable_secure_agg
    strat = make_strategy("chainfed", CFG, CHAIN, KEY, use_foat=False)
    enable_secure_agg(strat, SecureAggConfig(cohort=3))
    with pytest.raises(ValueError, match="secure"):
        enable_compression(strat)


def test_enable_with_adaptive_clip_dp_refused():
    from repro.fed.privacy import DPConfig, enable_dp
    strat = make_strategy("chainfed", CFG, CHAIN, KEY, use_foat=False)
    enable_dp(strat, DPConfig(clip=1.0, noise_multiplier=0.5,
                              adaptive_clip=True))
    with pytest.raises(ValueError, match="adaptive"):
        enable_compression(strat)


def test_fixed_clip_dp_composes():
    res = run_experiment("chainfed", **_run_kw(
        rounds=2, compress={"kind": "topk", "ratio": 0.25},
        dp={"clip": 1.0, "noise_multiplier": 0.3}))
    assert np.isfinite(res.history[-1].loss)


def test_whole_client_plan_refused_at_round_time():
    res_kw = _run_kw(rounds=1, compress={"kind": "topk", "ratio": 0.5})
    with pytest.raises(ValueError, match="delta-style"):
        run_experiment("fedkseed", **res_kw)


# ================================================================ kill/resume
def test_compress_kill_resume_bit_identical(tmp_path):
    """Error-feedback residuals and the compression PRNG key are part of the
    checkpoint: a halted+resumed run reproduces the uninterrupted one."""
    kw = _run_kw(rounds=4, eval_every=2,
                 compress={"kind": "topk", "ratio": 0.25})
    full = run_experiment("chainfed", **kw)
    ck = tmp_path / "c.msgpack"
    run_experiment("chainfed", **kw, checkpoint_every=2, checkpoint_path=ck,
                   halt_after=2)
    resumed = run_experiment("chainfed", **kw, resume=ck)
    assert full.history == resumed.history
    for x, y in zip(jax.tree_util.tree_leaves(full.strategy.adapters),
                    jax.tree_util.tree_leaves(resumed.strategy.adapters)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    # the residual store itself round-tripped
    r_full = full.strategy._compress_residuals
    r_res = resumed.strategy._compress_residuals
    assert set(r_full) == set(r_res)
    for cid in r_full:
        for x, y in zip(jax.tree_util.tree_leaves(r_full[cid]),
                        jax.tree_util.tree_leaves(r_res[cid])):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_resume_refuses_config_mismatch(tmp_path):
    kw = _run_kw(rounds=3, compress={"kind": "topk", "ratio": 0.25})
    ck = tmp_path / "c.msgpack"
    run_experiment("chainfed", **kw, checkpoint_every=1, checkpoint_path=ck,
                   halt_after=1)
    with pytest.raises(ValueError):
        run_experiment("chainfed", **_run_kw(rounds=3), resume=ck)
