"""Chain-core invariants: adapters, DLCT scheduling, GPO dual loss, FOAT
boundary selection, and the chain↔end-to-end equivalence property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given_or_grid

from repro.configs import get_config, get_smoke_config
from repro.core import foat
from repro.core.adapters import adapter_apply, adapter_chain_apply, adapter_stack_init
from repro.core.dlct import make_schedule, window_scatter, window_slice
from repro.models import transformer as T
from repro.models.config import ChainConfig
from repro.train.losses import IGNORE, cross_entropy, gpo_loss

CFG = get_config("bert_tiny")
KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ adapters
def test_adapter_identity_at_init():
    ad = adapter_stack_init(KEY, CFG)
    h = jax.random.normal(KEY, (3, 5, CFG.d_model))
    one = jax.tree_util.tree_map(lambda x: x[0], ad)
    np.testing.assert_allclose(np.asarray(adapter_apply(one, h, CFG)),
                               np.asarray(h), atol=1e-6)
    np.testing.assert_allclose(np.asarray(adapter_chain_apply(ad, h, CFG)),
                               np.asarray(h), atol=1e-6)


def test_window_slice_scatter_roundtrip():
    ad = adapter_stack_init(KEY, CFG)
    from repro.models.transformer import ChainSegments
    seg = ChainSegments(2, 3)
    win = window_slice(ad, seg)
    win2 = jax.tree_util.tree_map(lambda x: x + 1.0, win)
    full = window_scatter(ad, win2, seg)
    got = window_slice(full, seg)
    np.testing.assert_allclose(np.asarray(got["down"]),
                               np.asarray(win["down"]) + 1.0)
    # outside the window untouched
    np.testing.assert_allclose(np.asarray(full["down"][:2]),
                               np.asarray(ad["down"][:2]))


# ------------------------------------------------------------------ DLCT
@given_or_grid([dict(L=L, Q=Q, l_start=s) for L in (2, 6, 13, 24)
                for Q in (1, 2, 8) for s in (0, 7, 20)],
               lambda st: dict(L=st.integers(2, 24), Q=st.integers(1, 8),
                               l_start=st.integers(0, 20)),
               max_examples=40)
def test_schedule_windows_valid(L, Q, l_start):
    cfg = CFG.replace(n_layers=L)
    sched = make_schedule(cfg, min(l_start, L - 1), Q)
    assert sched.n_stages >= 1
    for k in sched.offsets:
        assert 0 <= k <= L - sched.window
    # consecutive offsets overlap by Q-1 (the DLCT conduit property)
    offs = sched.offsets
    for a, b in zip(offs, offs[1:]):
        assert b - a == 1


def test_schedule_cycles():
    sched = make_schedule(CFG, 0, 2)       # L=6 → offsets 0..4
    assert sched.offsets == (0, 1, 2, 3, 4)
    segs = [sched.segments(r).prefix for r in range(7)]
    assert segs == [0, 1, 2, 3, 4, 0, 1]   # cyclic holistic passes


def test_schedule_encdec_never_straddles():
    cfg = get_smoke_config("seamless_m4t_large_v2")   # E=2, D=2
    sched = make_schedule(cfg, 0, 2)
    E = cfg.n_encoder_layers
    for k in sched.offsets:
        assert not (k < E < k + sched.window), sched.offsets


# ------------------------------------------------------------------ GPO
def test_gpo_loss_combination():
    B, S, V = 2, 4, 16
    key = jax.random.PRNGKey(1)
    out = {"local_logits": jax.random.normal(key, (B, S, V)),
           "global_logits": jax.random.normal(jax.random.fold_in(key, 1), (B, S, V)),
           "aux": {"load_balance": jnp.float32(0), "router_z": jnp.float32(0)}}
    labels = jnp.zeros((B, S), jnp.int32)
    for lam in (0.0, 0.2, 1.0):
        loss, parts = gpo_loss(out, labels, CFG, lam, final_stage=False)
        expect = parts["local"] + lam * parts["global"]
        assert abs(float(loss) - float(expect)) < 1e-6
    loss_f, parts_f = gpo_loss(out, labels, CFG, 0.5, final_stage=True)
    assert abs(float(loss_f) - float(parts_f["local"])) < 1e-6


def test_gradients_confined_to_window():
    """Backward never reaches prefix/suffix adapters or base params."""
    cfg = CFG
    params = T.init_lm(KEY, cfg)
    adapters = T.init_adapters(KEY, cfg)
    seg = T.ChainSegments(2, 2)
    batch = {"tokens": jnp.ones((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}

    def loss(window, frozen, params):
        out = T.forward_chain(params, window, frozen, batch, cfg, seg)
        l, _ = gpo_loss(out, batch["labels"], cfg, 0.2, False)
        return l

    win = window_slice(adapters, seg)
    gw, gf, gp = jax.grad(loss, argnums=(0, 1, 2))(win, adapters, params)
    assert float(jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))), gw, 0.0)) > 0
    # frozen stack receives gradient ONLY through suffix adapters (GPO aux
    # branch) — prefix adapters must stay at exactly zero
    assert float(jnp.sum(jnp.abs(gf["down"][:seg.prefix]))) == 0.0
    assert float(jnp.sum(jnp.abs(gp["layers"]["norm1"]["scale"][:seg.prefix]))) == 0.0


# ------------------------------------------------------------------ FOAT
def test_foat_boundary_selection():
    scores = jnp.array([0.99, 0.95, 0.85, 0.70, 0.55])
    assert foat.select_start_layer(scores, 0.9) == 2
    assert foat.select_start_layer(scores, 0.5) == 4   # never below -> last
    assert foat.select_start_layer(scores, 1.0) == 0


def test_foat_cka_range_and_invariance():
    X = jax.random.normal(KEY, (32, 16))
    Y = X @ jax.random.normal(jax.random.fold_in(KEY, 2), (16, 16))
    c = float(foat.linear_cka(X, Y))
    assert 0.0 <= c <= 1.0 + 1e-6
    # CKA is invariant to isotropic scaling and orthogonal transforms
    c2 = float(foat.linear_cka(X * 3.0, Y))
    assert abs(c - c2) < 1e-5


def test_foat_run_on_model():
    cfg = CFG
    params = T.init_lm(KEY, cfg)
    adapters = T.init_adapters(KEY, cfg)
    batches = [{"tokens": jax.random.randint(jax.random.fold_in(KEY, i),
                                             (8, 12), 0, cfg.vocab_size)}
               for i in range(3)]
    l_start, scores = foat.run_foat(params, adapters, batches, cfg, 0.8)
    assert 0 <= l_start < cfg.n_layers
    assert scores.shape == (cfg.n_layers,)
    assert bool(jnp.all(jnp.isfinite(scores)))


# ------------------------------------------------------------------ equivalence
def test_final_stage_local_equals_end_to_end():
    """With the window covering the whole tail, the stage's local logits must
    equal the end-to-end forward (paper: final stage trains on the e2e loss)."""
    cfg = CFG
    params = T.init_lm(KEY, cfg)
    adapters = T.init_adapters(KEY, cfg)
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    seg = T.ChainSegments(0, cfg.n_layers)
    out = T.forward_chain(params, adapters, adapters, batch, cfg, seg)
    full, _ = T.forward_full(params, adapters, batch, cfg, remat=False)
    np.testing.assert_allclose(np.asarray(out["local_logits"]),
                               np.asarray(full), atol=1e-4, rtol=1e-4)


def test_chain_prefix_plus_window_matches_full_when_adapters_identity():
    """At init (identity adapters) the GPO aux branch is the identity, so
    global logits == local logits."""
    cfg = CFG
    params = T.init_lm(KEY, cfg)
    adapters = T.init_adapters(KEY, cfg)
    batch = {"tokens": jnp.arange(16, dtype=jnp.int32).reshape(2, 8)}
    seg = T.ChainSegments(1, 2)
    win = window_slice(adapters, seg)
    out = T.forward_chain(params, win, adapters, batch, cfg, seg)
    np.testing.assert_allclose(np.asarray(out["local_logits"]),
                               np.asarray(out["global_logits"]), atol=1e-5)
