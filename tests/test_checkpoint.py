"""Durable checkpoint/resume (ISSUE 7): msgpack state round-trips with
dtype fidelity, the RDP accountant snapshot preserves ε, serialized plans
stay hash-equal (→ no resume recompiles), and a run killed mid-flight and
restored from its checkpoint finishes bit-identically to one that was never
interrupted — final trainable state, ε spend, and every RoundMetrics — in
sync, semisync (carried stragglers) and async (buffered updates) modes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim
from repro.fed.faults import ClientBehavior
from repro.fed.registry import make_strategy, run_experiment
from repro.fed.runtime import FedScheduler
from repro.models.config import ChainConfig, FedConfig

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=1, lr=3e-3)
KEY = jax.random.PRNGKey(0)


def build_sim(seed=3, n_clients=6, clients_per_round=3):
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: classification_batch(spec, tokens, labels, idx)
    fed = FedConfig(n_clients=n_clients, clients_per_round=clients_per_round,
                    seed=seed)
    return FedSim(CFG, fed, tokens, labels, batch_fn, batch_size=4,
                  memory_constrained=False)


def build_sched(mode, method="chainfed", dp=False, **sched_kw):
    sim = build_sim()
    strat = make_strategy(method, CFG, CHAIN, KEY)
    if dp:
        from repro.fed.privacy import DPConfig, enable_dp
        enable_dp(strat, DPConfig(clip=0.5, noise_multiplier=0.6,
                                  delta=1e-5))
    return FedScheduler(sim, strat, mode=mode, **sched_kw)


def trainable_leaves(sched):
    strat = sched.strategy
    tree = {"adapters": strat.adapters}
    if strat.head is not None:
        tree["head"] = strat.head
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


# ================================================== state io dtype fidelity
def test_save_state_mixed_dtype_round_trip(tmp_path):
    from repro.ckpt.io import load_state, save_state
    gen = np.random.default_rng(7)
    state = {
        "bf16": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
        "f32": jnp.linspace(0, 1, 5, dtype=jnp.float32),
        "i32": np.arange(4, dtype=np.int32),
        "scalar0d": np.float64(0.125),
        "bool_arr": np.array([True, False]),
        "flags": (True, False, None, "label", b"\x00\xff"),
        "bigint": gen.bit_generator.state["state"]["state"],  # 128-bit PCG64
        "intkeys": {0: "a", 3: [1, 2.5]},
        "nested": [{"x": jnp.zeros((2,), jnp.bfloat16)}, 3],
    }
    save_state(tmp_path / "s.msgpack", state)
    got = load_state(tmp_path / "s.msgpack")
    assert got["bf16"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(got["bf16"], np.float32),
                          np.asarray(state["bf16"], np.float32))
    assert got["f32"].dtype == jnp.float32
    assert np.array_equal(np.asarray(got["f32"]), np.asarray(state["f32"]))
    assert got["i32"].dtype == np.int32
    assert np.array_equal(np.asarray(got["i32"]), state["i32"])
    assert float(got["scalar0d"]) == 0.125
    assert np.asarray(got["bool_arr"]).dtype == bool
    assert got["flags"] == (True, False, None, "label", b"\x00\xff")
    assert got["bigint"] == state["bigint"]      # exceeds uint64
    assert got["intkeys"] == {0: "a", 3: [1, 2.5]}
    assert got["nested"][0]["x"].dtype == jnp.bfloat16


def test_save_state_restores_numpy_generator(tmp_path):
    from repro.ckpt.io import load_state, save_state
    rng = np.random.default_rng((3, 0xC0FFEE))
    rng.random(7)                                # advance the stream
    save_state(tmp_path / "g.msgpack", {"bg": rng.bit_generator.state})
    twin = np.random.default_rng(0)
    twin.bit_generator.state = load_state(tmp_path / "g.msgpack")["bg"]
    assert np.array_equal(rng.random(5), twin.random(5))


def test_atomic_write_leaves_no_tmp(tmp_path):
    from repro.ckpt.io import save_state
    save_state(tmp_path / "a.msgpack", {"x": 1})
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["a.msgpack"]


# ============================================================== accountant
def test_accountant_state_round_trip_preserves_epsilon():
    from repro.fed.privacy import RDPAccountant
    acc = RDPAccountant()
    acc.step(0.8, q=0.5, steps=3)
    acc.step(1.2, q=0.25)
    twin = RDPAccountant.from_state(acc.to_state())
    assert twin.steps == acc.steps and twin.orders == acc.orders
    for d in (1e-5, 1e-7):
        assert twin.epsilon(d) == acc.epsilon(d)
    # restored accountant keeps composing identically
    acc.step(0.8, q=0.5)
    twin.step(0.8, q=0.5)
    assert twin.epsilon(1e-5) == acc.epsilon(1e-5)


def test_accountant_state_is_plain_jsonable():
    import json

    from repro.fed.privacy import RDPAccountant
    acc = RDPAccountant()
    acc.step(1.0, q=0.5)
    assert json.loads(json.dumps(acc.to_state())) == acc.to_state()


# ============================================================ plan identity
def test_plan_state_round_trip_is_hash_equal():
    from repro.fed.checkpoint import plan_from_state, plan_state
    strat = make_strategy("chainfed", CFG, CHAIN, KEY)
    plan = strat.plan(build_sim().clients[0], 0)
    twin = plan_from_state(plan_state(plan))
    assert twin == plan and hash(twin) == hash(plan)
    assert len({plan: 1, twin: 2}) == 1         # same jit-cache key


def test_plan_state_preserves_grad_cfg():
    from repro.fed.checkpoint import plan_from_state, plan_state
    strat = make_strategy("fedkseed", CFG, CHAIN, KEY)
    plan = strat.plan(build_sim().clients[0], 0)
    twin = plan_from_state(plan_state(plan))
    assert twin.grad == plan.grad and twin.grad_cfg == plan.grad_cfg
    assert hash(twin) == hash(plan)


# ===================================================== kill-resume equality
def _kill_and_resume(mode, tmp_path, rounds=6, halt=2, eval_every=2, **kw):
    """Three runs: A uninterrupted; B checkpoints every ``halt`` and 'dies'
    there; C restores B's file and finishes.  A and C must agree bit for
    bit."""
    a = build_sched(mode, **dict(kw))
    ha = a.run(rounds, eval_every=eval_every)
    ck = tmp_path / "run.msgpack"
    b = build_sched(mode, **dict(kw))
    b.run(rounds, eval_every=eval_every, checkpoint_every=halt,
          checkpoint_path=ck, halt_after=halt)
    c = build_sched(mode, **dict(kw))
    c.restore(ck)
    hc = c.run(rounds, eval_every=eval_every)
    for x, y in zip(trainable_leaves(a), trainable_leaves(c)):
        assert x.dtype == y.dtype and np.array_equal(x, y)
    assert ha == hc                              # every RoundMetrics field
    assert c.committed_updates == a.committed_updates > 0
    # restore must not add jit entries: each cohort fn compiled exactly once
    for cache in (c.strategy.engine._cohort_updates,
                  c.strategy.engine._cohort):
        for f in cache.values():
            if hasattr(f, "_cache_size"):
                assert f._cache_size() == 1
    return a, c, ha


def test_sync_dp_kill_resume_bit_identical(tmp_path):
    _, _, hist = _kill_and_resume("sync", tmp_path, dp=True)
    assert hist[-1].dp_epsilon > 0.0


def test_semisync_carry_kill_resume_bit_identical(tmp_path):
    _kill_and_resume(
        "semisync", tmp_path, straggler="carry",
        faults=ClientBehavior(dropout_prob=0.3, straggler_prob=0.4, seed=5))


def test_async_buffered_kill_resume_bit_identical(tmp_path):
    a, c, _ = _kill_and_resume(
        "async", tmp_path, halt=3, buffer_size=2, concurrency=3,
        faults=ClientBehavior(dropout_prob=0.3, seed=5))
    assert c.fault_dropouts == a.fault_dropouts


def test_trace_churn_kill_resume_bit_identical(tmp_path):
    from repro.data.partition import AvailabilityTrace
    win = (((0.0, 0.30),), ((0.0, 0.35),), ((0.55, 0.95),),
           ((0.60, 1.00),), ((1.25, 1.60),), ((1.30, 1.65),))
    a, c, _ = _kill_and_resume(
        "async", tmp_path, rounds=5, halt=2, eval_every=5,
        trace=AvailabilityTrace(windows=win, period=2.0), buffer_size=2,
        concurrency=2, backoff_base=0.05, backoff_cap=0.4)
    assert c.backoff_retries == a.backoff_retries
    assert c.trace_dropouts == a.trace_dropouts


def test_restore_rejects_mismatched_config(tmp_path):
    ck = tmp_path / "run.msgpack"
    a = build_sched("semisync")
    a.run(2, eval_every=2, checkpoint_every=2, checkpoint_path=ck)
    wrong_mode = build_sched("async")
    with pytest.raises(ValueError, match="mismatch on 'mode'"):
        wrong_mode.restore(ck)
    sim = build_sim(n_clients=8, clients_per_round=3)
    wrong_fleet = FedScheduler(
        sim, make_strategy("chainfed", CFG, CHAIN, KEY), mode="semisync")
    with pytest.raises(ValueError, match="mismatch on 'n_clients'"):
        wrong_fleet.restore(ck)
    wrong_strategy = FedScheduler(
        build_sim(), make_strategy("full_adapters", CFG, CHAIN, KEY),
        mode="semisync")
    with pytest.raises(ValueError, match="mismatch on 'strategy'"):
        wrong_strategy.restore(ck)


def test_checkpoint_refuses_inflight_secure_sessions():
    """An open masking session holds pairwise secrets that must never land
    on disk; a heap entry still carrying one is not checkpointable."""
    from repro.fed.checkpoint import _pending_state
    from repro.fed.runtime import _Pending
    e = _Pending(finish=1.0, client=build_sim().clients[0], plan=None,
                 bucket=None, bi=0, masks={}, weight=4.0, version=0,
                 session=object())
    with pytest.raises(ValueError, match="secure-aggregation"):
        _pending_state(e, None, None)
    ok = dataclasses.replace(e, session=None)
    assert _pending_state(ok, None, None)["cid"] == 0


def test_run_experiment_resume_path(tmp_path):
    """The registry-level wiring: checkpoint_every/halt_after/resume flow
    through ``run_experiment`` and reproduce the uninterrupted run."""
    ck = tmp_path / "exp.msgpack"
    kw = dict(cfg=CFG, chain=CHAIN,
              fed=FedConfig(n_clients=6, clients_per_round=3, seed=3),
              batch_size=4, memory_constrained=False, rounds=4, eval_every=2,
              mode="semisync", dp={"clip": 0.5, "noise_multiplier": 0.6,
                                   "delta": 1e-5})
    full = run_experiment("chainfed", **kw)
    run_experiment("chainfed", **kw, checkpoint_every=2, checkpoint_path=ck,
                   halt_after=2)
    resumed = run_experiment("chainfed", **kw, resume=ck)
    assert full.history == resumed.history
    la = jax.tree_util.tree_leaves(full.strategy.adapters)
    lc = jax.tree_util.tree_leaves(resumed.strategy.adapters)
    for x, y in zip(la, lc):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ========================================================== adaptive clipping
def test_adaptive_clip_decays_toward_quantile():
    """All observed norms sit far below the bound → frac_below = 1 every
    round and the clip follows the closed form C·exp(−η·(1−γ)) per round:
    10 → 10·exp(−0.6) ≈ 5.488 after 4 rounds with η=0.3, γ=0.5."""
    from repro.fed.privacy import current_clip
    kw = dict(cfg=CFG, chain=CHAIN,
              fed=FedConfig(n_clients=6, clients_per_round=3, seed=3),
              batch_size=4, memory_constrained=False, rounds=4, eval_every=4,
              dp={"clip": 10.0, "noise_multiplier": 0.3, "delta": 1e-5,
                  "adaptive_clip": True, "target_quantile": 0.5,
                  "clip_lr": 0.3})
    sync = run_experiment("full_adapters", **kw)
    got = current_clip(sync.strategy)
    assert got == pytest.approx(10.0 * np.exp(-0.6), rel=1e-6)
    # the event-driven path observes the same norms → identical clip
    semi = run_experiment("full_adapters", mode="semisync",
                          scheduler_opts={"deadline_quantile": 1.0}, **kw)
    assert current_clip(semi.strategy) == pytest.approx(got, rel=1e-6)
