"""Planet-scale population runtime (ISSUE 8): hierarchical edge → silo →
server aggregation equals the flat cohort (the 1-silo topology routes
through the unmodified flat commit, N-silo matches to ≤1e-5 for uniform and
weighted cohorts), lazy ``ClientPool`` synthesis is deterministic in
``(seed, cid)`` and keeps resident state O(active cohort) at a 10⁶-client
population, a kill/resume through a hierarchical + lazy run is
bit-identical, per-completion async (pow2 dispatch batching) matches the
buffer=1 fixed-pad path, and the event loop stays recompile-free."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.memory import hierarchy_comm_bytes
from repro.data.partition import AvailabilityTrace, uniform_profiles
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim
from repro.fed.registry import make_strategy
from repro.fed.runtime import FedScheduler, SiloAggregator, Topology
from repro.models.config import ChainConfig, FedConfig

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=1, lr=3e-3)
KEY = jax.random.PRNGKey(0)


def build_sim(seed=3, n_clients=6, clients_per_round=3, batch_size=4,
              uniform=False, iid=False, lazy=False, shard_size=None):
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: classification_batch(spec, tokens, labels, idx)
    fed = FedConfig(n_clients=n_clients, clients_per_round=clients_per_round,
                    seed=seed, iid=iid)
    sim = FedSim(CFG, fed, tokens, labels, batch_fn, batch_size=batch_size,
                 memory_constrained=False, lazy=lazy, shard_size=shard_size)
    if uniform and not lazy:
        for c, p in zip(sim.clients, uniform_profiles(n_clients)):
            c.profile = p
    return sim


def run_topo(topology, mode="sync", rounds=4, name="full_adapters",
             eval_every=2, sim_kw=None, sched_kw=None, dp=False):
    sim = build_sim(**(sim_kw or {}))
    strat = make_strategy(name, CFG, CHAIN, KEY)
    if dp:
        from repro.fed.privacy import DPConfig, enable_dp
        enable_dp(strat, DPConfig(clip=0.5, noise_multiplier=0.0, delta=1e-5))
    sched = FedScheduler(sim, strat, mode=mode, topology=topology,
                         **(sched_kw or {}))
    hist = sched.run(rounds, eval_every=eval_every)
    leaves = [np.asarray(l)
              for l in jax.tree_util.tree_leaves(strat.adapters)]
    return hist, leaves, sched


def metric_rows(hist):
    return [(m.round, m.loss, m.acc, m.n_participants) for m in hist]


# ===================================================== hierarchy ≡ flat
def test_one_silo_topology_routes_through_flat_path():
    """``n_silos=1`` must be *literally* the flat path — no ``SiloAggregator``
    is even constructed, so the histories and trainables are bit-identical
    by construction (and verified here anyway)."""
    h_flat, s_flat, _ = run_topo(None)
    h_one, s_one, sched = run_topo(Topology(n_silos=1))
    assert sched._silo is None
    assert metric_rows(h_flat) == metric_rows(h_one)
    for a, b in zip(s_flat, s_one):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("assign", ["block", "mod"])
def test_hierarchy_matches_flat_weighted_cohort(assign):
    """2-tier aggregation over dirichlet (non-uniform sample weight) cohorts
    is the same weighted mean as the flat commit, differing only in float
    summation order: every eval and the final trainables agree to ≤1e-5."""
    h_flat, s_flat, _ = run_topo(None)
    h_hier, s_hier, sched = run_topo(Topology(n_silos=3, assign=assign))
    assert [(m.round, m.n_participants) for m in h_flat] == \
           [(m.round, m.n_participants) for m in h_hier]
    for a, b in zip(h_flat, h_hier):
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(a.acc, b.acc, rtol=1e-5, atol=1e-5)
    for a, b in zip(s_flat, s_hier):
        np.testing.assert_allclose(a, b, atol=1e-5)
    # every silo that held sampled members contributed
    assert int(sched._silo.silo_updates.sum()) == sched.committed_updates


def test_hierarchy_matches_flat_uniform_weights():
    """IID shards → equal sample counts → uniform weights: the two-tier mean
    collapses to the flat mean exactly (up to summation order)."""
    kw = {"iid": True, "clients_per_round": 4, "n_clients": 8}
    h_flat, s_flat, _ = run_topo(None, sim_kw=kw, rounds=3)
    h_hier, s_hier, _ = run_topo(Topology(n_silos=2), sim_kw=kw, rounds=3)
    for a, b in zip(h_flat, h_hier):
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-5, atol=1e-5)
    for a, b in zip(s_flat, s_hier):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_hierarchy_dp_clips_at_silo_tier_matches_flat():
    """σ=0 isolates the clip: per-tier DP (members clipped at the silo, the
    uniform live-member mean at the server) must equal the flat private
    aggregate's clip-then-mean to float tolerance."""
    h_flat, s_flat, _ = run_topo(None, dp=True)
    h_hier, s_hier, _ = run_topo(Topology(n_silos=2), dp=True)
    for a, b in zip(s_flat, s_hier):
        np.testing.assert_allclose(a, b, atol=1e-5)
    for a, b in zip(h_flat, h_hier):
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-5, atol=1e-5)


def test_hierarchy_tier_bytes_accounting():
    """Edge traffic counts every member upload; WAN traffic one payload per
    contributing silo per commit — and the static ``hierarchy_comm_bytes``
    model agrees with the live counters for full-wave commits."""
    _, _, sched = run_topo(Topology(n_silos=3), rounds=3)
    strat = sched.strategy
    payload = strat.comm_bytes_per_round() // max(
        1, sched.sim.fed.clients_per_round)
    assert sched.tier_bytes["edge"] == payload * sched.committed_updates
    assert sched.tier_bytes["silo"] > 0
    assert sched.tier_bytes["silo"] <= sched.tier_bytes["edge"]
    model = hierarchy_comm_bytes(payload, 3, n_silos=3)
    assert model["edge"] == 3 * payload and model["silo"] <= 3 * payload
    flat = hierarchy_comm_bytes(payload, 3, n_silos=1)
    assert flat == {"edge": 0, "silo": 3 * payload, "total": 3 * payload}


def test_silo_trace_takes_members_offline():
    """A dark silo's clients must never be sampled: with silo 1 offline for
    the whole horizon every commit draws from silo 0 only."""
    trace = AvailabilityTrace(windows=(((0.0, 900.0),), ((990.0, 999.0),)),
                              period=1000.0)
    h, _, sched = run_topo(Topology(n_silos=2, trace=trace), mode="semisync",
                           rounds=3)
    assert sched.committed_updates > 0
    assert int(sched._silo.silo_updates[0]) == sched.committed_updates
    assert int(sched._silo.silo_updates[1]) == 0


def test_hierarchy_refuses_custom_update_space():
    """Strategies with a bespoke in-graph cohort aggregation (fedkseed's
    (K,) coefficient space) can't be silo-pre-aggregated as parameter
    deltas — the scheduler must refuse loudly, not aggregate garbage."""
    sim = build_sim()
    strat = make_strategy("fedkseed", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="sync", topology=Topology(n_silos=2))
    with pytest.raises(ValueError, match="cohort"):
        sched.run(2, eval_every=2)


# ==================================================== lazy client pool
def test_lazy_synthesis_deterministic_in_seed_and_cid():
    """Budget, device profile and data shard depend on ``(seed, cid)``
    alone; the minibatch stream additionally on the visit number — so two
    pools visiting cids in different orders materialize identical clients
    and identical per-visit batches."""
    a = build_sim(lazy=True, n_clients=12)
    b = build_sim(lazy=True, n_clients=12)
    for cid in (0, 7, 11):
        ca = a.pool.acquire(cid)
        a.pool.release(cid)
    # b visits in a different global order, interleaved with other cids
    for cid in (5, 11, 3, 7, 0):
        b.pool.acquire(cid)
        b.pool.release(cid)
    for cid in (0, 7, 11):
        ca, cb = a.pool.acquire(cid), b.pool.acquire(cid)
        assert ca.mem_budget == cb.mem_budget == a.lazy_budget(cid)
        assert ca.profile == cb.profile
        np.testing.assert_array_equal(ca.sampler.shard, cb.sampler.shard)
        # same visit number (2nd for both) → identical batch stream
        np.testing.assert_array_equal(ca.sampler.next_indices(),
                                      cb.sampler.next_indices())
        a.pool.release(cid)
        b.pool.release(cid)


def test_lazy_run_is_reproducible():
    """Two identical lazy runs (same seed, same population) must produce
    bit-identical histories and trainables — dispatch-order determinism of
    the pool's rejection sampler and visit cursors."""
    kw = {"lazy": True, "n_clients": 32, "shard_size": 8}
    h1, s1, _ = run_topo(None, mode="semisync", sim_kw=kw)
    h2, s2, _ = run_topo(None, mode="semisync", sim_kw=kw)
    assert metric_rows(h1) == metric_rows(h2)
    for a, b in zip(s1, s2):
        np.testing.assert_array_equal(a, b)


def test_million_client_population_smoke():
    """A 10⁶-client federation on one host: resident client state stays
    O(active cohort) — the pool materializes only dispatched cids and
    releases them at commit."""
    kw = {"lazy": True, "n_clients": 1_000_000, "clients_per_round": 3,
          "shard_size": 8}
    h, _, sched = run_topo(Topology(n_silos=4, assign="mod"), mode="semisync",
                           rounds=2, eval_every=2, sim_kw=kw)
    pool = sched.sim.pool
    assert sched.committed_updates > 0
    assert pool.resident == 0                    # all released post-commit
    assert pool.max_resident <= 4 * 3 + 8        # O(cohort), not O(10⁶)
    assert pool.max_resident_bytes < 1 << 20
    assert sched.events > 0


# ============================================== per-completion dispatch
def test_pow2_per_completion_matches_fixed_pad_async():
    """buffer=1 async under ``pad_policy="pow2"`` dispatches size-1
    replacement buckets (true per-completion FedBuff) — the trajectory must
    match the fixed-pad path (padding rows never contribute) with the
    compile set still bounded."""
    common = dict(mode="async", rounds=6, eval_every=3,
                  sim_kw={"uniform": True})
    h_fix, s_fix, _ = run_topo(None, sched_kw={"buffer_size": 1,
                                               "concurrency": 3,
                                               "pad_policy": "fixed"},
                               **common)
    h_p2, s_p2, sched = run_topo(None, sched_kw={"buffer_size": 1,
                                                 "concurrency": 3,
                                                 "pad_policy": "pow2"},
                                 **common)
    assert [(m.round, m.n_participants) for m in h_fix] == \
           [(m.round, m.n_participants) for m in h_p2]
    for a, b in zip(h_fix, h_p2):
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-5, atol=1e-6)
    for a, b in zip(s_fix, s_p2):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    # pow2 compile keys: initial wave (3) + singles (1) only
    for f in sched.strategy.engine._cohort.values():
        if hasattr(f, "_cache_size"):
            assert f._cache_size() <= 2


def _engine_cache_sizes(strat):
    return [f._cache_size()
            for cache in (strat.engine._cohort, strat.engine._cohort_updates)
            for f in cache.values() if hasattr(f, "_cache_size")]


def test_hierarchical_event_loop_is_recompile_free():
    """Steady state triggers zero recompiles: with a constant commit
    composition (full participation) every jit cache — the cohort step,
    the silo reduce and the server combine — is warm after the first
    commit and must stop growing."""
    sim = build_sim(n_clients=8, clients_per_round=8)
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="sync",
                         topology=Topology(n_silos=2), pad_policy="pow2")
    sched.run(2, eval_every=2)
    warm = (_engine_cache_sizes(strat), sched._silo._cache_sizes())
    sched.run(6, eval_every=3)
    assert (_engine_cache_sizes(strat), sched._silo._cache_sizes()) == warm


def test_hierarchical_compile_set_is_bounded_under_churn():
    """Partial participation varies the commit size and the per-silo member
    counts commit to commit; pow2 padding must still bound the whole
    compile set: ONE fused fedavg/fedavg entry whose traces are capped by
    the reachable pow2 ``(E, tgt, Sp)`` triples — a handful no matter how
    many rounds run."""
    sim = build_sim(n_clients=8, clients_per_round=4)
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="semisync",
                         topology=Topology(n_silos=2), pad_policy="pow2")
    sched.run(10, eval_every=5)
    assert len(sched._silo._fused_jit) == 1
    assert not sched._silo._reduce_jit and not sched._silo._server_jit
    # commits of E ∈ {1..4} members over 2 silos reach ≤ 9 distinct
    # (pow2 members, pow2 max-per-silo) shape pairs — the silo axis is
    # churn-independent and never keys a trace
    assert all(n <= 9 for n in sched._silo._cache_sizes())


# ========================================== kill/resume at planet scale
def test_kill_resume_hierarchical_lazy_bit_identical(tmp_path):
    """The full ISSUE-8 state surface round-trips: pool visit cursors, silo
    tallies and the event heap — a run killed mid-flight over a lazy
    population with 2 silos finishes bit-identically to an uninterrupted
    one."""
    def sched_for():
        sim = build_sim(lazy=True, n_clients=24, clients_per_round=3,
                        shard_size=8)
        strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
        return FedScheduler(sim, strat, mode="semisync",
                            topology=Topology(n_silos=2))

    rounds, ck = 6, tmp_path / "pop.msgpack"
    a = sched_for()
    ha = a.run(rounds, eval_every=2)
    b = sched_for()
    b.run(rounds, eval_every=2, checkpoint_every=2, checkpoint_path=ck,
          halt_after=2)
    c = sched_for()
    c.restore(ck)
    hc = c.run(rounds, eval_every=2)
    assert ha == hc
    for x, y in zip(jax.tree_util.tree_leaves(a.strategy.adapters),
                    jax.tree_util.tree_leaves(c.strategy.adapters)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(a._silo.silo_commits, c._silo.silo_commits)
    np.testing.assert_array_equal(a._silo.silo_updates, c._silo.silo_updates)
    sa, sc = a.sim.pool.state_dict(), c.sim.pool.state_dict()
    np.testing.assert_array_equal(sa["cids"], sc["cids"])
    np.testing.assert_array_equal(sa["visits"], sc["visits"])


def test_flat_checkpoint_refuses_silo_restore(tmp_path):
    """A checkpoint carrying silo state must not restore into a flat run —
    the tallies would silently vanish."""
    sim = build_sim(n_clients=8, clients_per_round=4)
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="semisync",
                         topology=Topology(n_silos=2))
    ck = tmp_path / "hier.msgpack"
    sched.run(2, eval_every=2, checkpoint_every=2, checkpoint_path=ck)
    flat = FedScheduler(build_sim(n_clients=8, clients_per_round=4),
                        make_strategy("full_adapters", CFG, CHAIN, KEY),
                        mode="semisync")
    with pytest.raises(ValueError):
        flat.restore(ck)
