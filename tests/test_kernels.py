"""Per-kernel correctness: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp oracles in repro.kernels.ref (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given_or_grid

from repro.kernels import ops, ref


def rnd(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(jax.random.PRNGKey(key), shape)).astype(dtype)


# ------------------------------------------------------------- fused adapter
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,d,r", [(8, 64, 8), (130, 256, 16), (33, 128, 64)])
def test_fused_adapter_shapes(T, d, r, dtype):
    h = rnd(0, (T, d), dtype)
    wd = rnd(1, (d, r), dtype, 0.05)
    wu = rnd(2, (r, d), dtype, 0.05)
    out = ops.fused_adapter(h, wd, wu)
    exp = ref.fused_adapter_ref(h, wd, wu)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@given_or_grid([dict(T=T, d=d, r=r, act=act)
                for T, d, r in [(1, 32, 4), (33, 64, 8), (80, 128, 16)]
                for act in ("gelu", "relu", "silu")],
               lambda st: dict(T=st.integers(1, 80),
                               d=st.sampled_from([32, 64, 128]),
                               r=st.sampled_from([4, 8, 16]),
                               act=st.sampled_from(["gelu", "relu", "silu"])),
               max_examples=12)
def test_fused_adapter_property(T, d, r, act):
    h = rnd(T, (T, d))
    wd = rnd(T + 1, (d, r), scale=0.05)
    wu = rnd(T + 2, (r, d), scale=0.05)
    out = ops.fused_adapter(h, wd, wu, activation=act)
    exp = ref.fused_adapter_ref(h, wd, wu, activation=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_fused_adapter_identity_at_zero_up():
    """W_up = 0 ⇒ adapter is the identity (the chain's safe insertion)."""
    h = rnd(3, (17, 64))
    wd = rnd(4, (64, 8), scale=0.1)
    out = ops.fused_adapter(h, wd, jnp.zeros((8, 64)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(h), atol=1e-6)


def test_fused_adapter_leading_dims():
    h = rnd(5, (2, 7, 64))
    wd, wu = rnd(6, (64, 8), scale=0.1), rnd(7, (8, 64), scale=0.1)
    out = ops.fused_adapter(h, wd, wu)
    exp = ref.fused_adapter_ref(h.reshape(-1, 64), wd, wu).reshape(2, 7, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


# ------------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,hd", [(1, 1, 128, 32), (2, 3, 256, 64)])
def test_flash_attention_causal(B, H, S, hd, dtype):
    q, k, v = (rnd(i, (B, H, S, hd), dtype) for i in range(3))
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@given_or_grid([dict(S=S, hd=hd, window=w, causal=c)
                for S, hd in [(64, 16), (128, 32), (192, 16)]
                for w in (None, 16, 50) for c in (True, False)],
               lambda st: dict(S=st.sampled_from([64, 128, 192]),
                               hd=st.sampled_from([16, 32]),
                               window=st.sampled_from([None, 16, 50]),
                               causal=st.booleans()),
               max_examples=12)
def test_flash_attention_property(S, hd, window, causal):
    if window is not None and not causal:
        window = None
    q, k, v = (rnd(i + 10, (1, 2, S, hd)) for i in range(3))
    out = ops.flash_attention(q, k, v, causal=causal, window=window, bq=32, bk=32)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=3e-5, rtol=1e-4)


# ------------------------------------------------------------- ssm scan
@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (64, 64)])
@pytest.mark.parametrize("d,N", [(8, 4), (16, 8)])
def test_ssm_scan_shapes(S, chunk, d, N):
    B = 2
    u = rnd(0, (B, S, d))
    dt = jax.nn.softplus(rnd(1, (B, S, d)))
    Bm, Cm = rnd(2, (B, S, N)), rnd(3, (B, S, N))
    A = -jnp.exp(rnd(4, (d, N)))
    D = jnp.ones((d,))
    y, h = ops.ssm_scan(u, dt, Bm, Cm, A, D, chunk=chunk)
    ye, he = ref.ssm_scan_ref(u, dt, Bm, Cm, A, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=1e-4, rtol=1e-4)


@given_or_grid([dict(S=S, d=d, N=N, with_h0=h)
                for S, d, N in [(16, 4, 2), (32, 8, 4), (32, 4, 4)]
                for h in (True, False)],
               lambda st: dict(S=st.sampled_from([16, 32]),
                               d=st.sampled_from([4, 8]),
                               N=st.sampled_from([2, 4]),
                               with_h0=st.booleans()),
               max_examples=12)
def test_ssm_scan_property(S, d, N, with_h0):
    B = 1
    u = rnd(20, (B, S, d))
    dt = jax.nn.softplus(rnd(21, (B, S, d)))
    Bm, Cm = rnd(22, (B, S, N)), rnd(23, (B, S, N))
    A = -jnp.exp(rnd(24, (d, N)))
    D = rnd(25, (d,))
    h0 = rnd(26, (B, d, N)) if with_h0 else None
    y, h = ops.ssm_scan(u, dt, Bm, Cm, A, D, h0=h0, chunk=8)
    ye, he = ref.ssm_scan_ref(u, dt, Bm, Cm, A, D, h0=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=1e-4, rtol=1e-4)


def test_ssm_scan_chunk_invariance():
    """Chunk size must not change the result (state carry correctness)."""
    B, S, d, N = 1, 64, 4, 4
    u = rnd(30, (B, S, d))
    dt = jax.nn.softplus(rnd(31, (B, S, d)))
    Bm, Cm = rnd(32, (B, S, N)), rnd(33, (B, S, N))
    A = -jnp.exp(rnd(34, (d, N)))
    D = jnp.zeros((d,))
    y8, _ = ops.ssm_scan(u, dt, Bm, Cm, A, D, chunk=8)
    y64, _ = ops.ssm_scan(u, dt, Bm, Cm, A, D, chunk=64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), atol=1e-5)


# ------------------------------------------------------------- cka gram
@pytest.mark.parametrize("n,d1,d2", [(16, 32, 32), (64, 100, 130), (8, 512, 64)])
def test_cka_gram(n, d1, d2):
    X = rnd(40, (n, d1))
    Y = rnd(41, (n, d2))
    X, Y = X - X.mean(0), Y - Y.mean(0)
    got = ops.cka_gram(X, Y, bd=32)
    exp = ref.cka_gram_ref(X, Y)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(float(g), float(e), rtol=1e-4)


def test_cka_gram_self_similarity():
    """CKA(X, X) must be exactly 1 through the kernel path."""
    from repro.core.foat import linear_cka
    X = rnd(42, (32, 64))
    assert abs(float(linear_cka(X, X, use_kernel=True)) - 1.0) < 1e-5
    assert abs(float(linear_cka(X, X, use_kernel=False)) - 1.0) < 1e-5
