"""GradProgram unit tests (ISSUE 4): the registry, SPSA convergence on a
quadratic, K-seed coefficient round-trips through ``kseed_apply``, the
deterministic per-(round, client, step) RNG derivation, and the grad-program
dispatch on the pjit pod step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adapters import ActiveAdapters
from repro.fed.strategies import (GRAD_PROGRAMS, LOSS_HOOKS, TrainablePlan,
                                  fold_step_masks, register_grad_program)
from repro.models.config import ChainConfig
from repro.optim.zeroth import (forward_value_and_grad, kseed_apply,
                                kseed_directional, spsa_value_and_grad,
                                _perturbation)
from repro.utils.tree import tree_axpy, tree_map

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)


# ---------------------------------------------------------------- registry
def test_builtin_programs_registered():
    for name in ("ad", "spsa", "jvp", "kseed"):
        assert name in GRAD_PROGRAMS, name
    assert not GRAD_PROGRAMS["ad"].whole_client
    assert not GRAD_PROGRAMS["spsa"].whole_client
    assert not GRAD_PROGRAMS["jvp"].whole_client
    assert GRAD_PROGRAMS["jvp"].needs_rng
    assert GRAD_PROGRAMS["kseed"].whole_client


def test_register_grad_program_decorator():
    try:
        @register_grad_program("_test_prog")
        def _prog(cfg, chain, plan, loss_fn):
            return None

        assert GRAD_PROGRAMS["_test_prog"] is _prog
        assert not _prog.whole_client
    finally:
        GRAD_PROGRAMS.pop("_test_prog", None)


def test_plan_hashable_with_grad_cfg():
    spec = ActiveAdapters.full(4)
    p1 = TrainablePlan(adapters=spec, grad="spsa",
                       grad_cfg=(("eps", 1e-3), ("n_samples", 4)))
    p2 = TrainablePlan(adapters=spec, grad="spsa",
                       grad_cfg=(("eps", 1e-3), ("n_samples", 4)))
    p3 = TrainablePlan(adapters=spec, grad="spsa",
                       grad_cfg=(("eps", 1e-3), ("n_samples", 8)))
    assert hash(p1) == hash(p2) and p1 == p2
    assert p1 != p3                 # knobs key the jit cache
    assert p1.grad_options == {"eps": 1e-3, "n_samples": 4}


# ------------------------------------------------------------------- spsa
def test_spsa_converges_on_quadratic():
    """SGD driven by the SPSA estimate must descend a strongly convex
    quadratic to (near) its minimum — the estimator is a descent direction
    in expectation."""
    target = {"w": jnp.asarray([1.5, -2.0, 0.5]), "b": jnp.asarray([0.25])}

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    p = {"w": jnp.zeros(3), "b": jnp.zeros(1)}
    key = jax.random.PRNGKey(0)
    l0 = float(loss(p))
    for i in range(200):
        _, g, _ = spsa_value_and_grad(loss, p, jax.random.fold_in(key, i),
                                      eps=1e-3, n_samples=8)
        p = tree_map(lambda x, gx: x - 0.05 * gx, p, g)
    assert float(loss(p)) < 1e-2 * l0
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target["w"]),
                               atol=0.1)


def test_spsa_loss_estimate_matches_center():
    """The reported loss is the mean of antithetic pair evaluations —
    loss(params) + O(eps²), so no extra forward pass is needed."""
    def loss(p):
        return jnp.sum(p["w"] ** 2)

    p = {"w": jnp.asarray([1.0, 2.0])}
    l_est, _, _ = spsa_value_and_grad(loss, p, jax.random.PRNGKey(1),
                                      eps=1e-3, n_samples=4)
    assert abs(float(l_est) - float(loss(p))) < 1e-4


# -------------------------------------------------------------------- jvp
def test_jvp_matches_finite_difference_on_quadratic():
    """True forward-mode vs SPSA parity (ISSUE 5 satellite): on a quadratic
    the central finite difference is *exact* for any eps, and both
    estimators draw identical perturbation directions from the same key —
    so ``jax.jvp``'s exact directional derivatives must reproduce the SPSA
    estimate to float precision, gradient and loss alike."""
    target = {"w": jnp.asarray([1.5, -2.0, 0.5]), "b": jnp.asarray([0.25])}

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    p = {"w": jnp.asarray([0.3, 0.1, -0.2]), "b": jnp.asarray([1.0])}
    key = jax.random.PRNGKey(11)
    l_fd, g_fd, c_fd = spsa_value_and_grad(loss, p, key, eps=1e-2,
                                           n_samples=6)
    l_jvp, g_jvp, c_jvp = forward_value_and_grad(loss, p, key, n_samples=6)
    # the SPSA loss report carries the +eps²|v|² antithetic-pair bias
    np.testing.assert_allclose(float(l_fd), float(l_jvp), rtol=1e-3)
    np.testing.assert_allclose(np.asarray(c_fd), np.asarray(c_jvp),
                               rtol=1e-4, atol=1e-5)
    for k in p:
        np.testing.assert_allclose(np.asarray(g_fd[k]),
                                   np.asarray(g_jvp[k]),
                                   rtol=1e-4, atol=1e-5)


def test_jvp_converges_on_quadratic():
    target = {"w": jnp.asarray([1.5, -2.0, 0.5])}

    def loss(p):
        return jnp.sum((p["w"] - target["w"]) ** 2)

    p = {"w": jnp.zeros(3)}
    key = jax.random.PRNGKey(0)
    l0 = float(loss(p))
    for i in range(200):
        _, g, _ = forward_value_and_grad(loss, p, jax.random.fold_in(key, i),
                                         n_samples=8)
        p = tree_map(lambda x, gx: x - 0.05 * gx, p, g)
    assert float(loss(p)) < 1e-2 * l0


def test_fwdllm_jvp_strategy_round_runs():
    """The registered ``fwdllm_jvp`` variant rides the batched cohort path
    with the forward-mode program and moves the adapters."""
    import dataclasses

    from repro.data.synthetic import (DATASETS, classification_batch,
                                      make_classification)
    from repro.fed.engine import FedSim
    from repro.fed.registry import make_strategy
    from repro.models.config import FedConfig

    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    bf = lambda idx: classification_batch(spec, tokens, labels, idx)
    sim = FedSim(CFG, FedConfig(n_clients=4, clients_per_round=2, seed=5),
                 tokens, labels, bf, batch_size=4, memory_constrained=False)
    strat = make_strategy("fwdllm_jvp", CFG,
                          ChainConfig(local_steps=1, lr=1e-3),
                          jax.random.PRNGKey(9))
    assert strat.plan(sim.clients[0], 0).grad == "jvp"
    before = np.asarray(strat.adapters["down"]).copy()
    strat.round(sim, sim.sample_clients(strat.memory_method), 0)
    assert len(strat.engine._cohort) == 1
    assert not np.array_equal(before, np.asarray(strat.adapters["down"]))


# ------------------------------------------------------------------ kseed
def test_kseed_coeffs_roundtrip_through_apply():
    """kseed_apply must reproduce exactly θ − lr Σ_k c_k v_k with the same
    seed-reconstructed directions the coefficients were estimated on, and
    the estimated coefficients must match the analytic directional
    derivative on a quadratic."""
    p = {"a": jnp.asarray([1.0, -1.0, 2.0]), "b": jnp.asarray([[0.5, 0.5]])}

    def loss(q):
        return 0.5 * sum(jnp.sum(q[k] ** 2) for k in q)

    seeds = tuple(range(7, 7 + 5))
    coeffs, l_est = kseed_directional(loss, p, jnp.asarray(seeds), eps=1e-3)
    assert coeffs.shape == (len(seeds),)
    assert abs(float(l_est) - float(loss(p))) < 1e-4
    # analytic: ∇loss = p, so coeff_k = <v_k, p>
    for s, c in zip(seeds, coeffs):
        v = _perturbation(jax.random.PRNGKey(s), p)
        expect = sum(float(jnp.sum(v[k] * p[k])) for k in p)
        assert abs(float(c) - expect) < 1e-2
    # replay: kseed_apply ≡ θ − lr Σ c_k v_k, and is deterministic
    lr = 0.01
    manual = p
    for s, c in zip(seeds, coeffs):
        v = _perturbation(jax.random.PRNGKey(int(s)), p)
        manual = tree_axpy(-lr * float(c), v, manual)
    got1 = kseed_apply(p, seeds, [float(c) for c in coeffs], lr)
    got2 = kseed_apply(p, seeds, [float(c) for c in coeffs], lr)
    for k in p:
        np.testing.assert_allclose(np.asarray(got1[k]), np.asarray(manual[k]),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(got1[k]),
                                      np.asarray(got2[k]))


def test_kseed_descends_quadratic():
    p = {"w": jnp.asarray([3.0, -4.0])}

    def loss(q):
        return 0.5 * jnp.sum(q["w"] ** 2)

    seeds = tuple(range(100, 132))
    for _ in range(10):
        coeffs, _ = kseed_directional(loss, p, jnp.asarray(seeds), eps=1e-3)
        p = kseed_apply(p, seeds, [float(c) / len(seeds) for c in coeffs],
                        lr=0.05)
    assert float(loss(p)) < 0.5 * (3.0 ** 2 + 4.0 ** 2) * 0.5


# ------------------------------------------------------- deterministic rng
def test_fold_step_masks_deterministic_and_distinct():
    key = jax.random.PRNGKey(42)
    masks = {"grad_key": key, "layer_mask": jnp.ones(4)}
    a = fold_step_masks(masks, 0)
    b = fold_step_masks(masks, 0)
    c = fold_step_masks(masks, 1)
    np.testing.assert_array_equal(np.asarray(a["grad_key"]),
                                  np.asarray(b["grad_key"]))
    assert not np.array_equal(np.asarray(a["grad_key"]),
                              np.asarray(c["grad_key"]))
    np.testing.assert_array_equal(np.asarray(a["layer_mask"]),
                                  np.asarray(masks["layer_mask"]))
    assert fold_step_masks({}, 3) == {}


def test_fwdllm_round_rerun_bit_identical():
    """Stateless RNG derivation: re-running the same round from the same
    state must reproduce bit-identical adapters (the old mutated-key path
    could not)."""
    import dataclasses

    from repro.data.synthetic import (DATASETS, classification_batch,
                                      make_classification)
    from repro.fed.engine import FedSim
    from repro.fed.registry import make_strategy
    from repro.models.config import FedConfig

    chain = ChainConfig(window=2, local_steps=2, lr=1e-3)

    def one_run():
        spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
        tokens, labels = make_classification(spec)
        bf = lambda idx: {k: jnp.asarray(v) for k, v in
                          classification_batch(spec, tokens, labels,
                                               idx).items()}
        sim = FedSim(CFG, FedConfig(n_clients=4, clients_per_round=2, seed=5),
                     tokens, labels, bf, batch_size=4,
                     memory_constrained=False)
        strat = make_strategy("fwdllm", CFG, chain, jax.random.PRNGKey(9))
        clients = sim.sample_clients(strat.memory_method)
        strat.round(sim, clients, 0)
        return np.asarray(strat.adapters["down"])

    np.testing.assert_array_equal(one_run(), one_run())


# ------------------------------------------------------------ pod dispatch
@pytest.mark.parametrize("grad,grad_cfg", [
    ("ad", ()),
    ("spsa", (("eps", 1e-3), ("n_samples", 2))),
])
def test_pod_e2e_step_dispatches_grad_program(grad, grad_cfg):
    """The pjit pod step builds from the same GradProgram dispatch: both the
    autodiff and the perturbation program produce finite losses and update
    the adapters."""
    from repro.models.transformer import init_adapters, init_lm
    from repro.train.steps import make_e2e_train_step

    params = init_lm(jax.random.PRNGKey(0), CFG)
    adapters = init_adapters(jax.random.PRNGKey(1), CFG)
    step = make_e2e_train_step(CFG, ChainConfig(local_steps=1, lr=1e-2,
                                                optimizer="sgd"),
                               grad=grad, grad_cfg=grad_cfg)
    batch = {"tokens": jnp.ones((2, 1, 2, 8), jnp.int32),
             "labels": jnp.ones((2, 1, 2, 8), jnp.int32)}
    key = None if grad == "ad" else jax.random.PRNGKey(3)
    new, metrics = jax.jit(step)(params, adapters, batch, key)
    assert np.isfinite(float(metrics["loss"]))
    delta = float(jnp.abs(new["down"] - adapters["down"]).sum()
                  + jnp.abs(new["up"] - adapters["up"]).sum())
    assert delta > 0.0
    if grad == "spsa":      # stochastic programs must fail loudly w/o a key
        with pytest.raises(ValueError, match="PRNG key"):
            step(params, adapters, batch)


def test_pod_step_rejects_whole_client_programs():
    """The pod step's FedAvg + scatter commit cannot consume a
    program-defined upload (kseed coefficients) — constructing it must fail
    with a clear error, not a tree mismatch deep in the trace."""
    from repro.train.steps import make_e2e_train_step

    with pytest.raises(ValueError, match="program-defined upload"):
        make_e2e_train_step(CFG, ChainConfig(local_steps=1), grad="kseed",
                            grad_cfg=(("seeds", (1, 2)), ("eps", 1e-3)))
