"""Declarative ExperimentSpec API (ISSUE 8 satellite): frozen spec sections
serialize/deserialize losslessly, ``run_experiment(spec=...)`` reproduces the
equivalent kwargs invocation bit for bit, checkpoints embed the spec and
refuse to resume under any changed field, the registry introspects strategy
knobs, and the legacy kwargs/``run_rounds`` surfaces are deprecated aliases
rather than separate code paths."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.fed.registry import (describe_strategy, list_strategies,
                                make_strategy, run_experiment)
from repro.fed.spec import (ExperimentSpec, FaultSpec, PrivacySpec, RunSpec,
                            ScheduleSpec, TopologySpec, spec_from_kwargs)
from repro.models.config import ChainConfig, FedConfig

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
KEY = jax.random.PRNGKey(0)

SPEC = ExperimentSpec(
    run=RunSpec(strategy="full_adapters", rounds=3, eval_every=1, seed=3,
                batch_size=4, memory_constrained=False, n_clients=6,
                clients_per_round=3, window=2, local_steps=1, lr=3e-3))


# ============================================================ serialization
def test_spec_json_round_trip_lossless():
    spec = ExperimentSpec(
        run=RunSpec(strategy="fwdllm", rounds=7, lazy=True, shard_size=16,
                    strategy_opts=(("n_samples", 2),)),
        schedule=ScheduleSpec(mode="async", buffer_size=2, pad_policy="pow2"),
        privacy=PrivacySpec(clip=0.5, noise_multiplier=0.6,
                            adaptive_clip=True),
        faults=FaultSpec(dropout_prob=0.2, aggregator="trimmed_mean",
                         aggregator_opts=(("trim", 0.2),)),
        topology=TopologySpec(n_silos=4, assign="mod", trace="diurnal"))
    twin = ExperimentSpec.from_json(spec.to_json())
    assert twin == spec
    assert spec.diff(twin) == {}
    # the wire form is plain JSON — editable config files
    doc = json.loads(spec.to_json())
    assert doc["run"]["strategy"] == "fwdllm"
    assert doc["topology"]["n_silos"] == 4


def test_spec_diff_names_every_changed_field():
    a = ExperimentSpec()
    b = dataclasses.replace(
        a, run=dataclasses.replace(a.run, lr=1e-4, rounds=99),
        topology=dataclasses.replace(a.topology, n_silos=8))
    d = a.diff(b)
    assert set(d) == {"run.lr", "run.rounds", "topology.n_silos"}
    assert d["run.rounds"] == (20, 99)


def test_spec_rejects_unknown_fields():
    with pytest.raises((ValueError, TypeError)):
        ExperimentSpec.from_dict({"run": {"no_such_knob": 1}})
    with pytest.raises((ValueError, TypeError)):
        ExperimentSpec.from_dict({"no_such_section": {}})


def test_spec_from_kwargs_shim():
    chain = ChainConfig(window=2, local_steps=1, lr=3e-3)
    fed = FedConfig(n_clients=6, clients_per_round=3, rounds=3, seed=3)
    s = spec_from_kwargs("full_adapters", batch_size=4, rounds=3,
                         eval_every=1, seed=3, memory_constrained=False,
                         chain=chain, fed=fed)
    assert s is not None
    assert s.run.strategy == "full_adapters" and s.run.window == 2
    assert s.run.n_clients == 6 and s.run.lr == 3e-3
    # live objects a spec can't represent → None (embed nothing), not a crash
    from repro.data.partition import AvailabilityTrace
    t = AvailabilityTrace(windows=(((0.0, 1.0),),), period=2.0)
    assert spec_from_kwargs("full_adapters", trace=t) is None


# ================================================== spec ≡ kwargs invocation
def test_spec_reproduces_kwargs_invocation():
    """The declarative path must build *exactly* what the deprecated loose
    kwargs built: identical RoundMetrics and bit-identical trainables."""
    r_spec = run_experiment(spec=SPEC, cfg=CFG)
    chain = ChainConfig(window=2, lam=0.2, foat_threshold=0.8, local_steps=1,
                        lr=3e-3, optimizer="adamw")
    fed = FedConfig(n_clients=6, clients_per_round=3, rounds=3, iid=False,
                    dirichlet_alpha=1.0, seed=3)
    with pytest.warns(DeprecationWarning, match="ExperimentSpec"):
        r_kw = run_experiment("full_adapters", cfg=CFG, chain=chain, fed=fed,
                              batch_size=4, memory_constrained=False,
                              rounds=3, eval_every=1, seed=3)
    assert r_spec.history == r_kw.history
    for a, b in zip(jax.tree_util.tree_leaves(r_spec.strategy.adapters),
                    jax.tree_util.tree_leaves(r_kw.strategy.adapters)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spec_and_strategy_arg_are_exclusive():
    with pytest.raises(TypeError):
        run_experiment("full_adapters", spec=SPEC)


# ======================================================= checkpointed specs
def test_resume_validates_whole_spec(tmp_path):
    """The checkpoint embeds the spec; resume succeeds under the identical
    spec and refuses — naming the field — under any mismatch."""
    ck = tmp_path / "spec.msgpack"
    full = run_experiment(spec=SPEC, cfg=CFG)
    run_experiment(spec=SPEC, cfg=CFG, checkpoint_every=2,
                   checkpoint_path=ck, halt_after=2)
    resumed = run_experiment(spec=SPEC, cfg=CFG, resume=ck)
    assert full.history == resumed.history
    for a, b in zip(jax.tree_util.tree_leaves(full.strategy.adapters),
                    jax.tree_util.tree_leaves(resumed.strategy.adapters)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    drifted = dataclasses.replace(
        SPEC, run=dataclasses.replace(SPEC.run, lr=1e-4))
    with pytest.raises(ValueError, match=r"spec mismatch.*run\.lr"):
        run_experiment(spec=drifted, cfg=CFG, resume=ck)


# ==================================================== registry introspection
def test_describe_strategy_surfaces_knobs():
    d = describe_strategy("fwdllm")
    assert d["grad_programs"] == ("spsa", "jvp")
    assert "n_samples" in d["options"]
    assert describe_strategy("fwdllm_jvp")["defaults"] == \
        {"grad_program": "jvp"}
    assert describe_strategy("fedkseed")["grad_programs"] == ("kseed",)
    assert describe_strategy("chainfed")["grad_programs"] == ("ad",)


def test_list_strategies_covers_registry():
    names = [d["name"] for d in list_strategies()]
    assert names == sorted(names)
    for expected in ("chainfed", "full_adapters", "fedkseed", "fwdllm"):
        assert expected in names


def test_unknown_strategy_suggests_nearest():
    with pytest.raises(KeyError, match="did you mean 'chainfed'"):
        make_strategy("chianfed", CFG, ChainConfig(), KEY)


def test_unknown_strategy_option_suggests_nearest():
    with pytest.raises(TypeError, match="did you mean 'n_samples'"):
        make_strategy("fwdllm", CFG, ChainConfig(), KEY, n_sample=2)


# ========================================================= deprecated aliases
def test_run_rounds_is_deprecated_alias():
    from repro.data.synthetic import (DATASETS, classification_batch,
                                      make_classification)
    from repro.fed.engine import FedSim, run_rounds
    from repro.fed.runtime import run_sync_rounds
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: classification_batch(spec, tokens, labels, idx)

    def sim():
        return FedSim(CFG, FedConfig(n_clients=6, clients_per_round=3,
                                     seed=3),
                      tokens, labels, batch_fn, batch_size=4,
                      memory_constrained=False)

    chain = ChainConfig(window=2, local_steps=1, lr=3e-3)
    with pytest.warns(DeprecationWarning, match="run_rounds is deprecated"):
        h_alias = run_rounds(sim(), make_strategy("full_adapters", CFG,
                                                  chain, KEY), 2,
                             eval_every=1)
    h_direct = run_sync_rounds(sim(), make_strategy("full_adapters", CFG,
                                                    chain, KEY), 2,
                               eval_every=1)
    assert h_alias == h_direct
