"""Fault injection & robust aggregation (ISSUE 6): deterministic fault
draws, the fixed byzantine set, robust-aggregator semantics (trimmed mean /
median neutralize an outlier, norm-clip bounds it), dropout → timeout →
re-dispatch on the async event heap with no recompiles, byzantine runs
converging under trimmed-mean, zero-fault transparency, and the adaptive
semisync deadline's latency window."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim
from repro.fed.faults import ClientBehavior, FaultModel
from repro.fed.registry import make_strategy, run_experiment
from repro.fed.runtime import FedScheduler
from repro.fed.strategies import cohort_fedavg, make_aggregator
from repro.models.config import ChainConfig, FedConfig

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=1, lr=3e-3)
KEY = jax.random.PRNGKey(0)


def build_sim(seed=3, n_clients=6, clients_per_round=3):
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: classification_batch(spec, tokens, labels, idx)
    fed = FedConfig(n_clients=n_clients, clients_per_round=clients_per_round,
                    seed=seed)
    return FedSim(CFG, fed, tokens, labels, batch_fn, batch_size=4,
                  memory_constrained=False)


def _experiment(**kw):
    fed = FedConfig(n_clients=6, clients_per_round=3, seed=3)
    return run_experiment(kw.pop("method", "full_adapters"), cfg=CFG,
                          chain=CHAIN, fed=fed, batch_size=4,
                          memory_constrained=False, eval_every=1, **kw)


# -------------------------------------------------------------- fault model
def test_fault_model_deterministic_and_sized():
    b = ClientBehavior(dropout_prob=0.4, byzantine_frac=0.25,
                       straggler_prob=0.5, seed=11)
    m1, m2 = FaultModel(b, 8), FaultModel(b, 8)
    assert m1.byzantine == m2.byzantine and len(m1.byzantine) == 2
    for cid in range(8):
        for seq in range(5):
            assert m1.draw(cid, seq) == m2.draw(cid, seq)
    # different dispatches of the same client draw independently
    draws = {m1.draw(0, s) for s in range(40)}
    assert len(draws) > 1
    assert FaultModel(ClientBehavior(), 8).byzantine == frozenset()


def test_update_scales_marks_byzantine_rows():
    b = ClientBehavior(byzantine_frac=0.5, byzantine_scale=-3.0, seed=1)
    m = FaultModel(b, 4)
    s = m.update_scales(list(range(4)))
    assert s.shape == (4,) and set(s.tolist()) == {1.0, -3.0}
    assert [x for x in s if x != 1.0] == [-3.0] * len(m.byzantine)


# ------------------------------------------------------- robust aggregators
def _cohort_with_outlier(c=5, scale=50.0):
    rng = np.random.default_rng(0)
    d = {"w": jnp.asarray(rng.normal(size=(c, 6, 2)), jnp.float32)}
    return {"w": d["w"].at[0].multiply(scale)}, \
        {"w": d["w"][1:]}  # honest rows


def test_trimmed_mean_neutralizes_outlier():
    deltas, honest = _cohort_with_outlier()
    t0 = {"w": jnp.zeros((6, 2), jnp.float32)}
    w = jnp.ones(5, jnp.float32)
    got = make_aggregator("trimmed_mean", trim=0.25)(t0, deltas, w, None)
    # the corrupted row is sorted to an extreme and trimmed away: the result
    # stays within the honest rows' coordinate-wise envelope
    lo = jnp.min(honest["w"], axis=0)
    hi = jnp.max(honest["w"], axis=0)
    assert bool(jnp.all((got["w"] >= lo - 1e-6) & (got["w"] <= hi + 1e-6)))
    plain = cohort_fedavg(t0, deltas, w, None)
    assert float(jnp.abs(plain["w"]).max()) > float(jnp.abs(got["w"]).max())


def test_median_neutralizes_outlier():
    deltas, honest = _cohort_with_outlier()
    t0 = {"w": jnp.zeros((6, 2), jnp.float32)}
    got = make_aggregator("median")(t0, deltas, jnp.ones(5, jnp.float32),
                                    None)
    lo, hi = jnp.min(honest["w"], axis=0), jnp.max(honest["w"], axis=0)
    assert bool(jnp.all((got["w"] >= lo - 1e-6) & (got["w"] <= hi + 1e-6)))


def test_norm_clip_bounds_contributions():
    deltas, _ = _cohort_with_outlier()
    t0 = {"w": jnp.zeros((6, 2), jnp.float32)}
    w = jnp.ones(5, jnp.float32)
    got = make_aggregator("norm_clip", clip=1.0)(t0, deltas, w, None)
    # every row clipped to L2 ≤ 1 → the mean's norm is at most 1
    assert float(jnp.linalg.norm(got["w"])) <= 1.0 + 1e-5
    # clip=0 defaults to the cohort's median norm — still tames the outlier
    med = make_aggregator("norm_clip")(t0, deltas, w, None)
    plain = cohort_fedavg(t0, deltas, w, None)
    assert float(jnp.abs(med["w"]).max()) < float(jnp.abs(plain["w"]).max())


def test_make_aggregator_unknown_raises():
    with pytest.raises(KeyError, match="unknown aggregator"):
        make_aggregator("krum")


# --------------------------------------------------- event-heap fault paths
def test_async_dropout_redispatches_and_completes():
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="async",
                         faults=ClientBehavior(dropout_prob=0.35, seed=7))
    hist = sched.run(4, eval_every=1)
    assert len(hist) == 4 and sched.version == 4
    assert sched.fault_dropouts >= 1 and sched.redispatches >= 1
    assert all(np.isfinite(m.loss) for m in hist)
    for f in strat.engine._cohort_updates.values():
        if hasattr(f, "_cache_size"):       # no recompiles in the event loop
            assert f._cache_size() == 1


def test_byzantine_trimmed_mean_stays_near_clean_run():
    clean = _experiment(rounds=4, mode="async")
    faulty = _experiment(rounds=4, mode="async", aggregator="trimmed_mean",
                         aggregator_opts={"trim": 0.34},
                         faults={"byzantine_frac": 0.2,
                                 "byzantine_scale": -10.0, "seed": 3})
    assert len(faulty.history) == len(clean.history)
    assert np.isfinite(faulty.history[-1].loss)
    assert faulty.history[-1].loss <= 1.25 * clean.history[-1].loss + 0.5


def test_byzantine_unmitigated_hurts():
    """Sanity that the injection bites: sign-flipped updates under plain
    FedAvg end worse than under trimmed-mean with the same faults."""
    faults = {"byzantine_frac": 0.34, "byzantine_scale": -10.0, "seed": 3}
    plain = _experiment(rounds=4, mode="async", faults=faults)
    robust = _experiment(rounds=4, mode="async", aggregator="trimmed_mean",
                         aggregator_opts={"trim": 0.34}, faults=faults)
    assert robust.history[-1].loss < plain.history[-1].loss


def test_zero_fault_model_is_transparent():
    base = _experiment(rounds=3, mode="async")
    nofx = _experiment(rounds=3, mode="async",
                       faults={"dropout_prob": 0.0, "byzantine_frac": 0.0})
    assert [(m.loss, m.acc, m.n_participants) for m in base.history] == \
           [(m.loss, m.acc, m.n_participants) for m in nofx.history]


def test_sync_mode_rejects_faults():
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    with pytest.raises(ValueError, match="lockstep sync"):
        FedScheduler(sim, strat, mode="sync",
                     faults=ClientBehavior(dropout_prob=0.1))


def test_semisync_adaptive_deadline_tracks_latencies():
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="semisync", deadline_quantile=0.7,
                         faults=ClientBehavior(straggler_prob=0.4,
                                               straggler_factor=6.0, seed=2))
    hist = sched.run(5, eval_every=5)
    assert len(hist) == 1 and np.isfinite(hist[-1].loss)
    # the running-quantile window saw one observation per dispatched client
    assert len(sched._lat_window) >= 8
    assert all(t >= 0 for t in sched._lat_window)
