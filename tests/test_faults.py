"""Fault injection & robust aggregation (ISSUE 6): deterministic fault
draws, the fixed byzantine set, robust-aggregator semantics (trimmed mean /
median neutralize an outlier, norm-clip bounds it), dropout → timeout →
re-dispatch on the async event heap with no recompiles, byzantine runs
converging under trimmed-mean, zero-fault transparency, and the adaptive
semisync deadline's latency window."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim
from repro.fed.faults import ClientBehavior, FaultModel
from repro.fed.registry import make_strategy, run_experiment
from repro.fed.runtime import FedScheduler
from repro.fed.strategies import cohort_fedavg, make_aggregator
from repro.models.config import ChainConfig, FedConfig

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=1, lr=3e-3)
KEY = jax.random.PRNGKey(0)


def build_sim(seed=3, n_clients=6, clients_per_round=3):
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: classification_batch(spec, tokens, labels, idx)
    fed = FedConfig(n_clients=n_clients, clients_per_round=clients_per_round,
                    seed=seed)
    return FedSim(CFG, fed, tokens, labels, batch_fn, batch_size=4,
                  memory_constrained=False)


def _experiment(**kw):
    fed = FedConfig(n_clients=6, clients_per_round=3, seed=3)
    return run_experiment(kw.pop("method", "full_adapters"), cfg=CFG,
                          chain=CHAIN, fed=fed, batch_size=4,
                          memory_constrained=False, eval_every=1, **kw)


# -------------------------------------------------------------- fault model
def test_fault_model_deterministic_and_sized():
    b = ClientBehavior(dropout_prob=0.4, byzantine_frac=0.25,
                       straggler_prob=0.5, seed=11)
    m1, m2 = FaultModel(b, 8), FaultModel(b, 8)
    assert m1.byzantine == m2.byzantine and len(m1.byzantine) == 2
    for cid in range(8):
        for seq in range(5):
            assert m1.draw(cid, seq) == m2.draw(cid, seq)
    # different dispatches of the same client draw independently
    draws = {m1.draw(0, s) for s in range(40)}
    assert len(draws) > 1
    assert FaultModel(ClientBehavior(), 8).byzantine == frozenset()


def test_update_scales_marks_byzantine_rows():
    b = ClientBehavior(byzantine_frac=0.5, byzantine_scale=-3.0, seed=1)
    m = FaultModel(b, 4)
    s = m.update_scales(list(range(4)))
    assert s.shape == (4,) and set(s.tolist()) == {1.0, -3.0}
    assert [x for x in s if x != 1.0] == [-3.0] * len(m.byzantine)


# ------------------------------------------------------- robust aggregators
def _cohort_with_outlier(c=5, scale=50.0):
    rng = np.random.default_rng(0)
    d = {"w": jnp.asarray(rng.normal(size=(c, 6, 2)), jnp.float32)}
    return {"w": d["w"].at[0].multiply(scale)}, \
        {"w": d["w"][1:]}  # honest rows


def test_trimmed_mean_neutralizes_outlier():
    deltas, honest = _cohort_with_outlier()
    t0 = {"w": jnp.zeros((6, 2), jnp.float32)}
    w = jnp.ones(5, jnp.float32)
    got = make_aggregator("trimmed_mean", trim=0.25)(t0, deltas, w, None)
    # the corrupted row is sorted to an extreme and trimmed away: the result
    # stays within the honest rows' coordinate-wise envelope
    lo = jnp.min(honest["w"], axis=0)
    hi = jnp.max(honest["w"], axis=0)
    assert bool(jnp.all((got["w"] >= lo - 1e-6) & (got["w"] <= hi + 1e-6)))
    plain = cohort_fedavg(t0, deltas, w, None)
    assert float(jnp.abs(plain["w"]).max()) > float(jnp.abs(got["w"]).max())


def test_median_neutralizes_outlier():
    deltas, honest = _cohort_with_outlier()
    t0 = {"w": jnp.zeros((6, 2), jnp.float32)}
    got = make_aggregator("median")(t0, deltas, jnp.ones(5, jnp.float32),
                                    None)
    lo, hi = jnp.min(honest["w"], axis=0), jnp.max(honest["w"], axis=0)
    assert bool(jnp.all((got["w"] >= lo - 1e-6) & (got["w"] <= hi + 1e-6)))


def test_norm_clip_bounds_contributions():
    deltas, _ = _cohort_with_outlier()
    t0 = {"w": jnp.zeros((6, 2), jnp.float32)}
    w = jnp.ones(5, jnp.float32)
    got = make_aggregator("norm_clip", clip=1.0)(t0, deltas, w, None)
    # every row clipped to L2 ≤ 1 → the mean's norm is at most 1
    assert float(jnp.linalg.norm(got["w"])) <= 1.0 + 1e-5
    # clip=0 defaults to the cohort's median norm — still tames the outlier
    med = make_aggregator("norm_clip")(t0, deltas, w, None)
    plain = cohort_fedavg(t0, deltas, w, None)
    assert float(jnp.abs(med["w"]).max()) < float(jnp.abs(plain["w"]).max())


def test_make_aggregator_unknown_raises():
    with pytest.raises(KeyError, match="unknown aggregator"):
        make_aggregator("geometric_median")


# --------------------------------------------------------------------- krum
def test_krum_hand_computed_selection():
    """Blanchard et al. on scalars x = [-1, -0.4, 0, 0.5, 100] with f = 1:
    k = C − f − 2 = 2 nearest peers per row gives scores
    1.36 / 0.52 / 0.41 / 1.06 / huge — Krum keeps x = 0.0, and multi-Krum
    with m = 2 averages the two best {0.0, −0.4} → −0.2."""
    t0 = {"a": jnp.zeros((1,), jnp.float32)}
    d = {"a": jnp.asarray([-1.0, -0.4, 0.0, 0.5, 100.0],
                          jnp.float32)[:, None]}
    w = jnp.ones((5,), jnp.float32)
    got = make_aggregator("krum", f=1)(t0, d, w, {})
    assert np.allclose(np.asarray(got["a"]), [0.0], atol=1e-6)
    got2 = make_aggregator("multi_krum", f=1, m=2)(t0, d, w, {})
    assert np.allclose(np.asarray(got2["a"]), [-0.2], atol=1e-6)


def test_krum_ignores_sample_weights_and_defaults():
    """Selection is distance-based: a huge sample count must not buy the
    outlier in.  f=0 auto-sizes to (C−3)//2; tiny cohorts fall back to a
    uniform mean (no pairwise geometry to select on)."""
    t0 = {"a": jnp.zeros((1,), jnp.float32)}
    d = {"a": jnp.asarray([-1.0, -0.4, 0.0, 0.5, 100.0],
                          jnp.float32)[:, None]}
    w = jnp.asarray([1.0, 1.0, 1.0, 1.0, 1e6], jnp.float32)
    got = make_aggregator("krum")(t0, d, w, {})
    assert abs(float(got["a"][0])) <= 1.0      # outlier never selected
    tiny = make_aggregator("krum")(
        t0, {"a": jnp.asarray([[1.0], [3.0]], jnp.float32)},
        jnp.asarray([1.0, 9.0], jnp.float32), {})
    assert np.allclose(np.asarray(tiny["a"]), [2.0], atol=1e-6)


def test_multi_krum_neutralizes_outlier_stack():
    deltas, honest = _cohort_with_outlier()
    t0 = {"w": jnp.zeros((6, 2), jnp.float32)}
    got = make_aggregator("multi_krum", f=1)(t0, deltas,
                                             jnp.ones(5, jnp.float32), None)
    lo, hi = jnp.min(honest["w"], axis=0), jnp.max(honest["w"], axis=0)
    assert bool(jnp.all((got["w"] >= lo - 1e-6) & (got["w"] <= hi + 1e-6)))


# ------------------------------------------------- model replacement attack
def test_replace_rows_blends_marked_rows_only():
    from repro.fed.faults import replace_rows
    deltas = {"w": jnp.ones((3, 2), jnp.float32)}
    t0 = {"w": jnp.zeros((2,), jnp.float32)}
    target = {"w": jnp.asarray([2.0, -2.0], jnp.float32)}
    out = jax.jit(replace_rows)(deltas, jnp.asarray([0.0, 1.0, 0.0]),
                                t0, target, jnp.float32(3.0))
    assert np.allclose(out["w"][0], [1.0, 1.0])
    assert np.allclose(out["w"][1], [6.0, -6.0])   # 3·(target − 0)
    assert np.allclose(out["w"][2], [1.0, 1.0])


def test_replacement_target_fixed_and_dtype_shaped():
    b = ClientBehavior(byzantine_frac=0.5, attack="replacement", seed=9)
    m = FaultModel(b, 4)
    like = {"a": jnp.zeros((2, 3), jnp.bfloat16), "b": jnp.zeros((4,))}
    t1, t2 = m.replacement_target(like), m.replacement_target(like)
    assert t1 is t2                               # cached per structure
    assert t1["a"].dtype == jnp.bfloat16 and t1["a"].shape == (2, 3)
    fresh = FaultModel(b, 4).replacement_target(like)
    assert np.array_equal(np.asarray(t1["b"]), np.asarray(fresh["b"]))


def test_unknown_attack_rejected():
    with pytest.raises(ValueError, match="unknown attack"):
        FaultModel(ClientBehavior(attack="label_flip"), 4)


def test_replacement_attack_degrades_fedavg_but_not_multi_krum():
    """The ISSUE 7 acceptance gate: one byzantine client in a 5-cohort
    steering the aggregate toward a random target wrecks plain FedAvg,
    while multi-Krum's distance selection excludes the poisoned row and
    stays at the clean run's loss."""
    faults = {"byzantine_frac": 0.2, "attack": "replacement",
              "replace_boost": 3.0, "seed": 1}
    kw = dict(rounds=3, mode="semisync",
              scheduler_opts={"deadline_quantile": 1.0})
    fed = FedConfig(n_clients=6, clients_per_round=5, seed=3)
    run = lambda **k: run_experiment(
        "full_adapters", cfg=CFG, chain=CHAIN, fed=fed, batch_size=4,
        memory_constrained=False, eval_every=3, **kw, **k)
    clean = run()
    attacked = run(faults=faults)
    defended = run(faults=faults, aggregator="multi_krum",
                   aggregator_opts={"f": 1})
    assert attacked.history[-1].loss > clean.history[-1].loss + 1.0
    assert defended.history[-1].loss <= clean.history[-1].loss + 0.25


def test_replacement_attack_rejects_seed_space_updates():
    """FedKSeed uploads seed-space coefficients, not trainable-shaped
    deltas — there is no trainable to replace, and the blend must refuse
    loudly instead of corrupting silently."""
    sim = build_sim()
    strat = make_strategy("fedkseed", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="async",
                         faults=ClientBehavior(byzantine_frac=0.4,
                                               attack="replacement", seed=2))
    with pytest.raises(ValueError, match="trainable-shaped"):
        sched.run(1, eval_every=1)


# -------------------------------------------- secure agg × robust aggregator
def test_secure_agg_rejects_robust_aggregator_both_orders():
    """PR 6 composition gap: a robust aggregator needs plaintext per-client
    updates, which masked uploads never reveal — both configuration orders
    must refuse."""
    from repro.fed.privacy import SecureAggConfig, enable_secure_agg
    # order 1: aggregator first, then enable_secure_agg
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    strat.aggregator = "krum"
    with pytest.raises(ValueError, match="krum"):
        enable_secure_agg(strat, SecureAggConfig(cohort=3))
    # order 2: secure first, then aggregator — caught at scheduler build
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    enable_secure_agg(strat, SecureAggConfig(cohort=3))
    strat.aggregator = "multi_krum"
    with pytest.raises(ValueError, match="plaintext"):
        FedScheduler(sim, strat, mode="semisync")
    # ... and at the sync round path
    with pytest.raises(ValueError, match="plaintext"):
        strat.round(sim, sim.clients[:3], 0)


# ------------------------------------------------------ availability traces
def test_trace_generators_schema_and_determinism():
    from repro.data.partition import (diurnal_traces, flaky_traces,
                                      make_trace)
    for tr in (diurnal_traces(8, period=100.0, seed=5),
               flaky_traces(8, period=100.0, seed=5)):
        assert len(tr.windows) == 8 and tr.period == 100.0
        for wins in tr.windows:
            for (s, e) in wins:
                assert 0.0 <= s < e <= tr.period
            # windows are sorted and non-overlapping
            flat = [x for w in wins for x in w]
            assert flat == sorted(flat)
    a = make_trace("diurnal", 4, period=50.0, seed=9)
    b = make_trace("diurnal", 4, period=50.0, seed=9)
    assert a == b
    assert make_trace("diurnal", 4, seed=1) != make_trace("diurnal", 4,
                                                          seed=2)
    with pytest.raises(KeyError, match="unknown trace kind"):
        make_trace("weekend", 4)


def test_trace_availability_and_offline_cut_semantics():
    from repro.data.partition import AvailabilityTrace
    tr = AvailabilityTrace(windows=(((0.0, 0.4), (0.8, 1.0)),), period=1.0)
    assert tr.available(0, 0.0) and tr.available(0, 0.39)
    assert not tr.available(0, 0.4) and not tr.available(0, 0.5)
    assert tr.available(0, 0.9) and tr.available(0, 1.85)  # cyclic
    # cut inside the first window; back-to-back wrap (0.8→1.0→0.0→0.4)
    # merges across the period boundary
    assert tr.offline_cut(0, 0.0, 1.0) == pytest.approx(0.4)
    assert tr.offline_cut(0, 0.85, 1.2) is None
    assert tr.offline_cut(0, 0.85, 1.5) == pytest.approx(1.4)
    # offline at dispatch → cut immediately
    assert tr.offline_cut(0, 0.5, 0.7) == pytest.approx(0.5)


def test_trace_churn_completes_via_backoff():
    """Staggered short windows with gaps where *nobody* is online: the run
    still reaches its commit target because dispatch failures park capped
    exponential-backoff retries on the event heap, and mid-round window
    closures become timeout events that re-dispatch."""
    from repro.data.partition import AvailabilityTrace
    win = (((0.0, 0.30),), ((0.0, 0.35),), ((0.55, 0.95),),
           ((0.60, 1.00),), ((1.25, 1.60),), ((1.30, 1.65),))
    tr = AvailabilityTrace(windows=win, period=2.0)
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="async", trace=tr, buffer_size=2,
                         concurrency=2, backoff_base=0.05, backoff_cap=0.4)
    hist = sched.run(5, eval_every=5)
    assert sched._done == 5 and sched.committed_updates == 10
    assert sched.backoff_retries >= 1      # rode through an all-offline gap
    assert sched.trace_dropouts >= 1       # a window closed mid-round
    assert all(np.isfinite(m.loss) for m in hist)
    for f in strat.engine._cohort_updates.values():
        if hasattr(f, "_cache_size"):      # churn recovery never recompiles
            assert f._cache_size() == 1


def test_sync_mode_rejects_trace():
    from repro.data.partition import make_trace
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    with pytest.raises(ValueError, match="lockstep sync"):
        FedScheduler(sim, strat, mode="sync",
                     trace=make_trace("diurnal", 6))


# --------------------------------------------------- event-heap fault paths
def test_async_dropout_redispatches_and_completes():
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="async",
                         faults=ClientBehavior(dropout_prob=0.35, seed=7))
    hist = sched.run(4, eval_every=1)
    assert len(hist) == 4 and sched.version == 4
    assert sched.fault_dropouts >= 1 and sched.redispatches >= 1
    assert all(np.isfinite(m.loss) for m in hist)
    for f in strat.engine._cohort_updates.values():
        if hasattr(f, "_cache_size"):       # no recompiles in the event loop
            assert f._cache_size() == 1


def test_byzantine_trimmed_mean_stays_near_clean_run():
    clean = _experiment(rounds=4, mode="async")
    faulty = _experiment(rounds=4, mode="async", aggregator="trimmed_mean",
                         aggregator_opts={"trim": 0.34},
                         faults={"byzantine_frac": 0.2,
                                 "byzantine_scale": -10.0, "seed": 3})
    assert len(faulty.history) == len(clean.history)
    assert np.isfinite(faulty.history[-1].loss)
    assert faulty.history[-1].loss <= 1.25 * clean.history[-1].loss + 0.5


def test_byzantine_unmitigated_hurts():
    """Sanity that the injection bites: sign-flipped updates under plain
    FedAvg end worse than under trimmed-mean with the same faults."""
    faults = {"byzantine_frac": 0.34, "byzantine_scale": -10.0, "seed": 3}
    plain = _experiment(rounds=4, mode="async", faults=faults)
    robust = _experiment(rounds=4, mode="async", aggregator="trimmed_mean",
                         aggregator_opts={"trim": 0.34}, faults=faults)
    assert robust.history[-1].loss < plain.history[-1].loss


def test_zero_fault_model_is_transparent():
    base = _experiment(rounds=3, mode="async")
    nofx = _experiment(rounds=3, mode="async",
                       faults={"dropout_prob": 0.0, "byzantine_frac": 0.0})
    assert [(m.loss, m.acc, m.n_participants) for m in base.history] == \
           [(m.loss, m.acc, m.n_participants) for m in nofx.history]


def test_sync_mode_rejects_faults():
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    with pytest.raises(ValueError, match="lockstep sync"):
        FedScheduler(sim, strat, mode="sync",
                     faults=ClientBehavior(dropout_prob=0.1))


def test_semisync_adaptive_deadline_tracks_latencies():
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="semisync", deadline_quantile=0.7,
                         faults=ClientBehavior(straggler_prob=0.4,
                                               straggler_factor=6.0, seed=2))
    hist = sched.run(5, eval_every=5)
    assert len(hist) == 1 and np.isfinite(hist[-1].loss)
    # the running-quantile window saw one observation per dispatched client
    assert len(sched._lat_window) >= 8
    assert all(t >= 0 for t in sched._lat_window)
