"""Batched cohort execution (ISSUE 2) + GradProgram dispatch (ISSUE 4):
cohort-vs-sequential equivalence for a windowed (chainfed), a layer-masked
(fedra), a rank-masked (flora), a perturbation-grad (fwdllm), a seed-space
(fedkseed), a transform-hooked (c2a) and an embedding-tuning (fedembed)
strategy, the cohort batch stacking/padding, the fused FedAvg, the
plan-driven pod step, and fused-vs-unfused adapter numerics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adapters import ActiveAdapters
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim
from repro.fed.registry import make_strategy
from repro.fed.strategies import PlanEngine, stack_masks
from repro.models.config import ChainConfig, FedConfig
from repro.train.losses import IGNORE

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=2, lr=1e-3)
KEY = jax.random.PRNGKey(0)


def build_sim(seed=3, n_clients=6, clients_per_round=3, batch_size=4):
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: {k: jnp.asarray(v) for k, v in
                            classification_batch(spec, tokens, labels,
                                                 idx).items()}
    fed = FedConfig(n_clients=n_clients, clients_per_round=clients_per_round,
                    seed=seed)
    return FedSim(CFG, fed, tokens, labels, batch_fn, batch_size=batch_size,
                  memory_constrained=False)


def run_one_round(name, path, rounds=2):
    """Fresh sim + strategy (identical seeds), then ``rounds`` rounds on the
    requested path; returns the aggregated (adapters, head, embed)."""
    sim = build_sim()
    opts = {"use_foat": False} if name == "chainfed" else {}
    strat = make_strategy(name, CFG, CHAIN, KEY, **opts)
    if name == "chainfed":
        strat._foat_done = True
    for r in range(rounds):
        clients = sim.sample_clients(strat.memory_method,
                                     **strat.memory_kwargs(r))
        if path == "sequential":
            strat.sequential_round(sim, clients, r)
        else:
            strat.round(sim, clients, r)
    head = None if strat.head is None else np.asarray(strat.head["w"])
    return (np.asarray(strat.adapters["down"]),
            np.asarray(strat.adapters["up"]), head,
            np.asarray(strat.params["embed"]["table"], np.float32))


# ------------------------------------------------- cohort ≡ sequential round
@pytest.mark.parametrize("name", ["chainfed", "fedra", "flora", "fwdllm",
                                  "fedkseed", "c2a", "fedembed"])
def test_cohort_matches_sequential(name):
    """Windowed (chainfed), layer-masked (fedra), rank-masked (flora),
    perturbation-grad (fwdllm), seed-space (fedkseed), transform-hooked
    (c2a) and embedding-tuning (fedembed) rounds must produce the same
    aggregated adapters/head/embedding on both paths."""
    seq = run_one_round(name, "sequential")
    coh = run_one_round(name, "cohort")
    for a, b in zip(seq, coh):
        if a is not None:
            np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-5)


def test_grad_program_round_uses_cohort_step():
    """Non-"ad" grad programs ride the batched cohort path: one cohort
    compilation, no per-client/per-step dispatch."""
    for name in ("fwdllm", "fedkseed"):
        sim = build_sim()
        strat = make_strategy(name, CFG, CHAIN, KEY)
        clients = sim.sample_clients(strat.memory_method)
        strat.round(sim, clients, 0)
        assert len(strat.engine._cohort) == 1, name
        assert len(strat.engine._steps) == 0, name


def test_cohort_round_uses_cohort_step():
    """The generic round must hit the cohort cache, not the per-client one."""
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    clients = sim.sample_clients(strat.memory_method)
    strat.round(sim, clients, 0)
    assert len(strat.engine._cohort) == 1
    assert len(strat.engine._steps) == 0


# --------------------------------------------------------- batch stacking
def test_cohort_batches_layout():
    sim = build_sim()
    clients = sim.clients[:3]
    batches = sim.cohort_batches(clients, 2)
    assert batches["tokens"].shape == (3, 2, 4, DATASETS["agnews"].seq_len)
    assert batches["labels"].shape == batches["tokens"].shape
    # non-batch leaves stack without padding logic
    assert batches["class_tokens"].shape[:2] == (3, 2)


def test_cohort_batches_pads_small_clients_with_ignore():
    """A client whose shard is smaller than the batch size is padded to the
    cohort batch size with IGNORE labels — zero loss weight, so padding is
    exact under the masked CE mean."""
    sim = build_sim(batch_size=4)
    small = sim.clients[0]
    small.sampler.bs = 2            # force a short batch for this client
    batches = sim.cohort_batches([small, sim.clients[1]], 1)
    assert batches["tokens"].shape[2] == 4
    lab = np.asarray(batches["labels"][0, 0])
    assert np.all(lab[2:] == IGNORE)
    assert np.any(np.asarray(batches["labels"][1, 0]) != IGNORE)


def test_stack_masks():
    ms = [{"layer_mask": jnp.arange(4.0)}, {"layer_mask": jnp.ones(4)}]
    out = stack_masks(ms)
    assert out["layer_mask"].shape == (2, 4)
    assert stack_masks([]) == {}
    assert stack_masks([{}, {}]) == {}


# ------------------------------------------------------------- fused FedAvg
def test_fedavg_weighted_mean():
    deltas = [{"w": jnp.full((2, 2), float(i))} for i in range(3)]
    out = PlanEngine.fedavg(deltas, [1.0, 1.0, 2.0])
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.full((2, 2), (0 + 1 + 2 * 2) / 4.0),
                               rtol=1e-6)


# ------------------------------------------------- plan-driven pod fed step
def test_pod_fed_step_window_confinement():
    """The pjit fed step built from a TrainablePlan updates only the DLCT
    window slice of the stacked adapters."""
    from repro.models.transformer import ChainSegments, init_adapters, init_lm
    from repro.train.steps import make_fed_train_step

    params = init_lm(jax.random.PRNGKey(0), CFG)
    adapters = init_adapters(jax.random.PRNGKey(1), CFG)
    seg = ChainSegments(1, 2)
    step = make_fed_train_step(CFG, CHAIN.replace(optimizer="sgd", lr=1e-2),
                               seg)
    batch = {"tokens": jnp.ones((2, 2, 2, 8), jnp.int32),
             "labels": jnp.ones((2, 2, 2, 8), jnp.int32)}
    new, metrics = jax.jit(step)(params, adapters, batch)
    assert np.isfinite(float(metrics["loss"]))
    delta = np.asarray(jnp.abs(new["down"] - adapters["down"]
                               ).sum(axis=(1, 2)))
    assert np.all(delta[1:3] > 0.0)
    assert np.all(delta[:1] == 0.0) and np.all(delta[3:] == 0.0)


def test_pod_fed_step_matches_gpo_seq():
    """gpo and gpo_seq hooks agree through the pod step (same math, different
    checkpointing)."""
    from repro.models.transformer import ChainSegments, init_adapters, init_lm
    from repro.train.steps import make_fed_train_step

    params = init_lm(jax.random.PRNGKey(0), CFG)
    adapters = init_adapters(jax.random.PRNGKey(1), CFG)
    seg = ChainSegments(1, 2)
    batch = {"tokens": jnp.ones((2, 1, 2, 8), jnp.int32),
             "labels": jnp.ones((2, 1, 2, 8), jnp.int32)}
    outs = []
    for gpo_seq in (False, True):
        step = make_fed_train_step(CFG, CHAIN, seg, gpo_sequential=gpo_seq)
        new, m = jax.jit(step)(params, adapters, batch)
        outs.append((np.asarray(new["down"]), float(m["loss"])))
    np.testing.assert_allclose(outs[0][0], outs[1][0], atol=1e-6)
    assert abs(outs[0][1] - outs[1][1]) < 1e-5


# ------------------------------------------------ fused adapter kernel path
def test_fused_adapter_forward_full_parity():
    """forward_full with the fused Pallas kernel path (cfg.adapter.fused=True,
    interpret on CPU) matches the plain XLA path — values and gradients."""
    from repro.models.transformer import forward_full, init_adapters, init_lm
    from repro.train.losses import cross_entropy

    cfg = CFG.replace(n_layers=2)
    cfgk = cfg.replace(adapter=cfg.adapter.replace(fused=True))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ad = init_adapters(jax.random.PRNGKey(1), cfg)
    ad = {"down": ad["down"],
          "up": 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                         ad["up"].shape)}
    batch = {"tokens": jnp.ones((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}

    def loss(adx, c):
        logits, _ = forward_full(params, adx, batch, c, remat=False)
        return cross_entropy(logits, batch["labels"])

    l_ref, g_ref = jax.value_and_grad(loss)(ad, cfg)
    l_k, g_k = jax.value_and_grad(loss)(ad, cfgk)
    np.testing.assert_allclose(float(l_ref), float(l_k), rtol=1e-6)
    for k in g_ref:
        np.testing.assert_allclose(np.asarray(g_ref[k]), np.asarray(g_k[k]),
                                   atol=1e-6)


def test_row_block_subtracts_resident_weights():
    from repro.kernels.fused_adapter import row_block

    # the weight footprint must shrink the block: with a huge rank the
    # resident weights eat the whole budget and the floor kicks in
    assert row_block(8192, 4, rank=128) < row_block(8192, 4, rank=1)
    assert row_block(8192, 4, rank=10 ** 6) == 8
    # bf16 tiles fit twice the rows of f32
    assert row_block(4096, 2, rank=64) >= row_block(4096, 4, rank=64)
