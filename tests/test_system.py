"""End-to-end behaviour tests for the CHAINFED system: federated learning
progress, the memory wall, checkpointing, and the analytic memory model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.memory import comm_bytes_per_round, peak_memory
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.baselines import BASELINES
from repro.fed.engine import FedSim
from repro.fed.runtime import run_sync_rounds
from repro.fed.registry import make_strategy
from repro.models.config import ChainConfig, FedConfig

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=96, d_ff=192)


def make_sim(iid=True, memory_constrained=False, n_clients=8):
    spec = DATASETS["agnews"]
    spec = spec.__class__(**{**spec.__dict__, "vocab": CFG.vocab_size,
                             "n_samples": 1024})
    tokens, labels = make_classification(spec)
    fed = FedConfig(n_clients=n_clients, clients_per_round=3, iid=iid)
    bf = lambda idx: {k: jnp.asarray(v) for k, v in
                      classification_batch(spec, tokens, labels, idx).items()}
    return FedSim(CFG, fed, tokens, labels, bf, batch_size=8,
                  memory_constrained=memory_constrained), tokens


def test_chainfed_improves_over_rounds():
    sim, tokens = make_sim()
    chain = ChainConfig(window=2, lam=0.2, local_steps=2, lr=3e-3)
    strat = make_strategy("chainfed", CFG, chain, jax.random.PRNGKey(0))
    from repro.train.pretrain import lm_pretrain
    params, _ = lm_pretrain(strat.params, CFG, tokens, steps=60)
    strat.params = params
    l0, a0 = strat.evaluate(sim.eval_batch())
    hist = run_sync_rounds(sim, strat, rounds=10, eval_every=5)
    assert hist[-1].loss < l0, "chainfed did not reduce eval loss"


def test_memory_wall_excludes_clients():
    """Full-adapter methods lose low-memory clients; CHAINFED recruits more.
    Uses a deep config (paper regime: window << L) so the chain footprint is
    a small fraction of end-to-end."""
    deep = CFG.replace(n_layers=24)
    spec = DATASETS["agnews"]
    spec = spec.__class__(**{**spec.__dict__, "vocab": deep.vocab_size,
                             "n_samples": 512})
    tokens, labels = make_classification(spec)
    fed = FedConfig(n_clients=20, clients_per_round=3)
    bf = lambda idx: {k: jnp.asarray(v) for k, v in
                      classification_batch(spec, tokens, labels, idx).items()}
    sim = FedSim(deep, fed, tokens, labels, bf, batch_size=8,
                 memory_constrained=True, budget_range=(0.10, 1.30))
    full = sim.eligible("full_adapters")
    cf = sim.eligible("chainfed", window=2, l_start=8)
    assert len(full) < 20, "memory wall should exclude some clients"
    assert len(cf) > len(full), "chainfed should recruit more clients"


def test_memory_model_orderings():
    cfg = get_config("qwen2_1_5b")
    fa = peak_memory(cfg, "full_adapters", 8, 256)["total"]
    cf2 = peak_memory(cfg, "chainfed", 8, 256, window=2, l_start=8)["total"]
    cf6 = peak_memory(cfg, "chainfed", 8, 256, window=6, l_start=8)["total"]
    lp = peak_memory(cfg, "linear_probing", 8, 256)["total"]
    assert cf2 < cf6 < fa          # Q↑ ⇒ memory↑ (Fig. 8), chain ≪ e2e
    assert fa / cf2 > 4            # the headline multiple-× reduction
    assert peak_memory(cfg, "fwdllm", 8, 256)["activations"] < \
        peak_memory(cfg, "full_adapters", 8, 256)["activations"]
    assert lp < fa


def test_param_dominance_matches_paper():
    """Fig. 3 claim: base parameters dominate (>85% for the 67B class)."""
    cfg = get_config("deepseek_67b")
    m = peak_memory(cfg, "full_adapters", 8, 256)
    assert m["params"] / m["total"] > 0.85


def test_comm_accounting():
    cfg = get_config("bert_tiny")
    cf = comm_bytes_per_round(cfg, "chainfed", window=2)
    fa = comm_bytes_per_round(cfg, "full_adapters")
    ks = comm_bytes_per_round(cfg, "fedkseed", kseeds=16)
    assert cf < fa                 # window-only sync (paper §H.2)
    assert ks < 1024               # "under 18 KB"


def test_ckpt_roundtrip(tmp_path):
    from repro.ckpt.io import load_pytree, save_pytree
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (3, 4)),
            "b": {"c": jnp.arange(5),
                  "d": jax.random.normal(key, (2, 2)).astype(jnp.bfloat16)}}
    p = save_pytree(tmp_path / "x.msgpack", tree, step=7)
    got, step = load_pytree(p, tree)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_all_baselines_one_round():
    sim, _ = make_sim()
    chain = ChainConfig(window=2, local_steps=1, lr=1e-3)
    for name, cls in BASELINES.items():
        strat = cls(CFG, chain, jax.random.PRNGKey(1))
        hist = run_sync_rounds(sim, strat, rounds=1, eval_every=1)
        assert np.isfinite(hist[-1].loss), name


def test_pretrain_reduces_lm_loss():
    from repro.train.pretrain import lm_pretrain
    from repro.models import transformer as T
    sim, tokens = make_sim()
    params = T.init_lm(jax.random.PRNGKey(0), CFG)
    _, loss_few = lm_pretrain(params, CFG, tokens, steps=5)
    _, loss_more = lm_pretrain(params, CFG, tokens, steps=60)
    assert loss_more < loss_few
