"""Event-driven federation runtime (ISSUE 5): sync-mode bit-identity with
the legacy ``run_rounds`` loop, async ≡ sync under uniform latencies with
buffer = cohort size, staleness-weight monotonicity, device-profile
sampling, the virtual-clock cost model, and heterogeneous per-tier
``n_samples`` bucketing with no cross-bucket recompiles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.memory import round_flops
from repro.data.partition import (DEVICE_TIERS, DeviceProfile,
                                  sample_profiles, uniform_profiles)
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim, RoundMetrics, run_rounds
from repro.fed.registry import make_strategy
from repro.fed.runtime import FedScheduler, client_round_time
from repro.models.config import ChainConfig, FedConfig

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=2, lr=1e-3)
KEY = jax.random.PRNGKey(0)


def build_sim(seed=3, n_clients=6, clients_per_round=3, batch_size=4,
              uniform=False):
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: classification_batch(spec, tokens, labels, idx)
    fed = FedConfig(n_clients=n_clients, clients_per_round=clients_per_round,
                    seed=seed)
    sim = FedSim(CFG, fed, tokens, labels, batch_fn, batch_size=batch_size,
                 memory_constrained=False)
    if uniform:
        for c, p in zip(sim.clients, uniform_profiles(n_clients)):
            c.profile = p
    return sim


def legacy_run_rounds(sim, strategy, rounds, eval_every=5):
    """The pre-runtime lockstep loop, verbatim — the bit-identity oracle."""
    history = []
    eval_b = sim.eval_batch()
    for r in range(rounds):
        clients = sim.sample_clients(strategy.memory_method,
                                     **strategy.memory_kwargs(r))
        if clients:
            strategy.round(sim, clients, r)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            loss, acc = strategy.evaluate(eval_b)
            history.append(RoundMetrics(r, loss, acc, len(clients),
                                        strategy.comm_bytes_per_round()))
    return history


def run_mode(name, mode, rounds=4, eval_every=2, opts=None, uniform=False,
             legacy=False, seed=3, strategy_opts=None):
    sim = build_sim(seed=seed, uniform=uniform)
    strat = make_strategy(name, CFG, CHAIN, KEY, **(strategy_opts or {}))
    if legacy:
        hist = legacy_run_rounds(sim, strat, rounds, eval_every=eval_every)
    elif mode == "sync" and not opts:
        hist = run_rounds(sim, strat, rounds, eval_every=eval_every)
    else:
        hist = FedScheduler(sim, strat, mode=mode, **(opts or {})).run(
            rounds, eval_every=eval_every)
    head = None if strat.head is None else np.asarray(strat.head["w"])
    return hist, (np.asarray(strat.adapters["down"]),
                  np.asarray(strat.adapters["up"]), head)


# --------------------------------------------- sync ≡ legacy (bit-identical)
@pytest.mark.parametrize("name", ["chainfed", "full_adapters", "fedra"])
def test_sync_reproduces_legacy_run_rounds(name):
    """``FedScheduler(mode="sync")`` (the ``run_rounds`` wrapper) must
    reproduce the legacy lockstep history bit-identically: same rng draws,
    same cohort dispatch, same eval cadence — for chainfed (stage-advance,
    FOAT) and two baselines (one with a bespoke in-graph aggregation)."""
    h_legacy, s_legacy = run_mode(name, "sync", legacy=True)
    h_sync, s_sync = run_mode(name, "sync")
    assert [(m.round, m.loss, m.acc, m.n_participants, m.comm_bytes)
            for m in h_legacy] == \
           [(m.round, m.loss, m.acc, m.n_participants, m.comm_bytes)
            for m in h_sync]
    for a, b in zip(s_legacy, s_sync):
        if a is not None:
            np.testing.assert_array_equal(a, b)
    # the wrapper additionally tracks the virtual clock
    assert all(m.wallclock > 0 for m in h_sync)
    assert [m.wallclock for m in h_sync] == sorted(m.wallclock
                                                   for m in h_sync)


# ------------------------------- async degenerates to sync (uniform devices)
@pytest.mark.parametrize("name", ["full_adapters", "fwdllm"])
def test_async_uniform_buffer_equals_sync(name):
    """With identical device profiles and buffer = concurrency = cohort
    size, every buffer flush contains exactly one full dispatch wave with
    zero staleness — async must match the sync trajectory (allclose: the
    aggregation runs unfused vs fused)."""
    h_sync, s_sync = run_mode(name, "sync", uniform=True)
    h_async, s_async = run_mode(name, "async", uniform=True,
                                opts={"buffer_size": 3, "concurrency": 3})
    assert len(h_sync) == len(h_async)
    for a, b in zip(h_sync, h_async):
        assert a.n_participants == b.n_participants
        assert b.stale_updates == 0
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(a.acc, b.acc, rtol=1e-5, atol=1e-6)
    for a, b in zip(s_sync, s_async):
        if a is not None:
            np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-5)


def test_async_heterogeneous_differs_and_counts_staleness():
    """With heterogeneous latencies and a small buffer, commits interleave:
    the trajectory departs from sync and stale updates appear (discounted,
    not dropped)."""
    hist, _ = run_mode("full_adapters", "async", rounds=6, eval_every=1,
                       opts={"buffer_size": 1, "concurrency": 3})
    assert len(hist) == 6
    assert sum(m.stale_updates for m in hist) >= 0
    assert all(np.isfinite(m.loss) for m in hist)
    wall = [m.wallclock for m in hist]
    assert wall == sorted(wall) and wall[0] > 0


# ------------------------------------------------------------------ semisync
@pytest.mark.parametrize("straggler", ["drop", "carry"])
def test_semisync_modes_run(straggler):
    hist, _ = run_mode("chainfed", "semisync", rounds=4, eval_every=2,
                       opts={"deadline_quantile": 0.5,
                             "straggler": straggler})
    assert len(hist) == 2
    assert all(np.isfinite(m.loss) for m in hist)
    if straggler == "drop":
        # the deadline cuts the cohort: fewer participants than sampled
        assert all(m.n_participants <= 3 for m in hist)
    else:
        # carried stragglers commit late, staleness-discounted
        assert sum(m.stale_updates for m in hist) >= 0


def test_semisync_full_quantile_commits_everyone():
    hist, _ = run_mode("full_adapters", "semisync", rounds=2, eval_every=1,
                       opts={"deadline_quantile": 1.0})
    assert all(m.n_participants == 3 for m in hist)
    assert all(m.stale_updates == 0 for m in hist)


# ------------------------------------------------- staleness weight contract
def test_staleness_weight_monotone_and_fresh_unit():
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    ws = [strat.staleness_weight(s) for s in range(8)]
    assert ws[0] == pytest.approx(1.0)
    assert all(a >= b for a, b in zip(ws, ws[1:]))   # non-increasing
    assert all(w > 0 for w in ws)                    # discounted, never dropped


# ------------------------------------- heterogeneous n_samples (bucketing)
def test_heterogeneous_nsamples_buckets_without_recompiles():
    """fwdllm with per-tier perturbation budgets: one experiment runs ≥ 2
    distinct ``n_samples`` plans; the runtime buckets dispatch waves by plan
    and compiles exactly one ``cohort_updates`` per bucket — further events
    never add compilations (the acceptance criterion)."""
    sim = build_sim(n_clients=8, clients_per_round=4)
    # split the population over two tiers with distinct budgets
    for i, c in enumerate(sim.clients):
        tier = "low" if i % 2 == 0 else "high"
        c.profile = DeviceProfile(tier=tier, flops=2e9 if tier == "low"
                                  else 2e10, bandwidth=1e7, memory=1 << 30)
    strat = make_strategy("fwdllm", CFG, CHAIN, KEY,
                          samples_by_tier={"low": 2, "high": 6})
    plans = {strat.plan(c, 0) for c in sim.clients}
    assert len(plans) == 2          # two distinct grad_cfg → two buckets
    sched = FedScheduler(sim, strat, mode="async", buffer_size=4,
                         concurrency=4, bucket_pad=4)
    sched.run(3, eval_every=3)
    progs = strat.engine._cohort_updates
    assert set(progs) == plans      # one compiled step per (plan, grad_cfg)
    traces = {p: f._cache_size() for p, f in progs.items()
              if hasattr(f, "_cache_size")}
    sched2 = FedScheduler(sim, strat, mode="async", buffer_size=4,
                          concurrency=4, bucket_pad=4)
    sched2.run(3, eval_every=3)
    assert set(strat.engine._cohort_updates) == plans
    for p, f in progs.items():      # no recompiles inside the event loop
        if hasattr(f, "_cache_size"):
            assert f._cache_size() == traces[p] == 1, p


def test_kseed_tiered_seed_budgets():
    """FedKSeed per-tier K: tiered clients select seed prefixes; the round
    commits per plan-group through each group's own seed set."""
    sim = build_sim(n_clients=4, clients_per_round=4)
    for i, c in enumerate(sim.clients):
        c.profile = DeviceProfile(tier="low" if i < 2 else "high",
                                  flops=1e9, bandwidth=1e7, memory=1 << 30)
    strat = make_strategy("fedkseed", CFG, CHAIN, KEY,
                          k_by_tier={"low": 4, "high": 8})
    before = np.asarray(strat.adapters["down"]).copy()
    clients = sim.sample_clients(strat.memory_method)
    strat.round(sim, clients, 0)
    plans = {strat.plan(c, 0) for c in clients}
    assert {len(p.grad_options["seeds"]) for p in plans} == {4, 8}
    assert not np.array_equal(before, np.asarray(strat.adapters["down"]))


# ------------------------------------------------ profiles & the cost model
def test_sample_profiles_deterministic_and_tiered():
    budgets = np.asarray([10, 50, 120], np.int64)
    p1 = sample_profiles(budgets, ref=100, seed=7)
    p2 = sample_profiles(budgets, ref=100, seed=7)
    assert p1 == p2
    assert [p.tier for p in p1] == ["low", "mid", "high"]
    assert p1[0].flops < p1[2].flops
    assert [p.memory for p in p1] == [10, 50, 120]


def test_fedsim_clients_carry_profiles():
    sim = build_sim()
    assert all(c.profile is not None for c in sim.clients)
    assert all(c.profile.memory == c.mem_budget for c in sim.clients)
    names = [t[0] for t in DEVICE_TIERS]
    assert all(c.profile.tier in names for c in sim.clients)


def test_round_flops_orders_methods_sensibly():
    kw = dict(batch=4, seq=32, local_steps=1)
    full = round_flops(CFG, "full_adapters", **kw)
    chain = round_flops(CFG, "chainfed", window=2, l_start=1, **kw)
    probe = round_flops(CFG, "linear_probing", **kw)
    fwd = round_flops(CFG, "fwdllm", n_samples=8, **kw)
    assert chain < full          # windowed backward beats full backprop
    assert probe < full
    assert fwd > round_flops(CFG, "fwdllm", n_samples=2, **kw)
    assert round_flops(CFG, "full_adapters", local_steps=4, batch=4,
                       seq=32) == pytest.approx(4 * full)


def test_client_round_time_uses_profile_and_plan():
    sim = build_sim()
    strat = make_strategy("fwdllm", CFG, CHAIN, KEY,
                          samples_by_tier={"low": 2, "high": 8})
    c = sim.clients[0]
    slow = dataclasses.replace(c.profile, flops=1e9, bandwidth=1e6)
    fast = dataclasses.replace(c.profile, flops=1e11, bandwidth=1e9)
    plan = strat.plan(c, 0)
    c.profile = slow
    t_slow = client_round_time(sim, strat, c, plan)
    c.profile = fast
    t_fast = client_round_time(sim, strat, c, plan)
    assert t_slow > t_fast > 0


def test_scheduler_rejects_unknown_mode():
    sim = build_sim()
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    with pytest.raises(ValueError, match="unknown mode"):
        FedScheduler(sim, strat, mode="warp")
    with pytest.raises(ValueError, match="straggler"):
        FedScheduler(sim, strat, mode="semisync", straggler="shrug")
    with pytest.raises(ValueError, match="buffer_size"):
        # a buffer larger than the in-flight set could never fill
        FedScheduler(sim, strat, mode="async", concurrency=2, buffer_size=4)


def test_sample_never_redispatches_inflight_clients():
    """A device cannot compute two overlapping local rounds: clients parked
    on the event heap are excluded from replacement sampling."""
    sim = build_sim(n_clients=4, clients_per_round=2)
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="async")
    busy = frozenset(c.cid for c in sim.clients[:3])
    for _ in range(8):
        got = sched._sample(2, 0, busy=busy)
        assert all(c.cid not in busy for c in got)
    assert sched._sample(2, 0, busy=frozenset(c.cid for c in sim.clients)) \
        == []


def test_staleness_cap_voided_buffer_not_counted_as_commit():
    """When the cap filters out every buffered entry the model does not
    move — the flush must not consume a commit or record a metric."""
    sim = build_sim(uniform=True)
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    sched = FedScheduler(sim, strat, mode="async", buffer_size=2,
                         concurrency=3, staleness_cap=0)
    hist = sched.run(4, eval_every=1)
    assert sched.version == len(hist) or len(hist) <= sched.version
    assert all(m.stale_updates == 0 for m in hist)   # capped, never stale
    assert sched.committed_updates >= len(hist)


def test_chainfed_one_stage_event_per_server_commit():
    """A multi-plan-group server commit (async buffers mixing dispatch
    stages) must fire exactly ONE stage event — begin/end_commit debounce
    the per-group ``commit_trainable`` bookkeeping."""
    strat = make_strategy("chainfed", CFG, CHAIN, KEY, use_foat=False)
    plan = strat.plan(None, 0)
    new = strat.init_trainable(plan)
    before = strat._commits
    strat.begin_commit()
    strat.commit_trainable(plan, new)
    strat.commit_trainable(strat.plan(None, 0), strat.init_trainable(plan))
    strat.end_commit()
    assert strat._commits == before + 1
    # outside a bracket (the sync round path) every commit is an event
    strat.commit_trainable(strat.plan(None, 0),
                           strat.init_trainable(strat.plan(None, 0)))
    assert strat._commits == before + 2


# ------------------------------------------------ chainfed plateau advance
def test_chainfed_plateau_advances_on_convergence_events():
    """The DLCT window advances on commit/convergence events, not round
    numbering: the plateau policy holds a stage while its committed loss
    improves and releases it when improvement stalls."""
    sim = build_sim()
    strat = make_strategy("chainfed_plateau", CFG, CHAIN, KEY,
                          use_foat=False, plateau_patience=1,
                          plateau_tol=1e9)   # huge tol → immediate plateau
    strat._foat_done = True
    run_rounds(sim, strat, 4, eval_every=4)
    assert strat._stage >= 1                 # advanced by events
    assert strat._commits == 4
